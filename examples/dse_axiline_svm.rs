//! Paper §8.4 / Fig. 11: MOTPE design-space exploration of an
//! Axiline-SVM (55 features) accelerator on NanGate45 — architectural
//! knobs (dimension, num_cycles) and backend knobs (f_target, util),
//! objective alpha*E + beta*A with alpha=1, beta=0.001, then the top-3
//! winners ground-truthed against the full SP&R oracle.
//!
//! Run: `cargo run --release --example dse_axiline_svm [-- --quick]`

use fso::coordinator::experiments::{dse, ExpOptions};

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let opts = ExpOptions { quick, ..Default::default() };
    opts.ensure_out_dir()?;
    dse::fig11_axiline_svm(&opts)
}
