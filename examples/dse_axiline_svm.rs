//! Paper §8.4 / Fig. 11: MOTPE design-space exploration of an
//! Axiline-SVM (55 features) accelerator on NanGate45 — architectural
//! knobs (dimension, num_cycles) and backend knobs (f_target, util),
//! objective alpha*E + beta*A with alpha=1, beta=0.001, then the top-3
//! winners ground-truthed against the full SP&R oracle.
//!
//! Run: `cargo run --release --example dse_axiline_svm [-- --quick] [-- --cache-dir DIR]`
//! With `--cache-dir`, the SP&R oracle results *and* the fitted
//! surrogate bundle persist between runs — a second invocation
//! warm-starts from disk (0 oracle runs, 0 surrogate refits) and
//! replays a byte-identical Pareto front. `--no-model-cache` keeps
//! only the oracle half.

use fso::coordinator::experiments::{dse, ExpOptions};
use fso::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let opts = ExpOptions {
        quick: args.flag("quick"),
        cache_dir: args.path("cache-dir"),
        no_model_cache: args.flag("no-model-cache"),
        coalesce: args.flag("coalesce"),
        inflight: args.usize_or("inflight", 4)?,
        ..Default::default()
    };
    opts.ensure_out_dir()?;
    dse::fig11_axiline_svm(&opts)
}
