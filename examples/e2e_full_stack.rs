//! End-to-end validation driver (DESIGN.md §End-to-end validation):
//! exercises every layer of the stack on a real small workload —
//!
//!   1. generators -> LHG -> backend SP&R oracle -> system simulators
//!      produce a labelled dataset (Axiline running SVM training);
//!   2. all five predictor families train, the ANN and GCN through the
//!      AOT JAX/Pallas artifacts on the PJRT runtime (python is not
//!      running — the artifacts were compiled by `make artifacts`);
//!   3. the dynamic-batching predict server serves concurrent traffic;
//!   4. MOTPE DSE + Eq. 3 picks a design, ground-truthed by the oracle.
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `cargo run --release --example e2e_full_stack`

use std::rc::Rc;
use std::time::Instant;

use anyhow::Result;

use fso::backend::Enablement;
use fso::coordinator::dse_driver::{axiline_svm_problem, DseDriver, SurrogateBundle};
use fso::coordinator::{
    datagen, DatagenConfig, EvalService, ModelMenu, PredictServer, TrainOptions, Trainer,
};
use fso::data::Metric;
use fso::dse::MotpeConfig;
use fso::generators::Platform;
use fso::models::ann::glorot_init;
use fso::runtime::Engine;
use fso::util::rng::Rng;

fn main() -> Result<()> {
    let t_start = Instant::now();
    let artifacts = fso::test_support::artifacts_dir()
        .expect("artifacts not built — run `make artifacts`");

    // ---- 1. data generation through the full substrate stack --------
    println!("[1/4] datagen: Axiline/GF12, SVM-55 workload");
    let cfg = DatagenConfig::small(Platform::Axiline, Enablement::Gf12);
    let t0 = Instant::now();
    let g = datagen::generate(&cfg)?;
    println!(
        "      {} rows in {:.2}s ({} ROI)",
        g.dataset.len(),
        t0.elapsed().as_secs_f64(),
        g.dataset.rows.iter().filter(|r| r.in_roi).count()
    );

    // ---- 2. all five model families --------------------------------
    println!("[2/4] training all five model families (power metric)");
    let engine = Rc::new(Engine::load(&artifacts)?);
    let trainer = Trainer::new(Some(engine.clone()));
    let opts = TrainOptions { menu: ModelMenu::default(), ..Default::default() };
    let t0 = Instant::now();
    let report = trainer.run(&g.dataset, &g.backend_split, Metric::Power, &opts)?;
    for (model, stats) in &report.models {
        println!(
            "      {model:9} muAPE {:5.2}%  MAPE {:6.2}%",
            stats.mu_ape, stats.max_ape
        );
    }
    println!(
        "      ROI classifier acc {:.3} / F1 {:.3}; trained in {:.1}s",
        report.roi.accuracy,
        report.roi.f1,
        t0.elapsed().as_secs_f64()
    );
    let best = report
        .models
        .values()
        .map(|s| s.mu_ape)
        .fold(f64::INFINITY, f64::min);
    assert!(best < 10.0, "best model should be < 10% muAPE, got {best}");

    // ---- 3. dynamic-batching predict server -------------------------
    println!("[3/4] predict server: 8 concurrent clients");
    let server = PredictServer::start(artifacts.clone())?;
    let variant = engine.manifest.variant("ann32x4_relu")?.clone();
    let theta: Vec<f32> = glorot_init(&variant, &mut Rng::new(7)).data().to_vec();
    let feat = engine.manifest.feat;
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..8 {
            let client = server.client();
            let theta = theta.clone();
            scope.spawn(move || {
                let mut rng = Rng::new(c);
                let rows: Vec<Vec<f32>> =
                    (0..200).map(|_| (0..feat).map(|_| rng.f32()).collect()).collect();
                client.predict("ann32x4_relu", &theta, rows).expect("predict");
            });
        }
    });
    let stats = server.stats()?;
    println!(
        "      {} rows / {} batches (occupancy {:.1}/32) in {:.3}s",
        stats.rows,
        stats.batches,
        stats.mean_occupancy,
        t0.elapsed().as_secs_f64()
    );

    // the same server reached through the EvalService's batched ANN path
    let mut ann_service = EvalService::new(Enablement::Gf12, 7);
    ann_service.attach_predict_client(server.client(), "ann32x4_relu", theta.clone());
    let demo_rows: Vec<Vec<f64>> = {
        let mut rng = Rng::new(99);
        (0..64).map(|_| (0..feat).map(|_| rng.f64()).collect()).collect()
    };
    let ann_out = ann_service.predict_ann_batch(&demo_rows)?;
    println!(
        "      EvalService ANN path: {} rows in one coalesced request",
        ann_out.len()
    );

    // ---- 4. MOTPE DSE + ground truth --------------------------------
    println!("[4/4] MOTPE DSE of Axiline-SVM, 200 iterations (batches of 16)");
    let surrogate = SurrogateBundle::fit(&g.dataset, &g.backend_split, 7)?;
    let driver = DseDriver::new(Enablement::Gf12, surrogate, cfg.seed).with_workers(4);
    let mut runtimes: Vec<f64> = g.dataset.rows.iter().map(|r| r.runtime_s).collect();
    runtimes.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let problem = axiline_svm_problem(
        g.dataset.rows.iter().map(|r| r.power_w).fold(0.0, f64::max),
        runtimes[runtimes.len() / 2],
    );
    let outcome = driver.run_batched(&problem, 200, 3, MotpeConfig::default(), 16)?;
    println!("      eval service: {}", driver.stats());
    let feasible = outcome.points.iter().filter(|p| p.feasible).count();
    println!("      {feasible}/200 feasible points");
    let mut worst = 0.0f64;
    for (rank, errs) in outcome.ground_truth_errors.iter().enumerate() {
        let e_energy = errs[&Metric::Energy] * 100.0;
        let e_area = errs[&Metric::Area] * 100.0;
        println!("      top-{}: energy err {e_energy:.1}%, area err {e_area:.1}%", rank + 1);
        for m in Metric::ALL {
            worst = worst.max(errs[&m]);
        }
    }
    println!(
        "\nE2E OK in {:.1}s — worst top-3 prediction error {:.1}% (paper: <= 7%)",
        t_start.elapsed().as_secs_f64(),
        worst * 100.0
    );
    Ok(())
}
