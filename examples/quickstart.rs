//! Quickstart: generate a PPA + system-metric dataset for one platform,
//! train the two-stage model (ROI classifier + GBDT regressor), and
//! predict an unseen configuration — the framework's minimal loop.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;

use fso::backend::Enablement;
use fso::coordinator::dse_driver::SurrogateBundle;
use fso::coordinator::{datagen, DatagenConfig};
use fso::data::Metric;
use fso::generators::Platform;
use fso::metrics::mape_stats;

fn main() -> Result<()> {
    // 1. Sample architectures + backend knobs and run the SP&R oracle +
    //    system simulator over the cartesian product (paper §7.1).
    let cfg = DatagenConfig::small(Platform::Axiline, Enablement::Gf12);
    println!("generating dataset ({} architectures)...", cfg.n_arch);
    let g = datagen::generate(&cfg)?;
    println!(
        "  {} rows, {} in ROI",
        g.dataset.len(),
        g.dataset.rows.iter().filter(|r| r.in_roi).count()
    );

    // 2. Fit the two-stage surrogate (ROI classifier + per-metric GBDT).
    let surrogate = SurrogateBundle::fit(&g.dataset, &g.backend_split, 7)?;

    // 3. Evaluate on the held-out backend points (unseen-backend
    //    protocol, paper Table 4).
    let eval: Vec<usize> = g
        .backend_split
        .test
        .iter()
        .copied()
        .filter(|&i| g.dataset.rows[i].in_roi)
        .collect();
    for metric in Metric::ALL {
        let y: Vec<f64> = eval.iter().map(|&i| g.dataset.rows[i].target(metric)).collect();
        let pred: Vec<f64> = eval
            .iter()
            .map(|&i| {
                surrogate.regressors[&metric].predict_one(&g.dataset.rows[i].features_vec())
            })
            .collect();
        let stats = mape_stats(&y, &pred);
        println!(
            "{:8} muAPE {:5.2}%  MAPE {:5.2}%",
            metric.name(),
            stats.mu_ape,
            stats.max_ape
        );
    }

    // 4. Predict one new configuration end to end.
    let row = &g.dataset.rows[0];
    let (in_roi, pred) = surrogate.predict(&row.features_vec());
    println!(
        "\nsample config -> roi={in_roi} predicted power {:.3} W (truth {:.3} W)",
        pred[&Metric::Power], row.power_w
    );
    Ok(())
}
