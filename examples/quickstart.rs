//! Quickstart: generate a PPA + system-metric dataset for one platform,
//! train the two-stage model (ROI classifier + GBDT regressor), and
//! score unseen configurations through the batched `EvalService` path —
//! the framework's minimal loop.
//!
//! Run: `cargo run --release --example quickstart [-- --cache-dir DIR]`
//! With `--cache-dir`, the SP&R oracle results *and* the fitted
//! surrogate persist: a second run warm-starts from disk (watch the
//! "persistent … disk hits" stats and the "surrogate: replayed" line —
//! zero oracle runs, zero refits). `--no-model-cache` keeps only the
//! oracle half.

use std::sync::Arc;

use anyhow::Result;

use fso::backend::Enablement;
use fso::coordinator::dse_driver::SurrogateBundle;
use fso::coordinator::{datagen, CacheStore, DatagenConfig, EvalService, ModelStore};
use fso::data::Metric;
use fso::generators::Platform;
use fso::metrics::mape_stats;
use fso::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    // 1. Sample architectures + backend knobs and run the SP&R oracle +
    //    system simulator over the cartesian product (paper §7.1). The
    //    sweep fans out over the EvalService worker pool and memoizes
    //    per-design work; an optional persistent store carries the
    //    oracle cache across runs.
    let cfg = DatagenConfig::small(Platform::Axiline, Enablement::Gf12);
    println!("generating dataset ({} architectures)...", cfg.n_arch);
    let store = match args.path("cache-dir") {
        Some(dir) => Some(Arc::new(CacheStore::open(dir)?)),
        None => None,
    };
    let oracle = EvalService::new(cfg.enablement, cfg.seed)
        .with_workers(cfg.workers)
        .with_cache_store_opt(store.clone());
    let g = datagen::generate_with(&oracle, &cfg)?;
    if let Some(store) = &store {
        store.flush()?;
        println!("  cache store: {}", store.stats());
        // housekeeping for long-lived stores: reclaim tombstones and
        // dead lines (a no-op on a healthy store; reads are unchanged
        // either way — see `fso store compact`)
        println!("  compacted:   {}", store.compact()?);
    }
    println!(
        "  {} rows, {} in ROI",
        g.dataset.len(),
        g.dataset.rows.iter().filter(|r| r.in_roi).count()
    );
    println!("  datagen eval service: {}", g.stats);

    // 2. Fit the two-stage surrogate (ROI classifier + per-metric GBDT)
    //    and attach it to a service for batched scoring. With a cache
    //    dir, the fitted bundle reads through the model store: a warm
    //    run loads the artifact instead of refitting.
    let mstore = match args.path("cache-dir") {
        Some(dir) if !args.flag("no-model-cache") => {
            Some(Arc::new(ModelStore::open_under(dir)?))
        }
        _ => None,
    };
    let (surrogate, replayed) =
        SurrogateBundle::fit_cached(&g.dataset, &g.backend_split, 7, mstore.as_deref())?;
    println!(
        "  surrogate: {}",
        if replayed { "replayed from model store (0 refits)" } else { "fitted fresh" }
    );
    if let Some(ms) = &mstore {
        ms.flush()?;
        println!("  model store: {}", ms.stats());
    }
    let service = EvalService::new(cfg.enablement, cfg.seed)
        .with_surrogate(surrogate)
        .with_workers(2);

    // 3. Evaluate on the held-out backend points (unseen-backend
    //    protocol, paper Table 4) — one batched pass instead of
    //    per-row predict_one calls.
    let eval: Vec<usize> = g
        .backend_split
        .test
        .iter()
        .copied()
        .filter(|&i| g.dataset.rows[i].in_roi)
        .collect();
    let feats: Vec<Vec<f64>> =
        eval.iter().map(|&i| g.dataset.rows[i].features_vec()).collect();
    let scored = service.predict_batch(&feats)?;
    for metric in Metric::ALL {
        let y: Vec<f64> = eval.iter().map(|&i| g.dataset.rows[i].target(metric)).collect();
        let pred: Vec<f64> = scored.iter().map(|p| p.predicted[&metric]).collect();
        let stats = mape_stats(&y, &pred);
        println!(
            "{:8} muAPE {:5.2}%  MAPE {:5.2}%",
            metric.name(),
            stats.mu_ape,
            stats.max_ape
        );
    }

    // 4. Predict one new configuration end to end.
    let row = &g.dataset.rows[0];
    let one = service.predict_batch(&[row.features_vec()])?;
    println!(
        "\nsample config -> roi={} predicted power {:.3} W (truth {:.3} W)",
        one[0].in_roi,
        one[0].predicted[&Metric::Power],
        row.power_w
    );
    println!("surrogate service: {}", service.stats());
    Ok(())
}
