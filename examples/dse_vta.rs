//! Paper §8.4 / Fig. 12: backend-knob DSE (f_target, util) of a fixed
//! VTA design on GF12 with alpha=beta=1; top-3 winners checked against
//! post-SP&R ground truth.
//!
//! Run: `cargo run --release --example dse_vta [-- --quick] [-- --cache-dir DIR]`
//! With `--cache-dir`, the SP&R oracle results persist between runs —
//! a second invocation warm-starts from disk and reports the hits.

use fso::coordinator::experiments::{dse, ExpOptions};
use fso::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let opts = ExpOptions {
        quick: args.flag("quick"),
        cache_dir: args.path("cache-dir"),
        ..Default::default()
    };
    opts.ensure_out_dir()?;
    dse::fig12_vta(&opts)
}
