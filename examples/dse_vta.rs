//! Paper §8.4 / Fig. 12: backend-knob DSE (f_target, util) of a fixed
//! VTA design on GF12 with alpha=beta=1; top-3 winners checked against
//! post-SP&R ground truth.
//!
//! Run: `cargo run --release --example dse_vta [-- --quick]`

use fso::coordinator::experiments::{dse, ExpOptions};

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let opts = ExpOptions { quick, ..Default::default() };
    opts.ensure_out_dir()?;
    dse::fig12_vta(&opts)
}
