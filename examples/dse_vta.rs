//! Paper §8.4 / Fig. 12: backend-knob DSE (f_target, util) of a fixed
//! VTA design on GF12 with alpha=beta=1; top-3 winners checked against
//! post-SP&R ground truth.
//!
//! Run: `cargo run --release --example dse_vta [-- --quick] [-- --cache-dir DIR]`
//! With `--cache-dir`, the SP&R oracle results *and* the fitted
//! surrogate bundle persist between runs — a second invocation
//! warm-starts from disk (0 oracle runs, 0 surrogate refits) and
//! replays a byte-identical Pareto front. `--no-model-cache` keeps
//! only the oracle half.

use fso::coordinator::experiments::{dse, ExpOptions};
use fso::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let opts = ExpOptions {
        quick: args.flag("quick"),
        cache_dir: args.path("cache-dir"),
        no_model_cache: args.flag("no-model-cache"),
        coalesce: args.flag("coalesce"),
        inflight: args.usize_or("inflight", 4)?,
        ..Default::default()
    };
    opts.ensure_out_dir()?;
    dse::fig12_vta(&opts)
}
