"""L2: JAX compute graphs for the paper's learned predictors (ANN + GCN),
built on the L1 Pallas kernels, with Adam and the muAPE loss (paper Eq. 7).

Everything here is build-time: `aot.py` lowers `predict` / `embed` /
`train_step` closures once to HLO text; the rust coordinator owns the
training loop, batching, early stopping, LR decay and hyperparameter
search (paper §7.3), and only ever calls the compiled artifacts.

Fixed AOT shapes (see DESIGN.md §3): B=32 rows per batch, F=16 unified
architectural+backend features, N=128 LHG nodes, NF=9 node features
(Fig. 5c features + fold multiplicity).
"""

from dataclasses import dataclass, field
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import dense, gcn_conv, graph_conv, masked_mean_pool

# ---------------------------------------------------------------------------
# Fixed interchange dimensions (must match rust/src/runtime/artifacts.rs).
# ---------------------------------------------------------------------------
BATCH = 32  # rows per predict/train call (L3 pads to this)
FEAT = 16  # unified arch+backend feature vector length
NODES = 128  # max LHG nodes (generators fold to stay under this)
NODE_FEAT = 9  # Fig. 5c structural features + multiplicity

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
APE_EPS = 1e-6


# ---------------------------------------------------------------------------
# Algorithm 2 (paper): hidden layer configuration generator.
# ---------------------------------------------------------------------------
def get_node_config(node_count: int, h_layer_count: int, min_p: int = 2, max_p: int = 7) -> List[int]:
    """Paper Algorithm 2: power-of-two hidden layer sizes that rise to an
    expected maximum then decay. Mirrored bit-for-bit by
    rust/src/models/tuning.rs (tested for equality on the full Table 2 grid).
    """
    p = (node_count - 1).bit_length()  # ceil(log2(node_count))
    exp_max_p = min((h_layer_count + min_p + p) // 2, max_p)
    if exp_max_p <= p:
        exp_max_p = p + 1
    incr_p = exp_max_p - p
    decr_p = min(exp_max_p - min_p + 1, h_layer_count - incr_p)
    same_p = 0
    if h_layer_count > incr_p + decr_p:
        same_p = h_layer_count - incr_p - decr_p
    layer = []
    q = p
    for _ in range(incr_p):
        layer.append(2**q)
        q += 1
    for _ in range(same_p):
        layer.append(2**q)
    for _ in range(decr_p):
        layer.append(2**q)
        q -= 1
    return layer


# ---------------------------------------------------------------------------
# Flat parameter layout: rust holds ONE theta vector (plus Adam m, v).
# ---------------------------------------------------------------------------
@dataclass
class ParamLayout:
    entries: List[Tuple[str, int, Tuple[int, ...]]] = field(default_factory=list)
    total: int = 0

    def add(self, name: str, shape: Tuple[int, ...]) -> None:
        size = 1
        for d in shape:
            size *= d
        self.entries.append((name, self.total, shape))
        self.total += size

    def slices(self, theta):
        out = {}
        for name, off, shape in self.entries:
            size = 1
            for d in shape:
                size *= d
            out[name] = jax.lax.dynamic_slice(theta, (off,), (size,)).reshape(shape)
        return out

    def to_json(self):
        return {
            "total": self.total,
            "entries": [
                {"name": n, "offset": o, "shape": list(s)} for n, o, s in self.entries
            ],
        }


def glorot_init(key, layout: ParamLayout) -> jnp.ndarray:
    """Glorot-uniform init of the flat parameter vector (fixtures/tests;
    rust re-implements the same scheme with its own RNG)."""
    theta = jnp.zeros((layout.total,), jnp.float32)
    for name, off, shape in layout.entries:
        key, sub = jax.random.split(key)
        if len(shape) == 2:
            limit = (6.0 / (shape[0] + shape[1])) ** 0.5
            vals = jax.random.uniform(sub, shape, jnp.float32, -limit, limit)
        else:
            vals = jnp.zeros(shape, jnp.float32)
        theta = jax.lax.dynamic_update_slice(theta, vals.reshape(-1), (off,))
    return theta


# ---------------------------------------------------------------------------
# ANN (paper §5.3 / §7.3): MLP with Algorithm-2 hidden configuration.
# ---------------------------------------------------------------------------
@dataclass
class AnnConfig:
    name: str
    hidden: List[int]
    act: str = "relu"
    in_dim: int = FEAT

    def layout(self) -> ParamLayout:
        lay = ParamLayout()
        dims = [self.in_dim] + list(self.hidden) + [1]
        for i in range(len(dims) - 1):
            lay.add(f"w{i}", (dims[i], dims[i + 1]))
            lay.add(f"b{i}", (dims[i + 1],))
        return lay


def ann_apply(cfg: AnnConfig, layout: ParamLayout, theta, x):
    """x: [B, F] -> prediction [B]."""
    p = layout.slices(theta)
    h = x
    n_hidden = len(cfg.hidden)
    for i in range(n_hidden):
        h = dense(h, p[f"w{i}"], p[f"b{i}"], cfg.act)
    out = dense(h, p[f"w{n_hidden}"], p[f"b{n_hidden}"], "linear")
    return out[:, 0]


# ---------------------------------------------------------------------------
# GCN (paper Fig. 7): conv stack -> GlobalMeanPool -> concat(global feats)
# -> FC stack (Algorithm 2) -> scalar.
# ---------------------------------------------------------------------------
@dataclass
class GcnConfig:
    name: str
    conv_dims: List[int]
    fc_hidden: List[int]
    conv_kind: str = "gcn"  # "gcn" (GCNConv) | "graph" (GraphConv)
    act: str = "relu"
    node_feat: int = NODE_FEAT
    gfeat_dim: int = FEAT

    def layout(self) -> ParamLayout:
        lay = ParamLayout()
        d = self.node_feat
        for i, g in enumerate(self.conv_dims):
            if self.conv_kind == "gcn":
                lay.add(f"cw{i}", (d, g))
            else:
                lay.add(f"cws{i}", (d, g))
                lay.add(f"cwn{i}", (d, g))
            lay.add(f"cb{i}", (g,))
            d = g
        dims = [d + self.gfeat_dim] + list(self.fc_hidden) + [1]
        for i in range(len(dims) - 1):
            lay.add(f"fw{i}", (dims[i], dims[i + 1]))
            lay.add(f"fb{i}", (dims[i + 1],))
        return lay

    @property
    def embed_dim(self) -> int:
        return self.conv_dims[-1]


def gcn_embed(cfg: GcnConfig, layout: ParamLayout, theta, nodes, adj, mask):
    """Conv stack + masked mean pool -> graph embedding [B, E] (Fig. 8)."""
    p = layout.slices(theta)
    h = nodes
    for i in range(len(cfg.conv_dims)):
        if cfg.conv_kind == "gcn":
            h = gcn_conv(h, adj, p[f"cw{i}"], p[f"cb{i}"], cfg.act)
        else:
            h = graph_conv(h, adj, p[f"cws{i}"], p[f"cwn{i}"], p[f"cb{i}"], cfg.act)
    return masked_mean_pool(h, mask)


def gcn_apply(cfg: GcnConfig, layout: ParamLayout, theta, nodes, adj, mask, gfeat):
    """Full GCN predictor: [B] prediction."""
    emb = gcn_embed(cfg, layout, theta, nodes, adj, mask)
    p = layout.slices(theta)
    h = jnp.concatenate([emb, gfeat], axis=1)
    n_hidden = len(cfg.fc_hidden)
    for i in range(n_hidden):
        h = dense(h, p[f"fw{i}"], p[f"fb{i}"], "relu")
    out = dense(h, p[f"fw{n_hidden}"], p[f"fb{n_hidden}"], "linear")
    return out[:, 0]


# ---------------------------------------------------------------------------
# muAPE loss (paper Eq. 7) with per-row weights (padding rows get w=0).
# ---------------------------------------------------------------------------
def mape_loss(pred, y, w):
    ape = jnp.abs(pred - y) / (jnp.abs(y) + APE_EPS)
    return jnp.sum(w * ape) / jnp.maximum(jnp.sum(w), 1.0)


# ---------------------------------------------------------------------------
# Adam (paper §7.3: Adam + decaying LR; the decay/patience logic lives in
# the rust trainer, which passes `lr` per call).
# ---------------------------------------------------------------------------
def adam_update(theta, m, v, grad, t, lr):
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * grad
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * grad * grad
    mhat = m / (1.0 - jnp.power(ADAM_B1, t))
    vhat = v / (1.0 - jnp.power(ADAM_B2, t))
    theta = theta - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return theta, m, v


# ---------------------------------------------------------------------------
# Jit-able closures for AOT lowering.
# ---------------------------------------------------------------------------
def make_ann_fns(cfg: AnnConfig):
    layout = cfg.layout()

    def predict(theta, x):
        return (ann_apply(cfg, layout, theta, x),)

    def train_step(theta, m, v, t, lr, x, y, w):
        def loss_fn(th):
            return mape_loss(ann_apply(cfg, layout, th, x), y, w)

        loss, grad = jax.value_and_grad(loss_fn)(theta)
        theta2, m2, v2 = adam_update(theta, m, v, grad, t, lr)
        return theta2, m2, v2, loss

    def train_epoch(theta, m, v, t, lr, xs, ys, ws):
        """S minibatches per PJRT call (perf: amortizes the FFI boundary)."""

        def body(carry, batch):
            th, mm, vv, tt = carry
            x, y, w = batch
            th, mm, vv, loss = train_step(th, mm, vv, tt, lr, x, y, w)
            return (th, mm, vv, tt + 1.0), loss

        (theta2, m2, v2, _), losses = jax.lax.scan(
            body, (theta, m, v, t), (xs, ys, ws)
        )
        return theta2, m2, v2, jnp.mean(losses)

    return layout, predict, train_step, train_epoch


def make_gcn_fns(cfg: GcnConfig):
    layout = cfg.layout()

    def predict(theta, nodes, adj, mask, gfeat):
        return (gcn_apply(cfg, layout, theta, nodes, adj, mask, gfeat),)

    def embed(theta, nodes, adj, mask):
        return (gcn_embed(cfg, layout, theta, nodes, adj, mask),)

    def train_step(theta, m, v, t, lr, nodes, adj, mask, gfeat, y, w):
        def loss_fn(th):
            return mape_loss(gcn_apply(cfg, layout, th, nodes, adj, mask, gfeat), y, w)

        loss, grad = jax.value_and_grad(loss_fn)(theta)
        theta2, m2, v2 = adam_update(theta, m, v, grad, t, lr)
        return theta2, m2, v2, loss

    return layout, predict, embed, train_step


# ---------------------------------------------------------------------------
# The variant menu the rust hyperparameter search draws from (Table 2,
# reduced to a discrete grid that is AOT-compiled once).
# ---------------------------------------------------------------------------
def ann_variants() -> List[AnnConfig]:
    return [
        AnnConfig("ann32x4_relu", get_node_config(32, 4), "relu"),
        AnnConfig("ann32x4_tanh", get_node_config(32, 4), "tanh"),
        AnnConfig("ann16x3_relu", get_node_config(16, 3), "relu"),
        AnnConfig("ann64x5_tanh", get_node_config(64, 5), "tanh"),
    ]


def gcn_variants() -> List[GcnConfig]:
    return [
        GcnConfig("gcn3", [16, 16, 16], get_node_config(16, 3), "gcn"),
        GcnConfig("gcn2", [16, 16], get_node_config(16, 2), "gcn"),
        GcnConfig("graph2", [16, 16], get_node_config(16, 3), "graph"),
    ]
