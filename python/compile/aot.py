"""AOT pipeline: lower every predictor graph to HLO *text* + manifest.

This is the only place python touches the system. `make artifacts` runs it
once; the rust coordinator then loads `artifacts/*.hlo.txt` through the
PJRT C API and python never appears on the train/predict path again.

Interchange format is HLO text, NOT `lowered.compile()`/`.serialize()`:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Outputs under --out-dir:
  <variant>_<kind>.hlo.txt   one per (model variant, entrypoint)
  manifest.json              shapes, argument order, flat param layouts
  fixtures/*.npy             golden inputs/outputs for the rust round-trip
                             integration tests
"""

import argparse
import json
import pathlib

import numpy as np

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

MANIFEST_VERSION = 3


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def sds(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def shapes_of(args):
    return [list(a.shape) for a in args]


def lower_and_write(fn, args, path: pathlib.Path) -> int:
    text = to_hlo_text(jax.jit(fn).lower(*args))
    path.write_text(text)
    return len(text)


EPOCH_STEPS = 8  # minibatches folded into one ann train_epoch call


def build_ann(out: pathlib.Path, cfg) -> dict:
    layout, predict, train_step, train_epoch = M.make_ann_fns(cfg)
    P = layout.total
    theta, m, v = sds(P), sds(P), sds(P)
    t, lr = sds(), sds()
    x, y, w = sds(M.BATCH, M.FEAT), sds(M.BATCH), sds(M.BATCH)
    xs = sds(EPOCH_STEPS, M.BATCH, M.FEAT)
    ys = sds(EPOCH_STEPS, M.BATCH)
    ws = sds(EPOCH_STEPS, M.BATCH)

    files = {}
    n = lower_and_write(predict, (theta, x), out / f"{cfg.name}_predict.hlo.txt")
    files["predict"] = {
        "file": f"{cfg.name}_predict.hlo.txt",
        "inputs": shapes_of((theta, x)),
        "outputs": [[M.BATCH]],
        "bytes": n,
    }
    args = (theta, m, v, t, lr, x, y, w)
    n = lower_and_write(train_step, args, out / f"{cfg.name}_train_step.hlo.txt")
    files["train_step"] = {
        "file": f"{cfg.name}_train_step.hlo.txt",
        "inputs": shapes_of(args),
        "outputs": [[P], [P], [P], []],
        "bytes": n,
    }
    args = (theta, m, v, t, lr, xs, ys, ws)
    n = lower_and_write(train_epoch, args, out / f"{cfg.name}_train_epoch.hlo.txt")
    files["train_epoch"] = {
        "file": f"{cfg.name}_train_epoch.hlo.txt",
        "inputs": shapes_of(args),
        "outputs": [[P], [P], [P], []],
        "bytes": n,
        "steps_per_call": EPOCH_STEPS,
    }
    return {
        "kind": "ann",
        "hidden": cfg.hidden,
        "act": cfg.act,
        "params": layout.to_json(),
        "entrypoints": files,
    }


def build_gcn(out: pathlib.Path, cfg) -> dict:
    layout, predict, embed, train_step = M.make_gcn_fns(cfg)
    P = layout.total
    theta, m, v = sds(P), sds(P), sds(P)
    t, lr = sds(), sds()
    nodes = sds(M.BATCH, M.NODES, M.NODE_FEAT)
    adj = sds(M.BATCH, M.NODES, M.NODES)
    mask = sds(M.BATCH, M.NODES)
    gfeat = sds(M.BATCH, M.FEAT)
    y, w = sds(M.BATCH), sds(M.BATCH)

    files = {}
    args = (theta, nodes, adj, mask, gfeat)
    n = lower_and_write(predict, args, out / f"{cfg.name}_predict.hlo.txt")
    files["predict"] = {
        "file": f"{cfg.name}_predict.hlo.txt",
        "inputs": shapes_of(args),
        "outputs": [[M.BATCH]],
        "bytes": n,
    }
    args = (theta, nodes, adj, mask)
    n = lower_and_write(embed, args, out / f"{cfg.name}_embed.hlo.txt")
    files["embed"] = {
        "file": f"{cfg.name}_embed.hlo.txt",
        "inputs": shapes_of(args),
        "outputs": [[M.BATCH, cfg.embed_dim]],
        "bytes": n,
    }
    args = (theta, m, v, t, lr, nodes, adj, mask, gfeat, y, w)
    n = lower_and_write(train_step, args, out / f"{cfg.name}_train_step.hlo.txt")
    files["train_step"] = {
        "file": f"{cfg.name}_train_step.hlo.txt",
        "inputs": shapes_of(args),
        "outputs": [[P], [P], [P], []],
        "bytes": n,
    }
    return {
        "kind": "gcn",
        "conv_kind": cfg.conv_kind,
        "conv_dims": cfg.conv_dims,
        "fc_hidden": cfg.fc_hidden,
        "embed_dim": cfg.embed_dim,
        "params": layout.to_json(),
        "entrypoints": files,
    }


def write_fixtures(out: pathlib.Path) -> None:
    """Golden input/output tensors for the rust round-trip tests."""
    fx = out / "fixtures"
    fx.mkdir(parents=True, exist_ok=True)

    def save(name, arr):
        np.save(fx / f"{name}.npy", np.asarray(arr, dtype=np.float32))

    # --- ANN fixture (default variant) -------------------------------
    cfg = M.ann_variants()[0]
    layout, predict, train_step, _ = M.make_ann_fns(cfg)
    key = jax.random.PRNGKey(42)
    theta = M.glorot_init(key, layout)
    x = jax.random.normal(jax.random.PRNGKey(7), (M.BATCH, M.FEAT))
    y = jnp.abs(jax.random.normal(jax.random.PRNGKey(8), (M.BATCH,))) + 0.5
    w = jnp.ones((M.BATCH,))
    m = jnp.zeros_like(theta)
    v = jnp.zeros_like(theta)
    pred = predict(theta, x)[0]
    th2, m2, v2, loss = train_step(
        theta, m, v, jnp.float32(1.0), jnp.float32(1e-3), x, y, w
    )
    save("ann_theta", theta)
    save("ann_x", x)
    save("ann_y", y)
    save("ann_w", w)
    save("ann_pred", pred)
    save("ann_theta2", th2)
    save("ann_m2", m2)
    save("ann_v2", v2)
    save("ann_loss", jnp.reshape(loss, (1,)))

    # --- GCN fixture (default variant) -------------------------------
    gcfg = M.gcn_variants()[0]
    glayout, gpredict, gembed, gtrain = M.make_gcn_fns(gcfg)
    gtheta = M.glorot_init(jax.random.PRNGKey(43), glayout)
    nodes = jax.random.normal(jax.random.PRNGKey(9), (M.BATCH, M.NODES, M.NODE_FEAT))
    # A plausible normalized adjacency: identity + a ring, row-normalized.
    eye = jnp.eye(M.NODES)
    ring = jnp.roll(eye, 1, axis=1) + jnp.roll(eye, -1, axis=1)
    adj_1 = (eye + ring) / 3.0
    adj = jnp.broadcast_to(adj_1, (M.BATCH, M.NODES, M.NODES))
    mask = jnp.ones((M.BATCH, M.NODES))
    gfeat = jax.random.normal(jax.random.PRNGKey(10), (M.BATCH, M.FEAT))
    gpred = gpredict(gtheta, nodes, adj, mask, gfeat)[0]
    gemb = gembed(gtheta, nodes, adj, mask)[0]
    gth2, gm2, gv2, gloss = gtrain(
        gtheta,
        jnp.zeros_like(gtheta),
        jnp.zeros_like(gtheta),
        jnp.float32(1.0),
        jnp.float32(1e-3),
        nodes,
        adj,
        mask,
        gfeat,
        jnp.abs(gfeat[:, 0]) + 0.5,
        jnp.ones((M.BATCH,)),
    )
    save("gcn_theta", gtheta)
    save("gcn_nodes", nodes)
    save("gcn_adj", adj)
    save("gcn_mask", mask)
    save("gcn_gfeat", gfeat)
    save("gcn_y", jnp.abs(gfeat[:, 0]) + 0.5)
    save("gcn_pred", gpred)
    save("gcn_emb", gemb)
    save("gcn_theta2", gth2)
    save("gcn_loss", jnp.reshape(gloss, (1,)))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--skip-fixtures", action="store_true")
    args = ap.parse_args()
    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)

    manifest = {
        "version": MANIFEST_VERSION,
        "batch": M.BATCH,
        "feat": M.FEAT,
        "nodes": M.NODES,
        "node_feat": M.NODE_FEAT,
        "epoch_steps": EPOCH_STEPS,
        "adam": {"b1": M.ADAM_B1, "b2": M.ADAM_B2, "eps": M.ADAM_EPS},
        "variants": {},
    }
    for cfg in M.ann_variants():
        print(f"[aot] lowering ANN variant {cfg.name} (hidden={cfg.hidden})")
        manifest["variants"][cfg.name] = build_ann(out, cfg)
    for cfg in M.gcn_variants():
        print(f"[aot] lowering GCN variant {cfg.name} ({cfg.conv_kind} x{len(cfg.conv_dims)})")
        manifest["variants"][cfg.name] = build_gcn(out, cfg)

    if not args.skip_fixtures:
        print("[aot] writing golden fixtures")
        write_fixtures(out)

    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    total = sum(
        ep["bytes"]
        for var in manifest["variants"].values()
        for ep in var["entrypoints"].values()
    )
    print(f"[aot] wrote {len(manifest['variants'])} variants, {total/1e6:.1f} MB HLO text -> {out}")


if __name__ == "__main__":
    main()
