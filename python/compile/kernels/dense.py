"""Fused dense layer as a Pallas kernel, with a custom VJP whose backward
pass is itself built from Pallas matmuls.

`pallas_call` has no automatic differentiation rule, so the ANN/GCN
`train_step` graphs (L2) differentiate through these layers via the
`jax.custom_vjp` below: forward saves the pre-activation, backward
re-expresses the three gradients as tiled matmuls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .matmul import INTERPRET, matmul


def _dense_kernel(act, x_ref, w_ref, b_ref, z_ref, h_ref):
    z = (
        jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...]
    )
    z_ref[...] = z
    h_ref[...] = ref.apply_act(z, act)


@functools.partial(jax.jit, static_argnames=("act",))
def _dense_fwd_kernel(x, w, b, act):
    """Returns (h, z): activated output and saved pre-activation."""
    m, k = x.shape
    n = w.shape[1]
    out_shapes = (
        jax.ShapeDtypeStruct((m, n), jnp.float32),  # z
        jax.ShapeDtypeStruct((m, n), jnp.float32),  # h
    )
    z, h = pl.pallas_call(
        functools.partial(_dense_kernel, act),
        out_shape=out_shapes,
        interpret=INTERPRET,
    )(x, w, b)
    return h, z


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x, w, b, act="relu"):
    """act(x @ w + b), x:[M,K] w:[K,N] b:[N]."""
    h, _ = _dense_fwd_kernel(x, w, b, act)
    return h


def _dense_vjp_fwd(x, w, b, act):
    h, z = _dense_fwd_kernel(x, w, b, act)
    return h, (x, w, z)


def _dense_vjp_bwd(act, res, g):
    x, w, z = res
    dz = g * ref.act_grad(z, act)
    dx = matmul(dz, w.T)
    dw = matmul(x.T, dz)
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


dense.defvjp(_dense_vjp_fwd, _dense_vjp_bwd)
