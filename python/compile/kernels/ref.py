"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness spec).

Every Pallas kernel in this package has an exact pure-`jax.numpy`
counterpart here. pytest/hypothesis sweep shapes and dtypes and
`assert_allclose` kernel-vs-ref; the AOT artifacts are only built after
these oracles pass.
"""

import jax.numpy as jnp


def matmul_ref(a, b):
    """Plain matrix product, f32 accumulation."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def dense_ref(x, w, b, act="relu"):
    """Fused dense layer: act(x @ w + b)."""
    z = jnp.matmul(x, w, preferred_element_type=jnp.float32) + b
    return apply_act(z, act)


def apply_act(z, act):
    if act == "relu":
        return jnp.maximum(z, 0.0)
    if act == "tanh":
        return jnp.tanh(z)
    if act == "linear":
        return z
    raise ValueError(f"unknown activation {act!r}")


def act_grad(z, act):
    """d act(z) / dz evaluated at pre-activation z."""
    if act == "relu":
        return (z > 0.0).astype(z.dtype)
    if act == "tanh":
        t = jnp.tanh(z)
        return 1.0 - t * t
    if act == "linear":
        return jnp.ones_like(z)
    raise ValueError(f"unknown activation {act!r}")


def gcn_conv_ref(nodes, adj, w, b, act="relu"):
    """GCNConv layer on a batch of dense graphs.

    nodes: [B, N, F]   node features
    adj:   [B, N, N]   normalized adjacency (D^-1/2 (A+I) D^-1/2), rows of
                       padded nodes are all-zero
    w:     [F, F']     weight
    b:     [F']        bias
    returns [B, N, F'] = act(adj @ (nodes @ w) + b)
    """
    xw = jnp.einsum("bnf,fg->bng", nodes, w)
    axw = jnp.einsum("bnm,bmg->bng", adj, xw)
    return apply_act(axw + b, act)


def graph_conv_ref(nodes, adj, w_self, w_nbr, b, act="relu"):
    """GraphConv layer (separate self/neighbour weights):

    act(nodes @ w_self + adj @ nodes @ w_nbr + b)
    """
    self_term = jnp.einsum("bnf,fg->bng", nodes, w_self)
    nbr = jnp.einsum("bnm,bmf->bnf", adj, nodes)
    nbr_term = jnp.einsum("bnf,fg->bng", nbr, w_nbr)
    return apply_act(self_term + nbr_term + b, act)


def masked_mean_pool_ref(h, mask):
    """GlobalMeanPool over valid nodes only.

    h:    [B, N, F]
    mask: [B, N]  1.0 for real nodes, 0.0 for padding
    returns [B, F]
    """
    s = jnp.einsum("bnf,bn->bf", h, mask)
    cnt = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    return s / cnt
