# L1: Pallas kernels for the predictor stack's compute hot-spots.
from .dense import dense
from .gcn_conv import gcn_conv, graph_conv
from .matmul import batched_matmul, matmul
from .pooling import masked_mean_pool

__all__ = [
    "dense",
    "gcn_conv",
    "graph_conv",
    "matmul",
    "batched_matmul",
    "masked_mean_pool",
]
