"""Graph convolution layers (GCNConv / GraphConv) as Pallas kernels over
batched dense graphs, with custom VJPs built on Pallas matmuls.

The logical hierarchy graphs (LHGs, paper §6) are tiny — a few hundred
nodes, |E| = |V|-1 — so the adjacency is kept dense ([B, N, N]) and each
grid program owns one whole graph: the fused chain
    act( adj @ (nodes @ w) + b )
is a single VMEM-resident block per graph (N<=128, F<=32 here; <=256 KiB
of operands — see DESIGN.md §9 for the MXU/VMEM projection).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .matmul import INTERPRET, batched_matmul


def _gcn_kernel(act, x_ref, a_ref, w_ref, b_ref, z_ref, h_ref):
    xw = jnp.dot(x_ref[0], w_ref[...], preferred_element_type=jnp.float32)
    z = jnp.dot(a_ref[0], xw, preferred_element_type=jnp.float32) + b_ref[...]
    z_ref[0] = z
    h_ref[0] = ref.apply_act(z, act)


@functools.partial(jax.jit, static_argnames=("act",))
def _gcn_fwd_kernel(nodes, adj, w, b, act):
    bsz, n, f = nodes.shape
    g = w.shape[1]
    out_shapes = (
        jax.ShapeDtypeStruct((bsz, n, g), jnp.float32),  # z
        jax.ShapeDtypeStruct((bsz, n, g), jnp.float32),  # h
    )
    z, h = pl.pallas_call(
        functools.partial(_gcn_kernel, act),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, n, f), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((f, g), lambda i: (0, 0)),
            pl.BlockSpec((g,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((1, n, g), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, g), lambda i: (i, 0, 0)),
        ),
        out_shape=out_shapes,
        interpret=INTERPRET,
    )(nodes, adj, w, b)
    return h, z


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def gcn_conv(nodes, adj, w, b, act="relu"):
    """GCNConv: act(adj @ (nodes @ w) + b).

    nodes: [B,N,F], adj: [B,N,N] (normalized, symmetric), w: [F,G], b: [G].
    adj is treated as a constant of the graph (no gradient).
    """
    h, _ = _gcn_fwd_kernel(nodes, adj, w, b, act)
    return h


def _gcn_vjp_fwd(nodes, adj, w, b, act):
    h, z = _gcn_fwd_kernel(nodes, adj, w, b, act)
    return h, (nodes, adj, w, z)


def _gcn_vjp_bwd(act, res, g_out):
    nodes, adj, w, z = res
    dz = g_out * ref.act_grad(z, act)  # [B,N,G]
    # z = A @ X @ W + b; A symmetric (normalized undirected adjacency).
    at_dz = batched_matmul(adj, dz)  # A^T @ dz == A @ dz
    # dW = sum_b X_b^T @ (A_b^T dz_b)
    dw = jnp.einsum("bnf,bng->fg", nodes, at_dz)
    dx = batched_matmul(at_dz, jnp.broadcast_to(w.T, (nodes.shape[0],) + w.T.shape))
    db = jnp.sum(dz, axis=(0, 1))
    return dx, None, dw, db


gcn_conv.defvjp(_gcn_vjp_fwd, _gcn_vjp_bwd)


def _graph_kernel(act, x_ref, a_ref, ws_ref, wn_ref, b_ref, z_ref, h_ref):
    x = x_ref[0]
    self_term = jnp.dot(x, ws_ref[...], preferred_element_type=jnp.float32)
    ax = jnp.dot(a_ref[0], x, preferred_element_type=jnp.float32)
    nbr_term = jnp.dot(ax, wn_ref[...], preferred_element_type=jnp.float32)
    z = self_term + nbr_term + b_ref[...]
    z_ref[0] = z
    h_ref[0] = ref.apply_act(z, act)


@functools.partial(jax.jit, static_argnames=("act",))
def _graph_fwd_kernel(nodes, adj, w_self, w_nbr, b, act):
    bsz, n, f = nodes.shape
    g = w_self.shape[1]
    out_shapes = (
        jax.ShapeDtypeStruct((bsz, n, g), jnp.float32),
        jax.ShapeDtypeStruct((bsz, n, g), jnp.float32),
    )
    z, h = pl.pallas_call(
        functools.partial(_graph_kernel, act),
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, n, f), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((f, g), lambda i: (0, 0)),
            pl.BlockSpec((f, g), lambda i: (0, 0)),
            pl.BlockSpec((g,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((1, n, g), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, g), lambda i: (i, 0, 0)),
        ),
        out_shape=out_shapes,
        interpret=INTERPRET,
    )(nodes, adj, w_self, w_nbr, b)
    return h, z


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def graph_conv(nodes, adj, w_self, w_nbr, b, act="relu"):
    """GraphConv: act(nodes @ w_self + (adj @ nodes) @ w_nbr + b)."""
    h, _ = _graph_fwd_kernel(nodes, adj, w_self, w_nbr, b, act)
    return h


def _graph_vjp_fwd(nodes, adj, w_self, w_nbr, b, act):
    h, z = _graph_fwd_kernel(nodes, adj, w_self, w_nbr, b, act)
    return h, (nodes, adj, w_self, w_nbr, z)


def _graph_vjp_bwd(act, res, g_out):
    nodes, adj, w_self, w_nbr, z = res
    bsz = nodes.shape[0]
    dz = g_out * ref.act_grad(z, act)  # [B,N,G]
    ax = batched_matmul(adj, nodes)  # recompute A@X (cheap, saves memory)
    dw_self = jnp.einsum("bnf,bng->fg", nodes, dz)
    dw_nbr = jnp.einsum("bnf,bng->fg", ax, dz)
    dz_wnT = batched_matmul(dz, jnp.broadcast_to(w_nbr.T, (bsz,) + w_nbr.T.shape))
    dx = batched_matmul(dz, jnp.broadcast_to(w_self.T, (bsz,) + w_self.T.shape))
    dx = dx + batched_matmul(adj, dz_wnT)  # A^T == A
    db = jnp.sum(dz, axis=(0, 1))
    return dx, None, dw_self, dw_nbr, db


graph_conv.defvjp(_graph_vjp_fwd, _graph_vjp_bwd)
