"""Tiled Pallas matmul — the primitive every other kernel's backward pass
builds on.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's compute
is ASIC PPA modelling, not GPU kernels; the hot loop we kernelize is the
predictor stack (GCN/ANN train + batched inference). On a real TPU the
tiles below are MXU-shaped (multiples of 8x128 lanes); operands at our
model sizes (<=256x256 f32) are single-block VMEM-resident so the
HBM<->VMEM schedule is trivial (one fetch, no re-streaming). We run
`interpret=True` everywhere: CPU PJRT cannot execute Mosaic custom-calls,
and interpret-mode lowers to plain HLO the rust client runs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _pick_tile(dim: int, preferred: int) -> int:
    """Largest divisor of `dim` that is <= preferred (>=1)."""
    t = min(dim, preferred)
    while dim % t != 0:
        t -= 1
    return t


def _mm_kernel(a_ref, b_ref, o_ref):
    # One (TM, TN) output tile; K is kept whole in-block: at our model sizes
    # (K <= 256) the operands fit VMEM, so no K-loop / accumulator needed.
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("tm", "tn"))
def matmul(a, b, tm: int = 128, tn: int = 128):
    """a[M,K] @ b[K,N] -> [M,N] with a grid of (M/TM, N/TN) tile programs."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {a.shape} @ {b.shape}"
    tm = _pick_tile(m, tm)
    tn = _pick_tile(n, tn)
    grid = (m // tm, n // tn)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(a, b)


def _bmm_kernel(a_ref, b_ref, o_ref):
    o_ref[0] = jnp.dot(
        a_ref[0], b_ref[0], preferred_element_type=jnp.float32
    )


@jax.jit
def batched_matmul(a, b):
    """a[B,M,K] @ b[B,K,N] -> [B,M,N]; grid over the batch dimension.

    Each grid program owns one graph/sample — the batch axis is the
    natural parallel axis for the predictor's dynamic batching (L3 pads
    requests to B and issues one call).
    """
    bsz, m, k = a.shape
    bsz2, k2, n = b.shape
    assert bsz == bsz2 and k == k2, f"bmm mismatch {a.shape} @ {b.shape}"
    return pl.pallas_call(
        _bmm_kernel,
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, m, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, k, n), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, m, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, m, n), jnp.float32),
        interpret=INTERPRET,
    )(a, b)
