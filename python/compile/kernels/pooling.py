"""Masked global mean pool (paper Eq. 6) as a Pallas kernel + custom VJP.

Padded node rows (mask == 0) contribute nothing; the divisor is the real
node count, so the pooled embedding is invariant to the padding amount —
a property the hypothesis tests pin down.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import INTERPRET


def _pool_kernel(h_ref, m_ref, o_ref):
    h = h_ref[0]  # [N, F]
    m = m_ref[0]  # [N]
    s = jnp.sum(h * m[:, None], axis=0)
    cnt = jnp.maximum(jnp.sum(m), 1.0)
    o_ref[0] = s / cnt


@jax.jit
def _pool_fwd_kernel(h, mask):
    bsz, n, f = h.shape
    return pl.pallas_call(
        _pool_kernel,
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, n, f), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, f), jnp.float32),
        interpret=INTERPRET,
    )(h, mask)


@jax.custom_vjp
def masked_mean_pool(h, mask):
    """h: [B,N,F], mask: [B,N] -> [B,F] mean over valid nodes."""
    return _pool_fwd_kernel(h, mask)


def _pool_vjp_fwd(h, mask):
    return _pool_fwd_kernel(h, mask), mask


def _pool_vjp_bwd(mask, g):
    cnt = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)  # [B,1]
    dh = mask[:, :, None] * (g / cnt)[:, None, :]
    return dh, None


masked_mean_pool.defvjp(_pool_vjp_fwd, _pool_vjp_bwd)
