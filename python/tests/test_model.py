"""L2 correctness: Algorithm 2, flat-param layout, ANN/GCN graphs, muAPE
loss, Adam — against pure-jnp re-derivations."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref


# ---------------------------------------------------------------------------
# Algorithm 2
# ---------------------------------------------------------------------------
def test_get_node_config_paper_shape():
    # nodeCount=32, hLayerCount=4: P=5, expMaxP=(4+2+5)//2=5 <= P -> 6,
    # incr=1, decr=min(5,3)=3 -> [32, 64, 32, 16]
    assert M.get_node_config(32, 4) == [32, 64, 32, 16]
    assert M.get_node_config(16, 3) == [16, 32, 16]
    assert M.get_node_config(64, 5) == [64, 128, 64, 32, 16]


def test_get_node_config_invariants():
    for node_count in [4, 8, 16, 32, 64, 128]:
        for layers in range(3, 10):
            cfg = M.get_node_config(node_count, layers)
            assert len(cfg) == layers
            # Algorithm 2's `expMaxP = P + 1` escape hatch may exceed maxP
            # by one doubling when nodeCount is already 2^maxP.
            assert all(4 <= c <= 256 for c in cfg), (node_count, layers, cfg)
            assert all(c & (c - 1) == 0 for c in cfg)  # powers of two
            # rises then falls (unimodal in exponent)
            peak = cfg.index(max(cfg))
            assert all(cfg[i] <= cfg[i + 1] for i in range(peak))
            assert all(cfg[i] >= cfg[i + 1] for i in range(peak, layers - 1))


# ---------------------------------------------------------------------------
# flat parameter layout
# ---------------------------------------------------------------------------
def test_layout_is_contiguous_and_disjoint():
    cfg = M.ann_variants()[0]
    lay = cfg.layout()
    expect_off = 0
    for name, off, shape in lay.entries:
        assert off == expect_off
        size = int(np.prod(shape))
        expect_off += size
    assert lay.total == expect_off


def test_layout_slices_roundtrip():
    cfg = M.ann_variants()[0]
    lay = cfg.layout()
    theta = jnp.arange(lay.total, dtype=jnp.float32)
    sl = lay.slices(theta)
    for name, off, shape in lay.entries:
        size = int(np.prod(shape))
        want = jnp.arange(off, off + size, dtype=jnp.float32).reshape(shape)
        np.testing.assert_array_equal(sl[name], want)


# ---------------------------------------------------------------------------
# ANN graph vs pure-jnp
# ---------------------------------------------------------------------------
def ann_ref(cfg, layout, theta, x):
    p = layout.slices(theta)
    h = x
    nh = len(cfg.hidden)
    for i in range(nh):
        h = ref.dense_ref(h, p[f"w{i}"], p[f"b{i}"], cfg.act)
    return ref.dense_ref(h, p[f"w{nh}"], p[f"b{nh}"], "linear")[:, 0]


@pytest.mark.parametrize("vi", range(4))
def test_ann_apply_matches_pure_jnp(vi):
    cfg = M.ann_variants()[vi]
    lay, predict, _, _ = M.make_ann_fns(cfg)
    theta = M.glorot_init(jax.random.PRNGKey(0), lay)
    x = jax.random.normal(jax.random.PRNGKey(1), (M.BATCH, M.FEAT))
    np.testing.assert_allclose(
        predict(theta, x)[0], ann_ref(cfg, lay, theta, x), rtol=1e-4, atol=1e-5
    )


# ---------------------------------------------------------------------------
# GCN graph vs pure-jnp
# ---------------------------------------------------------------------------
def gcn_ref(cfg, layout, theta, nodes, adj, mask, gfeat):
    p = layout.slices(theta)
    h = nodes
    for i in range(len(cfg.conv_dims)):
        if cfg.conv_kind == "gcn":
            h = ref.gcn_conv_ref(h, adj, p[f"cw{i}"], p[f"cb{i}"], cfg.act)
        else:
            h = ref.graph_conv_ref(
                h, adj, p[f"cws{i}"], p[f"cwn{i}"], p[f"cb{i}"], cfg.act
            )
    emb = ref.masked_mean_pool_ref(h, mask)
    h = jnp.concatenate([emb, gfeat], axis=1)
    nh = len(cfg.fc_hidden)
    for i in range(nh):
        h = ref.dense_ref(h, p[f"fw{i}"], p[f"fb{i}"], "relu")
    return ref.dense_ref(h, p[f"fw{nh}"], p[f"fb{nh}"], "linear")[:, 0]


@pytest.mark.parametrize("vi", range(3))
def test_gcn_apply_matches_pure_jnp(vi):
    cfg = M.gcn_variants()[vi]
    lay, predict, embed, _ = M.make_gcn_fns(cfg)
    theta = M.glorot_init(jax.random.PRNGKey(0), lay)
    nodes = jax.random.normal(jax.random.PRNGKey(1), (M.BATCH, M.NODES, M.NODE_FEAT))
    eye = jnp.eye(M.NODES)
    adj = jnp.broadcast_to(eye, (M.BATCH, M.NODES, M.NODES))
    mask = jnp.ones((M.BATCH, M.NODES))
    gfeat = jax.random.normal(jax.random.PRNGKey(2), (M.BATCH, M.FEAT))
    np.testing.assert_allclose(
        predict(theta, nodes, adj, mask, gfeat)[0],
        gcn_ref(cfg, lay, theta, nodes, adj, mask, gfeat),
        rtol=1e-4,
        atol=1e-4,
    )


# ---------------------------------------------------------------------------
# loss + optimizer
# ---------------------------------------------------------------------------
def test_mape_loss_hand_computed():
    pred = jnp.array([1.0, 2.0, 4.0, 100.0])
    y = jnp.array([1.0, 4.0, 2.0, 1.0])
    w = jnp.array([1.0, 1.0, 1.0, 0.0])  # last row is padding
    # APEs: 0, 0.5, 1.0 -> mean = 0.5
    np.testing.assert_allclose(M.mape_loss(pred, y, w), 0.5, rtol=1e-5)


def test_mape_loss_ignores_padding():
    pred = jnp.array([2.0, 123.0])
    y = jnp.array([1.0, 1.0])
    w = jnp.array([1.0, 0.0])
    np.testing.assert_allclose(M.mape_loss(pred, y, w), 1.0, rtol=1e-5)


def test_adam_first_step_direction():
    theta = jnp.zeros(4)
    g = jnp.array([1.0, -2.0, 0.5, 0.0])
    m = jnp.zeros(4)
    v = jnp.zeros(4)
    th2, m2, v2 = M.adam_update(theta, m, v, g, jnp.float32(1.0), jnp.float32(0.01))
    # after bias correction, step ~= -lr * sign(g)
    np.testing.assert_allclose(th2[:3], -0.01 * jnp.sign(g[:3]), rtol=1e-3)
    assert th2[3] == 0.0


def test_ann_training_reduces_loss():
    cfg = M.ann_variants()[0]
    lay, predict, train_step, _ = M.make_ann_fns(cfg)
    key = jax.random.PRNGKey(5)
    theta = M.glorot_init(key, lay)
    x = jax.random.normal(jax.random.PRNGKey(6), (M.BATCH, M.FEAT))
    y = jnp.abs(x[:, 0] * 2.0 + x[:, 1]) + 1.0
    w = jnp.ones((M.BATCH,))
    m = jnp.zeros_like(theta)
    v = jnp.zeros_like(theta)
    jit_step = jax.jit(train_step)
    losses = []
    for t in range(1, 61):
        theta, m, v, loss = jit_step(
            theta, m, v, jnp.float32(t), jnp.float32(3e-3), x, y, w
        )
        losses.append(float(loss))
    assert losses[-1] < 0.5 * losses[0], losses[::10]


def test_train_epoch_equals_unrolled_steps():
    cfg = M.ann_variants()[2]  # small variant for speed
    lay, _, train_step, train_epoch = M.make_ann_fns(cfg)
    S = 3
    theta = M.glorot_init(jax.random.PRNGKey(0), lay)
    m = jnp.zeros_like(theta)
    v = jnp.zeros_like(theta)
    xs = jax.random.normal(jax.random.PRNGKey(1), (S, M.BATCH, M.FEAT))
    ys = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (S, M.BATCH))) + 0.5
    ws = jnp.ones((S, M.BATCH))
    te_theta, te_m, te_v, _ = train_epoch(
        theta, m, v, jnp.float32(1.0), jnp.float32(1e-3), xs, ys, ws
    )
    th, mm, vv = theta, m, v
    for t in range(S):
        th, mm, vv, _ = train_step(
            th, mm, vv, jnp.float32(t + 1.0), jnp.float32(1e-3), xs[t], ys[t], ws[t]
        )
    np.testing.assert_allclose(te_theta, th, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(te_m, mm, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(te_v, vv, rtol=1e-5, atol=1e-7)
