"""AOT pipeline checks: manifest consistency, HLO text validity, fixture
self-consistency. Skipped when `make artifacts` has not run yet."""

import json
import pathlib

import numpy as np
import pytest

from compile import model as M

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"

pytestmark = pytest.mark.skipif(
    not (ART / "manifest.json").exists(),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    return json.loads((ART / "manifest.json").read_text())


def test_manifest_constants_match_model(manifest):
    assert manifest["batch"] == M.BATCH
    assert manifest["feat"] == M.FEAT
    assert manifest["nodes"] == M.NODES
    assert manifest["node_feat"] == M.NODE_FEAT


def test_every_listed_artifact_exists_and_is_hlo(manifest):
    for vname, var in manifest["variants"].items():
        for ep, info in var["entrypoints"].items():
            path = ART / info["file"]
            assert path.exists(), path
            head = path.read_text()[:200]
            assert "HloModule" in head, f"{path} does not look like HLO text"


def test_param_layouts_match_model(manifest):
    for cfg in M.ann_variants():
        lay = cfg.layout()
        got = manifest["variants"][cfg.name]["params"]
        assert got["total"] == lay.total
        assert len(got["entries"]) == len(lay.entries)
    for cfg in M.gcn_variants():
        lay = cfg.layout()
        got = manifest["variants"][cfg.name]["params"]
        assert got["total"] == lay.total


def test_entrypoint_input_shapes(manifest):
    var = manifest["variants"]["ann32x4_relu"]
    P = var["params"]["total"]
    pred = var["entrypoints"]["predict"]
    assert pred["inputs"] == [[P], [M.BATCH, M.FEAT]]
    ts = var["entrypoints"]["train_step"]
    assert ts["inputs"][0] == [P] and ts["inputs"][5] == [M.BATCH, M.FEAT]


def test_fixture_predict_consistency():
    """Recompute the golden ANN prediction from the fixture inputs."""
    fx = ART / "fixtures"
    theta = np.load(fx / "ann_theta.npy")
    x = np.load(fx / "ann_x.npy")
    want = np.load(fx / "ann_pred.npy")
    cfg = M.ann_variants()[0]
    lay, predict, _, _ = M.make_ann_fns(cfg)
    got = predict(theta, x)[0]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_fixture_gcn_consistency():
    fx = ART / "fixtures"
    theta = np.load(fx / "gcn_theta.npy")
    nodes = np.load(fx / "gcn_nodes.npy")
    adj = np.load(fx / "gcn_adj.npy")
    mask = np.load(fx / "gcn_mask.npy")
    gfeat = np.load(fx / "gcn_gfeat.npy")
    want = np.load(fx / "gcn_pred.npy")
    cfg = M.gcn_variants()[0]
    lay, predict, _, _ = M.make_gcn_fns(cfg)
    got = predict(theta, nodes, adj, mask, gfeat)[0]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
