"""L1 correctness: every Pallas kernel vs its pure-jnp oracle in ref.py.

hypothesis sweeps shapes (and activation choices); assert_allclose is the
core correctness signal gating `make artifacts`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    batched_matmul,
    dense,
    gcn_conv,
    graph_conv,
    masked_mean_pool,
    matmul,
)
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)

ACTS = ["relu", "tanh", "linear"]


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 64),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref(m, k, n, seed):
    a = rand(seed, m, k)
    b = rand(seed + 1, k, n)
    np.testing.assert_allclose(matmul(a, b), ref.matmul_ref(a, b), rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    bsz=st.integers(1, 8),
    m=st.integers(1, 32),
    k=st.integers(1, 32),
    n=st.integers(1, 32),
    seed=st.integers(0, 2**16),
)
def test_batched_matmul_matches_ref(bsz, m, k, n, seed):
    a = rand(seed, bsz, m, k)
    b = rand(seed + 1, bsz, k, n)
    want = jnp.einsum("bmk,bkn->bmn", a, b)
    np.testing.assert_allclose(batched_matmul(a, b), want, rtol=1e-4, atol=1e-5)


def test_matmul_tile_boundaries():
    # Exercise non-trivial grids: 128-divisible and prime sizes.
    for m, k, n in [(128, 128, 128), (256, 64, 128), (37, 13, 53), (1, 1, 1)]:
        a = rand(m, m, k)
        b = rand(n, k, n)
        np.testing.assert_allclose(
            matmul(a, b), ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4
        )


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 48),
    n=st.integers(1, 48),
    act=st.sampled_from(ACTS),
    seed=st.integers(0, 2**16),
)
def test_dense_matches_ref(m, k, n, act, seed):
    x, w, b = rand(seed, m, k), rand(seed + 1, k, n), rand(seed + 2, n)
    np.testing.assert_allclose(
        dense(x, w, b, act), ref.dense_ref(x, w, b, act), rtol=1e-4, atol=1e-5
    )


@pytest.mark.parametrize("act", ACTS)
def test_dense_grads_match_ref(act):
    x, w, b = rand(1, 16, 8), rand(2, 8, 4), rand(3, 4)

    def f_kernel(x, w, b):
        return jnp.sum(dense(x, w, b, act) ** 2)

    def f_ref(x, w, b):
        return jnp.sum(ref.dense_ref(x, w, b, act) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, r in zip(gk, gr):
        np.testing.assert_allclose(a, r, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# graph convolutions
# ---------------------------------------------------------------------------
def norm_adj(key, bsz, n):
    """Random symmetric normalized adjacency with self loops."""
    a = (jax.random.uniform(jax.random.PRNGKey(key), (bsz, n, n)) > 0.7).astype(
        jnp.float32
    )
    a = jnp.maximum(a, a.transpose(0, 2, 1))
    a = a + jnp.eye(n)[None]
    a = jnp.minimum(a, 1.0)
    d = jnp.sum(a, axis=2)
    dinv = 1.0 / jnp.sqrt(jnp.maximum(d, 1.0))
    return a * dinv[:, :, None] * dinv[:, None, :]


@settings(max_examples=15, deadline=None)
@given(
    bsz=st.integers(1, 6),
    n=st.integers(2, 24),
    f=st.integers(1, 12),
    g=st.integers(1, 12),
    act=st.sampled_from(ACTS),
    seed=st.integers(0, 2**16),
)
def test_gcn_conv_matches_ref(bsz, n, f, g, act, seed):
    nodes = rand(seed, bsz, n, f)
    adj = norm_adj(seed + 1, bsz, n)
    w, b = rand(seed + 2, f, g), rand(seed + 3, g)
    np.testing.assert_allclose(
        gcn_conv(nodes, adj, w, b, act),
        ref.gcn_conv_ref(nodes, adj, w, b, act),
        rtol=1e-4,
        atol=1e-5,
    )


@settings(max_examples=15, deadline=None)
@given(
    bsz=st.integers(1, 4),
    n=st.integers(2, 20),
    f=st.integers(1, 10),
    g=st.integers(1, 10),
    act=st.sampled_from(ACTS),
    seed=st.integers(0, 2**16),
)
def test_graph_conv_matches_ref(bsz, n, f, g, act, seed):
    nodes = rand(seed, bsz, n, f)
    adj = norm_adj(seed + 1, bsz, n)
    ws, wn, b = rand(seed + 2, f, g), rand(seed + 3, f, g), rand(seed + 4, g)
    np.testing.assert_allclose(
        graph_conv(nodes, adj, ws, wn, b, act),
        ref.graph_conv_ref(nodes, adj, ws, wn, b, act),
        rtol=1e-4,
        atol=1e-5,
    )


@pytest.mark.parametrize("act", ACTS)
def test_gcn_conv_grads_match_ref(act):
    nodes = rand(1, 3, 12, 5)
    adj = norm_adj(2, 3, 12)
    w, b = rand(3, 5, 7), rand(4, 7)

    def f_kernel(nodes, w, b):
        return jnp.sum(gcn_conv(nodes, adj, w, b, act) ** 2)

    def f_ref(nodes, w, b):
        return jnp.sum(ref.gcn_conv_ref(nodes, adj, w, b, act) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(nodes, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(nodes, w, b)
    for a, r in zip(gk, gr):
        np.testing.assert_allclose(a, r, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("act", ACTS)
def test_graph_conv_grads_match_ref(act):
    nodes = rand(1, 2, 10, 4)
    adj = norm_adj(2, 2, 10)
    ws, wn, b = rand(3, 4, 6), rand(4, 4, 6), rand(5, 6)

    def f_kernel(nodes, ws, wn, b):
        return jnp.sum(graph_conv(nodes, adj, ws, wn, b, act) ** 2)

    def f_ref(nodes, ws, wn, b):
        return jnp.sum(ref.graph_conv_ref(nodes, adj, ws, wn, b, act) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2, 3))(nodes, ws, wn, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2, 3))(nodes, ws, wn, b)
    for a, r in zip(gk, gr):
        np.testing.assert_allclose(a, r, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# pooling
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(
    bsz=st.integers(1, 6),
    n=st.integers(1, 32),
    f=st.integers(1, 16),
    valid=st.integers(1, 32),
    seed=st.integers(0, 2**16),
)
def test_masked_mean_pool_matches_ref(bsz, n, f, valid, seed):
    h = rand(seed, bsz, n, f)
    valid = min(valid, n)
    mask = jnp.concatenate(
        [jnp.ones((bsz, valid)), jnp.zeros((bsz, n - valid))], axis=1
    )
    np.testing.assert_allclose(
        masked_mean_pool(h, mask),
        ref.masked_mean_pool_ref(h, mask),
        rtol=1e-5,
        atol=1e-6,
    )


def test_pool_padding_invariance():
    """Adding zero-masked padding rows must not change the pooled value."""
    h = rand(0, 2, 8, 4)
    mask = jnp.ones((2, 8))
    base = masked_mean_pool(h, mask)
    h_pad = jnp.concatenate([h, rand(1, 2, 5, 4)], axis=1)
    mask_pad = jnp.concatenate([mask, jnp.zeros((2, 5))], axis=1)
    np.testing.assert_allclose(base, masked_mean_pool(h_pad, mask_pad), rtol=1e-6)


def test_pool_all_masked_is_zero_safe():
    h = rand(0, 1, 4, 3)
    mask = jnp.zeros((1, 4))
    out = masked_mean_pool(h, mask)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(out, jnp.zeros((1, 3)), atol=1e-6)


def test_pool_grads_match_ref():
    h = rand(0, 2, 6, 3)
    mask = jnp.concatenate([jnp.ones((2, 4)), jnp.zeros((2, 2))], axis=1)
    gk = jax.grad(lambda h: jnp.sum(masked_mean_pool(h, mask) ** 2))(h)
    gr = jax.grad(lambda h: jnp.sum(ref.masked_mean_pool_ref(h, mask) ** 2))(h)
    np.testing.assert_allclose(gk, gr, rtol=1e-5, atol=1e-6)
