"""pytest path setup: make `compile.*` importable when the suite is run
from the repository root (`pytest python/tests/`)."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
