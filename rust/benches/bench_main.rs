//! Benchmark harness (criterion is unavailable offline — this is a
//! self-contained timer harness with warmup, repetition, and median/MAD
//! reporting; `cargo bench` runs it).
//!
//! Two groups:
//!   * microbenches on the hot paths (backend oracle, simulators,
//!     samplers, tree models, MOTPE, batched HLO predict) — the §Perf
//!     targets in EXPERIMENTS.md;
//!   * one end-to-end row per paper table/figure family (datagen +
//!     two-stage train + DSE iteration costs), mirroring DESIGN.md §5.
//!
//! Filter: `cargo bench -- <substring>`; quick mode: `cargo bench -- --quick`.

use std::rc::Rc;
use std::time::Instant;

use fso::backend::{BackendConfig, Enablement, SpnrFlow};
use fso::coordinator::dse_driver::SurrogateBundle;
use fso::coordinator::{datagen, DatagenConfig, EvalService};
use fso::data::Metric;
use fso::dse::{Motpe, MotpeConfig};
use fso::generators::{ArchConfig, Lhg, ParamKind, ParamSpec, Platform};
use fso::models::{Gbdt, GbdtParams, RandomForest, RfParams};
use fso::runtime::Engine;
use fso::sampling::{Sampler, SamplerKind};
use fso::simulators::simulate;
use fso::util::rng::Rng;
use fso::util::tensor::Tensor;

struct Bench {
    filter: Option<String>,
    quick: bool,
}

impl Bench {
    fn run<F: FnMut() -> R, R>(&self, name: &str, mut f: F) {
        if let Some(filt) = &self.filter {
            if !name.contains(filt.as_str()) {
                return;
            }
        }
        let (warmup, reps) = if self.quick { (1, 5) } else { (3, 15) };
        for _ in 0..warmup {
            std::hint::black_box(f());
        }
        let mut times: Vec<f64> = (0..reps)
            .map(|_| {
                let t0 = Instant::now();
                std::hint::black_box(f());
                t0.elapsed().as_secs_f64() * 1e3
            })
            .collect();
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = times[times.len() / 2];
        let mad = {
            let mut d: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
            d.sort_by(|a, b| a.partial_cmp(b).unwrap());
            d[d.len() / 2]
        };
        println!("{name:<46} {median:10.3} ms  (+-{mad:.3})");
    }
}

fn mid_arch(p: Platform) -> ArchConfig {
    ArchConfig::new(
        p,
        p.param_space().iter().map(|s| s.kind.from_unit(0.5)).collect(),
    )
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let quick = args.iter().any(|a| a == "--quick");
    let filter = args.into_iter().find(|a| !a.starts_with("--"));
    let b = Bench { filter, quick };
    println!("{:<46} {:>10}", "benchmark", "median");
    println!("{}", "-".repeat(70));

    // ---- substrates -------------------------------------------------
    let flow = SpnrFlow::new(Enablement::Gf12, 1);
    for p in Platform::ALL {
        let arch = mid_arch(p);
        let tree = p.generate(&arch).unwrap();
        let agg = tree.aggregates();
        let id = arch.id_hash();
        b.run(&format!("backend_flow/{p}"), || {
            flow.run_on_aggregates(&agg, id, p.macro_heavy(), BackendConfig::new(0.9, 0.45))
        });
    }
    for p in Platform::ALL {
        let arch = mid_arch(p);
        let fr = flow.run(&arch, BackendConfig::new(0.9, 0.45)).unwrap();
        b.run(&format!("simulator/{p}"), || {
            simulate(&arch, &fr.backend, Enablement::Gf12).unwrap()
        });
    }
    {
        let p = Platform::GeneSys;
        let arch = mid_arch(p);
        b.run("generator+lhg/genesys", || {
            let tree = p.generate(&arch).unwrap();
            Lhg::from_tree(&tree)
        });
    }

    // ---- sampling ----------------------------------------------------
    for kind in SamplerKind::ALL {
        b.run(&format!("sampler/{}/64x8d", kind.name()), || {
            Sampler::new(kind, 8, 42).sample(64)
        });
    }

    // ---- models -------------------------------------------------------
    let (x, y) = {
        let mut rng = Rng::new(3);
        let x: Vec<Vec<f64>> =
            (0..600).map(|_| (0..16).map(|_| rng.f64()).collect()).collect();
        let y: Vec<f64> = x.iter().map(|v| v[0] * 3.0 + v[1] * v[2] + v[12]).collect();
        (x, y)
    };
    b.run("gbdt/fit_600x16", || Gbdt::fit(&x, &y, GbdtParams::default(), 0));
    let gbdt = Gbdt::fit(&x, &y, GbdtParams::default(), 0);
    b.run("gbdt/predict_600", || gbdt.predict(&x));
    b.run("rf/fit_600x16", || {
        RandomForest::fit(&x, &y, RfParams { n_estimators: 60, ..Default::default() }, 0)
    });

    // ---- MOTPE ---------------------------------------------------------
    {
        let space = vec![
            ParamSpec { name: "a", kind: ParamKind::Int { lo: 1, hi: 50 } },
            ParamSpec { name: "b", kind: ParamKind::Float { lo: 0.0, hi: 1.0 } },
            ParamSpec { name: "c", kind: ParamKind::Float { lo: 0.0, hi: 1.0 } },
        ];
        b.run("motpe/ask+tell_x50_at_200_trials", || {
            let mut m = Motpe::new(space.clone(), MotpeConfig::default());
            let mut rng = Rng::new(1);
            for _ in 0..200 {
                let x = m.ask();
                let o = vec![x[1], 1.0 - x[1] + rng.f64() * 0.1];
                m.tell(x, o, true);
            }
        });
    }

    // ---- eval service: parallel memoized ground-truth scoring ---------
    // the ISSUE-1 acceptance row: a 4-worker sweep must beat the serial
    // sweep by >= 2x, and the warm-cache row reports a nonzero oracle
    // cache hit-rate in the printed stats line.
    {
        let p = Platform::Axiline;
        let archs = datagen::sample_archs(p, 16, SamplerKind::Lhs, 11);
        let backends = datagen::sample_backend(p, Enablement::Gf12, 8, 12);
        let jobs: Vec<(ArchConfig, BackendConfig)> = archs
            .iter()
            .flat_map(|a| backends.iter().map(move |bk| (a.clone(), *bk)))
            .collect();
        for workers in [1usize, 4] {
            b.run(
                &format!("eval_service/ground_truth_{}pts_w{workers}", jobs.len()),
                || {
                    let svc = EvalService::new(Enablement::Gf12, 7).with_workers(workers);
                    svc.evaluate_many(&jobs, None).unwrap()
                },
            );
        }
        let warm = EvalService::new(Enablement::Gf12, 7).with_workers(4);
        b.run(
            &format!("eval_service/ground_truth_{}pts_warm_cache", jobs.len()),
            || warm.evaluate_many(&jobs, None).unwrap(),
        );
        println!("    eval_service stats: {}", warm.stats());

        // ---- persistent cache store: cold vs warm start (ISSUE 2) ----
        // cold: empty dir, full oracle sweep + flush; warm: reopen the
        // flushed store with a fresh service — disk hits replace flow runs
        use fso::coordinator::CacheStore;
        use std::sync::Arc;
        let dir =
            std::env::temp_dir().join(format!("fso-bench-cache-{}", std::process::id()));
        b.run(
            &format!("cache_store/cold_{}pts_flush", jobs.len()),
            || {
                let _ = std::fs::remove_dir_all(&dir);
                let store = Arc::new(CacheStore::open(&dir).unwrap());
                let svc = EvalService::new(Enablement::Gf12, 7)
                    .with_workers(4)
                    .with_cache_store(Arc::clone(&store));
                svc.evaluate_many(&jobs, None).unwrap();
                store.flush().unwrap()
            },
        );
        // seed the directory once for the warm rows
        let _ = std::fs::remove_dir_all(&dir);
        {
            let store = Arc::new(CacheStore::open(&dir).unwrap());
            let svc = EvalService::new(Enablement::Gf12, 7)
                .with_workers(4)
                .with_cache_store(Arc::clone(&store));
            svc.evaluate_many(&jobs, None).unwrap();
            store.flush().unwrap();
        }
        b.run(
            &format!("cache_store/warm_start_{}pts", jobs.len()),
            || {
                let store = Arc::new(CacheStore::open(&dir).unwrap());
                let svc = EvalService::new(Enablement::Gf12, 7)
                    .with_workers(4)
                    .with_cache_store(Arc::clone(&store));
                svc.evaluate_many(&jobs, None).unwrap()
            },
        );
        {
            let store = Arc::new(CacheStore::open(&dir).unwrap());
            let svc = EvalService::new(Enablement::Gf12, 7)
                .with_workers(4)
                .with_cache_store(Arc::clone(&store));
            svc.evaluate_many(&jobs, None).unwrap();
            println!("    warm-start stats: {}", svc.stats());
        }

        // ---- store lifecycle: compaction + eviction (ISSUE 4) ----
        // compaction over the warm dir is idempotent (byte-unchanged
        // shards are skipped), so the row times the full load + merge
        // + render + compare pass
        b.run(&format!("cache_store/compact_{}pts", jobs.len()), || {
            let store = CacheStore::open(&dir).unwrap();
            store.compact().unwrap()
        });
        // one-shot (destructive): LRU-evict the warm store down to half
        // its records, report the reclaim
        {
            use fso::coordinator::StorePolicy;
            let store = CacheStore::open(&dir).unwrap().with_policy(StorePolicy {
                max_records: Some(jobs.len() / 2),
                ..StorePolicy::default()
            });
            let t0 = Instant::now();
            let rep = store.compact().unwrap();
            println!(
                "    eviction to {} records: {rep} ({:.3} ms)",
                jobs.len() / 2,
                t0.elapsed().as_secs_f64() * 1e3
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    // ---- model store: cold fit + flush vs warm artifact load ----------
    // the ISSUE-3 acceptance rows: a cold start pays the full surrogate
    // fit (ROI classifier + 5 GBDT regressors) and the artifact flush;
    // a warm start loads and deserializes the stored bundle instead —
    // bit-identical predictions, zero refits.
    {
        use fso::coordinator::ModelStore;
        let g = datagen::generate(&DatagenConfig {
            n_arch: 8,
            n_backend_train: 12,
            n_backend_test: 4,
            ..DatagenConfig::small(Platform::Axiline, Enablement::Gf12)
        })
        .unwrap();
        let dir =
            std::env::temp_dir().join(format!("fso-bench-models-{}", std::process::id()));
        b.run("model_store/cold_fit_surrogate+flush", || {
            let _ = std::fs::remove_dir_all(&dir);
            let ms = ModelStore::open(&dir).unwrap();
            let (s, replayed) =
                SurrogateBundle::fit_cached(&g.dataset, &g.backend_split, 7, Some(&ms))
                    .unwrap();
            assert!(!replayed);
            ms.flush().unwrap();
            s
        });
        // seed the directory once for the warm rows
        let _ = std::fs::remove_dir_all(&dir);
        {
            let ms = ModelStore::open(&dir).unwrap();
            SurrogateBundle::fit_cached(&g.dataset, &g.backend_split, 7, Some(&ms)).unwrap();
            ms.flush().unwrap();
        }
        b.run("model_store/warm_load_surrogate", || {
            let ms = ModelStore::open(&dir).unwrap();
            let (s, replayed) =
                SurrogateBundle::fit_cached(&g.dataset, &g.backend_split, 7, Some(&ms))
                    .unwrap();
            assert!(replayed, "warm start must replay the stored bundle");
            s
        });
        {
            let ms = ModelStore::open(&dir).unwrap();
            let _ = SurrogateBundle::fit_cached(&g.dataset, &g.backend_split, 7, Some(&ms))
                .unwrap();
            println!("    model store stats: {}", ms.stats());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    // ---- request coalescing (ISSUE 5): duplicate-heavy oracle sweep ---
    // the acceptance rows: with duplicates outnumbering cores, single-
    // flight turns the redundant concurrent oracle runs per key into
    // one shared run — wall-clock drops and oracle_runs == unique keys.
    // The measured pair lands in BENCH_coalesce.json as a trajectory
    // point for cross-PR tracking.
    {
        let p = Platform::Axiline;
        let uniques = datagen::sample_archs(p, 6, SamplerKind::Lhs, 21);
        let bcfg = BackendConfig::new(0.9, 0.45);
        let dup = 16usize;
        // grouped by key: every worker piles onto the same fresh key at
        // once, the worst duplication pattern for an uncoalesced memo
        let jobs: Vec<(ArchConfig, BackendConfig)> = uniques
            .iter()
            .flat_map(|a| std::iter::repeat(a.clone()).take(dup).map(|a| (a, bcfg)))
            .collect();
        let workers = 16;
        b.run(
            &format!("coalesce/uncoalesced_{}keys_x{dup}dups_w{workers}", uniques.len()),
            || {
                let svc = EvalService::new(Enablement::Gf12, 7).with_workers(workers);
                svc.evaluate_many(&jobs, None).unwrap()
            },
        );
        b.run(
            &format!("coalesce/coalesced_{}keys_x{dup}dups_w{workers}", uniques.len()),
            || {
                let svc = EvalService::new(Enablement::Gf12, 7)
                    .with_workers(workers)
                    .with_coalescing(true);
                svc.evaluate_many(&jobs, None).unwrap()
            },
        );
        // one measured pair for the trajectory point + the invariant
        let t0 = Instant::now();
        let plain = EvalService::new(Enablement::Gf12, 7).with_workers(workers);
        plain.evaluate_many(&jobs, None).unwrap();
        let uncoalesced_ms = t0.elapsed().as_secs_f64() * 1e3;
        let uncoalesced_runs = plain.stats().oracle_runs;
        let t0 = Instant::now();
        let coal = EvalService::new(Enablement::Gf12, 7)
            .with_workers(workers)
            .with_coalescing(true);
        coal.evaluate_many(&jobs, None).unwrap();
        let coalesced_ms = t0.elapsed().as_secs_f64() * 1e3;
        let s = coal.stats();
        assert_eq!(
            s.oracle_runs,
            uniques.len(),
            "coalesced oracle runs must equal unique keys"
        );
        println!("    coalesced stats: {s}");
        let speedup = uncoalesced_ms / coalesced_ms.max(1e-9);
        let json = format!(
            "{{\"bench\":\"coalesce_dup_heavy\",\"jobs\":{},\"unique_keys\":{},\"workers\":{workers},\"uncoalesced_ms\":{uncoalesced_ms:.3},\"coalesced_ms\":{coalesced_ms:.3},\"speedup\":{speedup:.3},\"uncoalesced_oracle_runs\":{uncoalesced_runs},\"coalesced_oracle_runs\":{},\"coalesced_hits\":{}}}\n",
            jobs.len(),
            uniques.len(),
            s.oracle_runs,
            s.coalesced_hits,
        );
        std::fs::write("BENCH_coalesce.json", &json).ok();
        println!(
            "    wrote BENCH_coalesce.json (uncoalesced {uncoalesced_ms:.1} ms vs \
             coalesced {coalesced_ms:.1} ms, {speedup:.2}x)"
        );
    }

    // ---- EvalRouter: cross-client surrogate mega-batching -------------
    {
        use fso::coordinator::EvalRouter;
        use std::sync::Arc;
        let g = datagen::generate(&DatagenConfig {
            n_arch: 6,
            n_backend_train: 8,
            n_backend_test: 2,
            ..DatagenConfig::small(Platform::Axiline, Enablement::Gf12)
        })
        .unwrap();
        let feats: Vec<Vec<f64>> =
            g.dataset.rows.iter().map(|r| r.features_vec()).collect();
        let service = Arc::new(
            EvalService::new(Enablement::Gf12, 2023)
                .with_surrogate(SurrogateBundle::fit(&g.dataset, &g.backend_split, 7).unwrap()),
        );
        let router = EvalRouter::start(Arc::clone(&service));
        let clients = 8usize;
        let per_client = 40usize;
        b.run(&format!("coalesce/router_{clients}clients_x{per_client}rows"), || {
            std::thread::scope(|scope| {
                for c in 0..clients {
                    let client = router.client();
                    let feats = &feats;
                    scope.spawn(move || {
                        for k in 0..per_client {
                            let row =
                                feats[(c * per_client + k) % feats.len()].clone();
                            client.predict(vec![row]).unwrap();
                        }
                    });
                }
            })
        });
        println!("    router stats: {}", service.stats());
        drop(router);

        // pipelined vs strict DSE cadence (byte-identical trajectories)
        let mk_driver = |seed: u64| {
            let bundle = SurrogateBundle::fit(&g.dataset, &g.backend_split, seed).unwrap();
            fso::coordinator::DseDriver {
                service: EvalService::new(Enablement::Gf12, 2023).with_surrogate(bundle),
            }
        };
        let mut runtimes: Vec<f64> =
            g.dataset.rows.iter().map(|r| r.runtime_s).collect();
        runtimes.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let problem = fso::coordinator::dse_driver::axiline_svm_problem(
            g.dataset.rows.iter().map(|r| r.power_w).fold(0.0, f64::max) * 2.0,
            runtimes[runtimes.len() * 3 / 4],
        );
        let strict = mk_driver(7);
        b.run("dse/strict_alternation_x60_b12", || {
            strict
                .run_batched(
                    &problem,
                    60,
                    2,
                    MotpeConfig { n_startup: 16, seed: 5, ..Default::default() },
                    12,
                )
                .unwrap()
        });
        let piped = mk_driver(7);
        b.run("dse/pipelined_x60_b12_inflight4", || {
            piped
                .run_pipelined(
                    &problem,
                    60,
                    2,
                    MotpeConfig { n_startup: 16, seed: 5, ..Default::default() },
                    12,
                    4,
                )
                .unwrap()
        });
    }

    // ---- datagen / train / DSE end-to-end rows (per table family) -----
    b.run("e2e/datagen_axiline_24x40 (tab3-5 input)", || {
        datagen::generate(&DatagenConfig::small(Platform::Axiline, Enablement::Gf12))
            .unwrap()
    });
    {
        let g = datagen::generate(&DatagenConfig::small(Platform::Axiline, Enablement::Gf12))
            .unwrap();
        b.run("e2e/two_stage_fit_5metrics (tab4/5 cell)", || {
            SurrogateBundle::fit(&g.dataset, &g.backend_split, 7).unwrap()
        });
        let s = SurrogateBundle::fit(&g.dataset, &g.backend_split, 7).unwrap();
        b.run("e2e/surrogate_predict_x960 (fig11/12 inner loop)", || {
            for r in &g.dataset.rows {
                std::hint::black_box(s.predict(&r.features_vec()));
            }
        });
        // same 960 rows through the service's batched surrogate path
        let feats: Vec<Vec<f64>> =
            g.dataset.rows.iter().map(|r| r.features_vec()).collect();
        let svc = EvalService::new(Enablement::Gf12, 2023)
            .with_surrogate(SurrogateBundle::fit(&g.dataset, &g.backend_split, 7).unwrap())
            .with_workers(4);
        b.run("e2e/surrogate_predict_batched_x960 (EvalService)", || {
            svc.predict_batch(&feats).unwrap()
        });
        println!("    surrogate batching: {}", svc.stats());
    }

    // ---- PJRT hot path -------------------------------------------------
    if let Some(dir) = fso::test_support::artifacts_dir() {
        let engine = Rc::new(Engine::load(&dir).unwrap());
        let v = engine.manifest.variant("ann32x4_relu").unwrap().clone();
        let theta = fso::models::ann::glorot_init(&v, &mut Rng::new(1));
        let xb = Tensor::zeros(&[engine.manifest.batch, engine.manifest.feat]);
        let file = v.entrypoint("predict").unwrap().file.clone();
        // warm compile outside timing
        engine.run(&file, &[theta.clone(), xb.clone()]).unwrap();
        b.run("pjrt/ann_predict_batch32", || {
            engine.run(&file, &[theta.clone(), xb.clone()]).unwrap()
        });

        let ts = v.entrypoint("train_step").unwrap().file.clone();
        let p = v.param_total;
        let args = vec![
            theta.clone(),
            Tensor::zeros(&[p]),
            Tensor::zeros(&[p]),
            Tensor::scalar(1.0),
            Tensor::scalar(1e-3),
            xb.clone(),
            Tensor::zeros(&[32]),
            Tensor::zeros(&[32]),
        ];
        engine.run(&ts, &args).unwrap();
        b.run("pjrt/ann_train_step", || engine.run(&ts, &args).unwrap());

        let gv = engine.manifest.variant("gcn3").unwrap().clone();
        let gtheta = fso::models::ann::glorot_init(&gv, &mut Rng::new(2));
        let n = engine.manifest.nodes;
        let nf = engine.manifest.node_feat;
        let nodes = Tensor::zeros(&[32, n, nf]);
        let adj = Tensor::zeros(&[32, n, n]);
        let mask = Tensor::zeros(&[32, n]);
        let gfeat = Tensor::zeros(&[32, engine.manifest.feat]);
        let gp = gv.entrypoint("predict").unwrap().file.clone();
        let gargs = vec![gtheta.clone(), nodes.clone(), adj.clone(), mask.clone(), gfeat.clone()];
        engine.run(&gp, &gargs).unwrap();
        b.run("pjrt/gcn_predict_batch32", || engine.run(&gp, &gargs).unwrap());

        let gts = gv.entrypoint("train_step").unwrap().file.clone();
        let gp_total = gv.param_total;
        let gtargs = vec![
            gtheta,
            Tensor::zeros(&[gp_total]),
            Tensor::zeros(&[gp_total]),
            Tensor::scalar(1.0),
            Tensor::scalar(1e-3),
            nodes,
            adj,
            mask,
            gfeat,
            Tensor::zeros(&[32]),
            Tensor::zeros(&[32]),
        ];
        engine.run(&gts, &gtargs).unwrap();
        b.run("pjrt/gcn_train_step_batch32", || engine.run(&gts, &gtargs).unwrap());
    } else {
        println!("(artifacts not built: skipping PJRT benches)");
    }

    // ---- flat SoA tree inference (ISSUE 6): the gated perf suite -----
    // same suite the CI perf gate runs via `fso bench run/compare`;
    // emits BENCH_flat_tree.json as the trajectory point and asserts
    // the mega-batch flat-vs-recursive speedup invariant.
    if b.filter.as_ref().map_or(true, |f| "flat_tree".contains(f.as_str())) {
        println!("{}", "-".repeat(70));
        let report = fso::bench::run_suite("flat_tree", b.quick).unwrap();
        print!("{}", report.render());
        fso::bench::check_invariants(&report).unwrap();
        let out = std::path::Path::new("BENCH_flat_tree.json");
        report.save(out).unwrap();
        println!("    wrote BENCH_flat_tree.json (gate: fso bench compare)");
    }

    println!("{}", "-".repeat(70));
    println!("done");
}
