//! One dataset row: a fully-characterized (architecture, backend knobs)
//! point. `features` is the unified 16-dim vector of Eq. 1/2; the five
//! targets are the paper's metrics (backend power/performance/area,
//! system energy/runtime).

use crate::generators::FEAT_DIM;

/// The five predicted metrics (paper §3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Metric {
    /// Total post-route power, W.
    Power,
    /// Effective clock frequency, GHz.
    Performance,
    /// Chip area, mm^2.
    Area,
    /// Workload energy, J.
    Energy,
    /// Workload runtime, s.
    Runtime,
}

impl Metric {
    pub const ALL: [Metric; 5] = [
        Metric::Performance,
        Metric::Power,
        Metric::Area,
        Metric::Energy,
        Metric::Runtime,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Metric::Power => "power",
            Metric::Performance => "perf",
            Metric::Area => "area",
            Metric::Energy => "energy",
            Metric::Runtime => "runtime",
        }
    }

    pub fn is_backend(&self) -> bool {
        matches!(self, Metric::Power | Metric::Performance | Metric::Area)
    }
}

impl std::fmt::Display for Metric {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct Row {
    /// Index of the architectural configuration (keys the LHG cache).
    pub arch_idx: usize,
    /// Unified feature vector (Eq. 1/2 inputs).
    pub features: [f64; FEAT_DIM],
    /// Backend knobs (also in features[12..14]; kept raw for plots).
    pub f_target_ghz: f64,
    pub util: f64,
    /// Targets.
    pub power_w: f64,
    pub f_effective_ghz: f64,
    pub area_mm2: f64,
    pub energy_j: f64,
    pub runtime_s: f64,
    /// Ground-truth ROI membership (Eq. 4).
    pub in_roi: bool,
}

impl Row {
    pub fn target(&self, m: Metric) -> f64 {
        match m {
            Metric::Power => self.power_w,
            Metric::Performance => self.f_effective_ghz,
            Metric::Area => self.area_mm2,
            Metric::Energy => self.energy_j,
            Metric::Runtime => self.runtime_s,
        }
    }

    pub fn features_vec(&self) -> Vec<f64> {
        self.features.to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row() -> Row {
        Row {
            arch_idx: 3,
            features: [0.5; FEAT_DIM],
            f_target_ghz: 1.0,
            util: 0.5,
            power_w: 2.0,
            f_effective_ghz: 0.9,
            area_mm2: 1.5,
            energy_j: 0.1,
            runtime_s: 0.01,
            in_roi: true,
        }
    }

    #[test]
    fn target_accessor_matches_fields() {
        let r = row();
        assert_eq!(r.target(Metric::Power), 2.0);
        assert_eq!(r.target(Metric::Performance), 0.9);
        assert_eq!(r.target(Metric::Area), 1.5);
        assert_eq!(r.target(Metric::Energy), 0.1);
        assert_eq!(r.target(Metric::Runtime), 0.01);
    }

    #[test]
    fn metric_classification() {
        assert!(Metric::Power.is_backend());
        assert!(!Metric::Energy.is_backend());
        assert_eq!(Metric::ALL.len(), 5);
    }
}
