//! Dataset layer: rows of (features, targets, ROI flag) produced by the
//! datagen pipeline, with the paper's §7.2 split discipline (separately
//! sampled train/validation/test sets for unseen-backend and
//! unseen-architecture studies) and CSV/JSON persistence.

pub mod dataset;
pub mod row;

pub use dataset::{Dataset, Split};
pub use row::{Metric, Row};
