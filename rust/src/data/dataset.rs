//! Dataset container + split discipline + persistence.

use std::collections::BTreeSet;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::generators::{ArchConfig, Lhg, Platform, FEAT_DIM};
use crate::util::rng::Rng;

use super::row::{Metric, Row};

/// Train/validation/test split (paper §7.2: separately-sampled sets, no
/// overlap, each covering the design space).
#[derive(Debug, Clone, Default)]
pub struct Split {
    pub train: Vec<usize>,
    pub val: Vec<usize>,
    pub test: Vec<usize>,
}

impl Split {
    pub fn validate(&self, n: usize) -> Result<()> {
        let mut seen = BTreeSet::new();
        for (name, part) in
            [("train", &self.train), ("val", &self.val), ("test", &self.test)]
        {
            for &i in part {
                if i >= n {
                    bail!("{name} index {i} out of range {n}");
                }
                if !seen.insert(i) {
                    bail!("{name} index {i} appears in two parts");
                }
            }
        }
        Ok(())
    }
}

/// A generated dataset for one (platform, enablement) pair.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub platform: Platform,
    pub enablement: crate::backend::Enablement,
    /// Distinct architectural configurations.
    pub archs: Vec<ArchConfig>,
    /// Logical hierarchy graph per architecture (same index).
    pub lhgs: Vec<Lhg>,
    pub rows: Vec<Row>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn features(&self, idx: &[usize]) -> Vec<Vec<f64>> {
        idx.iter().map(|&i| self.rows[i].features_vec()).collect()
    }

    pub fn targets(&self, idx: &[usize], m: Metric) -> Vec<f64> {
        idx.iter().map(|&i| self.rows[i].target(m)).collect()
    }

    pub fn roi_labels(&self, idx: &[usize]) -> Vec<bool> {
        idx.iter().map(|&i| self.rows[i].in_roi).collect()
    }

    /// Indices of ROI rows only (stage-2 regressors train on these).
    pub fn roi_subset(&self, idx: &[usize]) -> Vec<usize> {
        idx.iter().copied().filter(|&i| self.rows[i].in_roi).collect()
    }

    /// Unseen-backend split (paper §7.2): the same architectures appear
    /// in train and test, but backend (f_target, util) points are
    /// disjoint sets. `test_backends` distinct backend points are held
    /// out by their quantized knob identity.
    pub fn split_unseen_backend(&self, test_frac: f64, seed: u64) -> Split {
        let mut knobs: Vec<(u64, u64)> = self
            .rows
            .iter()
            .map(|r| ((r.f_target_ghz * 1e4) as u64, (r.util * 1e4) as u64))
            .collect::<BTreeSet<_>>()
            .into_iter()
            .collect();
        let mut rng = Rng::new(seed ^ 0xBAC4E2D);
        rng.shuffle(&mut knobs);
        let n_test = ((knobs.len() as f64 * test_frac).round() as usize).max(1);
        let test_knobs: BTreeSet<(u64, u64)> = knobs.into_iter().take(n_test).collect();
        let mut split = Split::default();
        for (i, r) in self.rows.iter().enumerate() {
            let key = ((r.f_target_ghz * 1e4) as u64, (r.util * 1e4) as u64);
            if test_knobs.contains(&key) {
                split.test.push(i);
            } else {
                split.train.push(i);
            }
        }
        split
    }

    /// Unseen-architecture split (paper §7.2): architectures are
    /// disjoint between train and test; backend points shared.
    pub fn split_unseen_arch(&self, test_frac: f64, seed: u64) -> Split {
        let mut archs: Vec<usize> = (0..self.archs.len()).collect();
        let mut rng = Rng::new(seed ^ 0xA2C4);
        rng.shuffle(&mut archs);
        let n_test = ((archs.len() as f64 * test_frac).round() as usize).max(1);
        let test_archs: BTreeSet<usize> = archs.into_iter().take(n_test).collect();
        let mut split = Split::default();
        for (i, r) in self.rows.iter().enumerate() {
            if test_archs.contains(&r.arch_idx) {
                split.test.push(i);
            } else {
                split.train.push(i);
            }
        }
        split
    }

    /// Carve a validation set out of a split's training part (used for
    /// early stopping / hyperparameter selection, paper §7.3).
    pub fn carve_validation(&self, split: &mut Split, val_frac: f64, seed: u64) {
        let mut idx = std::mem::take(&mut split.train);
        let mut rng = Rng::new(seed ^ 0x7A11);
        rng.shuffle(&mut idx);
        let n_val = ((idx.len() as f64 * val_frac).round() as usize).max(1);
        split.val = idx.split_off(idx.len() - n_val);
        split.train = idx;
    }

    /// CSV persistence (features + targets; LHGs are regenerated from
    /// the stored architectural configs on load).
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        let mut out = String::new();
        out.push_str("arch_idx,f_target,util");
        for i in 0..FEAT_DIM {
            out.push_str(&format!(",x{i}"));
        }
        out.push_str(",power,perf,area,energy,runtime,in_roi\n");
        for r in &self.rows {
            out.push_str(&format!("{},{},{}", r.arch_idx, r.f_target_ghz, r.util));
            for v in r.features {
                out.push_str(&format!(",{v}"));
            }
            out.push_str(&format!(
                ",{},{},{},{},{},{}\n",
                r.power_w,
                r.f_effective_ghz,
                r.area_mm2,
                r.energy_j,
                r.runtime_s,
                r.in_roi as u8
            ));
        }
        std::fs::write(path, out).with_context(|| format!("writing {}", path.display()))
    }
}

#[cfg(test)]
pub(crate) mod test_fixtures {
    use super::*;
    use crate::backend::Enablement;

    /// A tiny synthetic dataset: 4 archs x 6 backend points.
    pub fn tiny() -> Dataset {
        let p = Platform::Axiline;
        let space = p.param_space();
        let archs: Vec<ArchConfig> = (0..4)
            .map(|i| {
                ArchConfig::new(
                    p,
                    space
                        .iter()
                        .map(|s| s.kind.from_unit(0.2 + 0.2 * i as f64))
                        .collect(),
                )
            })
            .collect();
        let lhgs = archs
            .iter()
            .map(|a| Lhg::from_tree(&p.generate(a).unwrap()))
            .collect();
        let mut rows = Vec::new();
        for (ai, _) in archs.iter().enumerate() {
            for bi in 0..6 {
                let ft = 0.4 + 0.3 * bi as f64;
                let util = 0.4 + 0.05 * bi as f64;
                let mut features = [0.0; FEAT_DIM];
                features[0] = ai as f64 / 4.0;
                features[12] = ft;
                features[13] = util;
                rows.push(Row {
                    arch_idx: ai,
                    features,
                    f_target_ghz: ft,
                    util,
                    power_w: 1.0 + ai as f64 + ft,
                    f_effective_ghz: ft * 0.95,
                    area_mm2: 0.5 + 0.1 * ai as f64,
                    energy_j: 0.01 * (1.0 + ai as f64),
                    runtime_s: 0.001 / ft,
                    in_roi: bi % 5 != 0,
                });
            }
        }
        Dataset { platform: p, enablement: Enablement::Gf12, archs, lhgs, rows }
    }
}

#[cfg(test)]
mod tests {
    use super::test_fixtures::tiny;
    use super::*;

    #[test]
    fn unseen_backend_split_separates_knobs() {
        let d = tiny();
        let s = d.split_unseen_backend(0.3, 1);
        s.validate(d.len()).unwrap();
        assert_eq!(s.train.len() + s.test.len(), d.len());
        let train_knobs: BTreeSet<u64> =
            s.train.iter().map(|&i| (d.rows[i].f_target_ghz * 1e4) as u64).collect();
        for &i in &s.test {
            let k = (d.rows[i].f_target_ghz * 1e4) as u64;
            assert!(!train_knobs.contains(&k), "knob leak {k}");
        }
    }

    #[test]
    fn unseen_arch_split_separates_archs() {
        let d = tiny();
        let s = d.split_unseen_arch(0.25, 2);
        s.validate(d.len()).unwrap();
        let train_archs: BTreeSet<usize> =
            s.train.iter().map(|&i| d.rows[i].arch_idx).collect();
        let test_archs: BTreeSet<usize> =
            s.test.iter().map(|&i| d.rows[i].arch_idx).collect();
        assert!(train_archs.is_disjoint(&test_archs));
        assert!(!test_archs.is_empty());
    }

    #[test]
    fn carve_validation_is_disjoint_and_complete() {
        let d = tiny();
        let mut s = d.split_unseen_arch(0.25, 2);
        let before = s.train.len();
        d.carve_validation(&mut s, 0.2, 3);
        s.validate(d.len()).unwrap();
        assert_eq!(s.train.len() + s.val.len(), before);
        assert!(!s.val.is_empty());
    }

    #[test]
    fn roi_subset_filters() {
        let d = tiny();
        let all: Vec<usize> = (0..d.len()).collect();
        let roi = d.roi_subset(&all);
        assert!(roi.len() < d.len());
        assert!(roi.iter().all(|&i| d.rows[i].in_roi));
    }

    #[test]
    fn csv_roundtrip_shape() {
        let d = tiny();
        let tmp = std::env::temp_dir().join("fso_test_dataset.csv");
        d.write_csv(&tmp).unwrap();
        let text = std::fs::read_to_string(&tmp).unwrap();
        assert_eq!(text.lines().count(), d.len() + 1);
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn split_validate_catches_overlap() {
        let s = Split { train: vec![0, 1], val: vec![1], test: vec![2] };
        assert!(s.validate(3).is_err());
        let s2 = Split { train: vec![0], val: vec![], test: vec![5] };
        assert!(s2.validate(3).is_err());
    }
}
