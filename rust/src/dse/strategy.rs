//! The optimizer seam of the DSE stack: every proposal engine implements
//! [`DseStrategy`], and `DseDriver` only ever talks to the trait.
//!
//! The zoo currently holds four strategies:
//!
//! - [`Motpe`] — the paper's multi-objective TPE (the default);
//! - [`RandomSearch`] — uniform prior sampling, the classic baseline;
//! - [`LhsSearch`] — block-wise maximin Latin hypercube sampling built on
//!   `sampling::Lhs`, so space-filling coverage survives an open-ended
//!   ask/tell loop;
//! - [`EvoSearch`] — a (mu+lambda) evolutionary strategy that mutates
//!   nondominated parents.
//!
//! Determinism contract: each strategy owns a private RNG stream derived
//! from the shared seed XOR a per-strategy constant, and consumes it only
//! inside `ask`. A fixed seed therefore replays the exact proposal
//! sequence for every cell of the strategy × workload × enablement grid,
//! independent of worker count, coalescing, or cache temperature.

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::generators::{ParamKind, ParamSpec};
use crate::sampling::lhs::Lhs;
use crate::util::rng::Rng;

use super::motpe::discrete_values;
use super::pareto::{nondominated_rank, pareto_front};
use super::{Motpe, MotpeConfig, Trial};

/// A multi-objective ask/tell proposal engine over a `ParamSpec` space.
///
/// The driver loop is strictly `ask_batch` → evaluate → `tell` in ask
/// order; implementations may assume tells arrive in the order points
/// were asked (that ordering is what makes pipelined runs byte-identical
/// to strict alternation).
pub trait DseStrategy {
    /// Short stable name (matches the `--strategy` flag spelling).
    fn name(&self) -> &'static str;

    /// Propose the next point to evaluate.
    fn ask(&mut self) -> Vec<f64>;

    /// Propose `n` points; defined as `n` sequential asks so batched and
    /// serial drivers see identical trajectories.
    fn ask_batch(&mut self, n: usize) -> Vec<Vec<f64>> {
        (0..n).map(|_| self.ask()).collect()
    }

    /// Record an observed outcome for an asked point.
    fn tell(&mut self, x: Vec<f64>, objectives: Vec<f64>, feasible: bool);

    /// Indices of recorded trials on the feasible Pareto front.
    fn pareto_trials(&self) -> Vec<usize>;

    /// All recorded trials, in tell order.
    fn trials(&self) -> &[Trial];
}

impl DseStrategy for Motpe {
    fn name(&self) -> &'static str {
        "motpe"
    }

    fn ask(&mut self) -> Vec<f64> {
        Motpe::ask(self)
    }

    fn ask_batch(&mut self, n: usize) -> Vec<Vec<f64>> {
        Motpe::ask_batch(self, n)
    }

    fn tell(&mut self, x: Vec<f64>, objectives: Vec<f64>, feasible: bool) {
        Motpe::tell(self, x, objectives, feasible)
    }

    fn pareto_trials(&self) -> Vec<usize> {
        Motpe::pareto_trials(self)
    }

    fn trials(&self) -> &[Trial] {
        &self.trials
    }
}

/// Feasible Pareto-front indices over a raw trial log (shared by the
/// non-TPE strategies; mirrors `Motpe::pareto_trials`).
fn feasible_pareto(trials: &[Trial]) -> Vec<usize> {
    let feasible: Vec<usize> =
        (0..trials.len()).filter(|&i| trials[i].feasible).collect();
    let objs: Vec<Vec<f64>> =
        feasible.iter().map(|&i| trials[i].objectives.clone()).collect();
    pareto_front(&objs).into_iter().map(|k| feasible[k]).collect()
}

fn prior_point(space: &[ParamSpec], rng: &mut Rng) -> Vec<f64> {
    space.iter().map(|s| s.kind.from_unit(rng.f64())).collect()
}

/// Uniform prior sampling. Every ask is an independent draw from the
/// parameter space; the trial log exists only for `pareto_trials`.
pub struct RandomSearch {
    space: Vec<ParamSpec>,
    trials: Vec<Trial>,
    rng: Rng,
}

impl RandomSearch {
    pub fn new(space: Vec<ParamSpec>, seed: u64) -> RandomSearch {
        RandomSearch { space, trials: Vec::new(), rng: Rng::new(seed ^ 0x52_41_4E_44) }
    }
}

impl DseStrategy for RandomSearch {
    fn name(&self) -> &'static str {
        "random"
    }

    fn ask(&mut self) -> Vec<f64> {
        prior_point(&self.space, &mut self.rng)
    }

    fn tell(&mut self, x: Vec<f64>, objectives: Vec<f64>, feasible: bool) {
        self.trials.push(Trial { x, objectives, feasible });
    }

    fn pareto_trials(&self) -> Vec<usize> {
        feasible_pareto(&self.trials)
    }

    fn trials(&self) -> &[Trial] {
        &self.trials
    }
}

/// Block-wise Latin hypercube sampling. `sampling::Lhs` produces a
/// fixed-size maximin design per call, so an open-ended ask stream is
/// served in blocks of [`LhsSearch::BLOCK`] points, each block seeded
/// from its own forked stream. Coverage is stratified within every
/// block and the sequence depends only on (seed, ask count).
pub struct LhsSearch {
    space: Vec<ParamSpec>,
    trials: Vec<Trial>,
    seed: u64,
    next_block: u64,
    buf: VecDeque<Vec<f64>>,
}

impl LhsSearch {
    /// Points per maximin design block.
    pub const BLOCK: usize = 16;

    pub fn new(space: Vec<ParamSpec>, seed: u64) -> LhsSearch {
        LhsSearch {
            space,
            trials: Vec::new(),
            seed,
            next_block: 0,
            buf: VecDeque::new(),
        }
    }

    fn refill(&mut self) {
        let block_seed = Rng::new(self.seed ^ 0x4C_48_53).fork(self.next_block).next_u64();
        self.next_block += 1;
        let unit = Lhs::new(self.space.len(), block_seed).sample(Self::BLOCK);
        for row in unit {
            let x: Vec<f64> = row
                .iter()
                .zip(&self.space)
                .map(|(u, s)| s.kind.from_unit(*u))
                .collect();
            self.buf.push_back(x);
        }
    }
}

impl DseStrategy for LhsSearch {
    fn name(&self) -> &'static str {
        "lhs"
    }

    fn ask(&mut self) -> Vec<f64> {
        if self.buf.is_empty() {
            self.refill();
        }
        self.buf.pop_front().expect("refilled block is non-empty")
    }

    fn tell(&mut self, x: Vec<f64>, objectives: Vec<f64>, feasible: bool) {
        self.trials.push(Trial { x, objectives, feasible });
    }

    fn pareto_trials(&self) -> Vec<usize> {
        feasible_pareto(&self.trials)
    }

    fn trials(&self) -> &[Trial] {
        &self.trials
    }
}

/// A (mu+lambda) evolutionary strategy: the parent pool is the best `mu`
/// trials of the whole history ranked by nondominated sort (feasible
/// trials only — the plus-selection union of parents and offspring),
/// and each ask mutates a uniformly chosen parent. Floats get Gaussian
/// perturbation scaled to `sigma` of the range; discrete dimensions
/// resample uniformly with a small probability. Until `n_startup` tells
/// have arrived (and with a small exploration probability afterwards)
/// asks fall back to the uniform prior.
pub struct EvoSearch {
    space: Vec<ParamSpec>,
    trials: Vec<Trial>,
    rng: Rng,
    /// Parent pool size (the "mu" of mu+lambda).
    pub mu: usize,
    /// Random-prior warmup budget before selection kicks in.
    pub n_startup: usize,
    /// Gaussian mutation scale as a fraction of each Float range.
    pub sigma: f64,
}

impl EvoSearch {
    /// Probability an ask ignores the parents and explores the prior.
    const P_EXPLORE: f64 = 0.10;
    /// Probability a discrete dimension resamples instead of inheriting.
    const P_DISCRETE_FLIP: f64 = 0.25;

    pub fn new(space: Vec<ParamSpec>, cfg: &MotpeConfig) -> EvoSearch {
        EvoSearch {
            space,
            trials: Vec::new(),
            rng: Rng::new(cfg.seed ^ 0x45_56_4F),
            mu: 8,
            n_startup: cfg.n_startup,
            sigma: 0.12,
        }
    }

    /// Best-`mu` feasible trial indices by nondominated rank (ties broken
    /// by tell order, which keeps selection deterministic).
    fn parents(&self) -> Vec<usize> {
        let feasible: Vec<usize> =
            (0..self.trials.len()).filter(|&i| self.trials[i].feasible).collect();
        if feasible.is_empty() {
            return Vec::new();
        }
        let objs: Vec<Vec<f64>> =
            feasible.iter().map(|&i| self.trials[i].objectives.clone()).collect();
        let ranks = nondominated_rank(&objs);
        let mut order: Vec<usize> = (0..feasible.len()).collect();
        order.sort_by_key(|&k| (ranks[k], k));
        order.into_iter().take(self.mu).map(|k| feasible[k]).collect()
    }

    fn mutate(&mut self, parent: &[f64]) -> Vec<f64> {
        let mut child = Vec::with_capacity(self.space.len());
        for (d, spec) in self.space.iter().enumerate() {
            let v = match &spec.kind {
                ParamKind::Float { lo, hi } => {
                    let step = self.sigma * (hi - lo) * self.rng.normal();
                    (parent[d] + step).clamp(*lo, *hi)
                }
                kind => {
                    if self.rng.bool(Self::P_DISCRETE_FLIP) {
                        let vals = discrete_values(kind);
                        vals[self.rng.below(vals.len())]
                    } else {
                        parent[d]
                    }
                }
            };
            child.push(v);
        }
        child
    }
}

impl DseStrategy for EvoSearch {
    fn name(&self) -> &'static str {
        "evo"
    }

    fn ask(&mut self) -> Vec<f64> {
        if self.trials.len() < self.n_startup || self.rng.bool(Self::P_EXPLORE) {
            return prior_point(&self.space, &mut self.rng);
        }
        let parents = self.parents();
        if parents.is_empty() {
            return prior_point(&self.space, &mut self.rng);
        }
        let pick = parents[self.rng.below(parents.len())];
        let parent = self.trials[pick].x.clone();
        self.mutate(&parent)
    }

    fn tell(&mut self, x: Vec<f64>, objectives: Vec<f64>, feasible: bool) {
        self.trials.push(Trial { x, objectives, feasible });
    }

    fn pareto_trials(&self) -> Vec<usize> {
        feasible_pareto(&self.trials)
    }

    fn trials(&self) -> &[Trial] {
        &self.trials
    }
}

/// Name-addressable constructor for the strategy zoo (the `--strategy`
/// CLI axis). `build` hands out a fresh strategy, so every run of a grid
/// cell starts from the same per-strategy RNG stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrategyKind {
    Motpe,
    Random,
    Lhs,
    Evo,
}

impl StrategyKind {
    pub const ALL: [StrategyKind; 4] =
        [StrategyKind::Motpe, StrategyKind::Random, StrategyKind::Lhs, StrategyKind::Evo];

    pub fn name(self) -> &'static str {
        match self {
            StrategyKind::Motpe => "motpe",
            StrategyKind::Random => "random",
            StrategyKind::Lhs => "lhs",
            StrategyKind::Evo => "evo",
        }
    }

    pub fn from_name(name: &str) -> Result<StrategyKind> {
        match name {
            "motpe" => Ok(StrategyKind::Motpe),
            "random" => Ok(StrategyKind::Random),
            "lhs" => Ok(StrategyKind::Lhs),
            "evo" => Ok(StrategyKind::Evo),
            other => {
                let names: Vec<&str> = Self::ALL.iter().map(|k| k.name()).collect();
                bail!("unknown DSE strategy {:?} (available: {})", other, names.join(", "))
            }
        }
    }

    /// Build a fresh strategy over `space`. The `MotpeConfig` doubles as
    /// the shared strategy config: every strategy derives its RNG stream
    /// from `cfg.seed`, and `n_startup` bounds warmup where applicable.
    pub fn build(self, space: Vec<ParamSpec>, cfg: &MotpeConfig) -> Box<dyn DseStrategy> {
        match self {
            StrategyKind::Motpe => Box::new(Motpe::new(space, cfg.clone())),
            StrategyKind::Random => Box::new(RandomSearch::new(space, cfg.seed)),
            StrategyKind::Lhs => Box::new(LhsSearch::new(space, cfg.seed)),
            StrategyKind::Evo => Box::new(EvoSearch::new(space, cfg)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::stratum;

    fn space2d() -> Vec<ParamSpec> {
        vec![
            ParamSpec { name: "a", kind: ParamKind::Float { lo: 0.0, hi: 1.0 } },
            ParamSpec { name: "b", kind: ParamKind::Float { lo: 0.0, hi: 1.0 } },
        ]
    }

    fn mixed_space() -> Vec<ParamSpec> {
        vec![
            ParamSpec { name: "f", kind: ParamKind::Float { lo: -2.0, hi: 3.0 } },
            ParamSpec { name: "i", kind: ParamKind::Int { lo: 4, hi: 9 } },
            ParamSpec { name: "c", kind: ParamKind::Choice(vec![8.0, 16.0, 32.0]) },
            ParamSpec { name: "k", kind: ParamKind::Cat(vec!["x", "y"]) },
        ]
    }

    fn eval(p: &[f64]) -> Vec<f64> {
        vec![p[0], 1.0 - p[0] + (p[1] - 0.5).abs()]
    }

    fn legal(space: &[ParamSpec], x: &[f64]) {
        assert_eq!(x.len(), space.len());
        for (v, s) in x.iter().zip(space) {
            match &s.kind {
                ParamKind::Float { lo, hi } => assert!(*v >= *lo && *v <= *hi),
                kind => assert!(
                    discrete_values(kind).iter().any(|d| (d - v).abs() < 1e-9),
                    "illegal discrete value {v} for {}",
                    s.name
                ),
            }
        }
    }

    fn drive(kind: StrategyKind, seed: u64, n: usize) -> Vec<Vec<f64>> {
        let cfg = MotpeConfig { seed, n_startup: 8, ..Default::default() };
        let mut s = kind.build(mixed_space(), &cfg);
        let mut asked = Vec::new();
        for _ in 0..n {
            let x = s.ask();
            legal(&mixed_space(), &x);
            let objs = vec![x[0], -x[0] + x[1]];
            let feasible = x[1] < 8.0;
            s.tell(x.clone(), objs, feasible);
            asked.push(x);
        }
        asked
    }

    #[test]
    fn every_strategy_is_deterministic_and_legal() {
        for kind in StrategyKind::ALL {
            let a = drive(kind, 11, 40);
            let b = drive(kind, 11, 40);
            assert_eq!(a, b, "{} replay diverged", kind.name());
        }
    }

    #[test]
    fn strategies_use_distinct_rng_streams() {
        let cfg = MotpeConfig { seed: 11, ..Default::default() };
        let firsts: Vec<Vec<f64>> = StrategyKind::ALL
            .iter()
            .map(|k| k.build(space2d(), &cfg).ask())
            .collect();
        for i in 0..firsts.len() {
            for j in (i + 1)..firsts.len() {
                assert_ne!(
                    firsts[i], firsts[j],
                    "{} and {} opened with the same point",
                    StrategyKind::ALL[i].name(),
                    StrategyKind::ALL[j].name()
                );
            }
        }
    }

    #[test]
    fn ask_batch_matches_sequential_asks_for_all_strategies() {
        for kind in StrategyKind::ALL {
            let cfg = MotpeConfig { seed: 3, n_startup: 4, ..Default::default() };
            let mut batched = kind.build(space2d(), &cfg);
            let mut serial = kind.build(space2d(), &cfg);
            for _ in 0..3 {
                let xs = batched.ask_batch(5);
                let ys: Vec<Vec<f64>> = (0..5).map(|_| serial.ask()).collect();
                assert_eq!(xs, ys, "{} batch != serial", kind.name());
                for x in xs {
                    let o = eval(&x);
                    batched.tell(x.clone(), o.clone(), true);
                    serial.tell(x, o, true);
                }
            }
        }
    }

    #[test]
    fn lhs_first_block_is_stratified_per_dimension() {
        let mut s = LhsSearch::new(space2d(), 5);
        let pts: Vec<Vec<f64>> = (0..LhsSearch::BLOCK).map(|_| s.ask()).collect();
        for d in 0..2 {
            let mut bins: Vec<usize> =
                pts.iter().map(|p| stratum(p[d], LhsSearch::BLOCK)).collect();
            bins.sort_unstable();
            assert_eq!(bins, (0..LhsSearch::BLOCK).collect::<Vec<_>>());
        }
    }

    #[test]
    fn evo_concentrates_near_the_front_after_warmup() {
        let cfg = MotpeConfig { seed: 9, n_startup: 12, ..Default::default() };
        let mut evo = EvoSearch::new(space2d(), &cfg);
        let mut late_hits = 0usize;
        for i in 0..120 {
            let x = evo.ask();
            let o = eval(&x);
            // Only points with b near 0.5 sit near the front; count how
            // often the strategy proposes them late in the run.
            if i >= 60 && (x[1] - 0.5).abs() < 0.2 {
                late_hits += 1;
            }
            evo.tell(x, o, true);
        }
        // Uniform sampling lands in the band 40% of the time; the ES
        // exploiting nondominated parents should do clearly better.
        assert!(late_hits > 33, "only {late_hits}/60 late proposals near the front");
    }

    #[test]
    fn pareto_trials_are_nondominated_for_non_tpe_strategies(
    ) {
        for kind in [StrategyKind::Random, StrategyKind::Lhs, StrategyKind::Evo] {
            let cfg = MotpeConfig { seed: 17, n_startup: 8, ..Default::default() };
            let mut s = kind.build(space2d(), &cfg);
            for i in 0..60 {
                let x = s.ask();
                let o = eval(&x);
                s.tell(x, o, i % 5 != 0);
            }
            let front = s.pareto_trials();
            assert!(!front.is_empty());
            let trials = s.trials();
            for &i in &front {
                assert!(trials[i].feasible, "{}: infeasible trial on front", kind.name());
                for &j in &front {
                    assert!(
                        !crate::dse::dominates(&trials[j].objectives, &trials[i].objectives),
                        "{}: front point dominated",
                        kind.name()
                    );
                }
            }
        }
    }

    #[test]
    fn unknown_strategy_name_lists_available() {
        let err = StrategyKind::from_name("annealing").unwrap_err().to_string();
        assert!(err.contains("annealing"));
        for k in StrategyKind::ALL {
            assert!(err.contains(k.name()), "error should list {}", k.name());
        }
        for k in StrategyKind::ALL {
            assert_eq!(StrategyKind::from_name(k.name()).unwrap(), k);
        }
    }
}
