//! Multi-Objective Tree-structured Parzen Estimator (paper §5.5,
//! following Ozaki et al. GECCO'20): observations are split into "good"
//! (G) and "bad" (B) sets by non-dominated rank; per-dimension Parzen
//! estimators l(x) (over G) and g(x) (over B) are built — Gaussian KDE
//! for continuous knobs, smoothed categoricals for discrete ones — and
//! each iteration proposes the candidate maximizing the acquisition
//! l(x)/g(x), drawn from l. Handles the mixed discrete/continuous spaces
//! of accelerator DSE natively (the paper's stated reason for MOTPE).

use crate::generators::{ParamKind, ParamSpec};
use crate::util::rng::Rng;

use super::pareto::nondominated_rank;

#[derive(Debug, Clone)]
pub struct MotpeConfig {
    /// Random startup trials before the model kicks in.
    pub n_startup: usize,
    /// Candidates drawn from l(x) per iteration.
    pub n_candidates: usize,
    /// Good-set quantile gamma.
    pub gamma: f64,
    pub seed: u64,
}

impl Default for MotpeConfig {
    fn default() -> Self {
        // gamma follows Optuna's selective default: |G| = min(ceil(0.1 n), 25).
        // A larger gamma dilutes the good set with tied mediocre trials
        // and the categorical estimators lock onto the wrong mode.
        MotpeConfig { n_startup: 24, n_candidates: 48, gamma: 0.10, seed: 7 }
    }
}

/// One recorded trial: knob vector (legal values) + objectives
/// (minimized) + feasibility (constraint flag, paper §8.4).
#[derive(Debug, Clone)]
pub struct Trial {
    pub x: Vec<f64>,
    pub objectives: Vec<f64>,
    pub feasible: bool,
}

pub struct Motpe {
    pub space: Vec<ParamSpec>,
    pub cfg: MotpeConfig,
    pub trials: Vec<Trial>,
    rng: Rng,
}

impl Motpe {
    pub fn new(space: Vec<ParamSpec>, cfg: MotpeConfig) -> Motpe {
        let rng = Rng::new(cfg.seed ^ 0x307_9E5);
        Motpe { space, cfg, trials: Vec::new(), rng }
    }

    pub fn tell(&mut self, x: Vec<f64>, objectives: Vec<f64>, feasible: bool) {
        self.trials.push(Trial { x, objectives, feasible });
    }

    fn random_point(&mut self) -> Vec<f64> {
        self.space
            .iter()
            .map(|s| {
                let u = self.rng.f64();
                s.kind.from_unit(u)
            })
            .collect()
    }

    /// Split trials into good/bad indices: infeasible trials are always
    /// bad; feasible ones sort by non-dominated rank and the best
    /// ceil(gamma * n) become G.
    fn split(&self) -> (Vec<usize>, Vec<usize>) {
        let feasible: Vec<usize> = (0..self.trials.len())
            .filter(|&i| self.trials[i].feasible)
            .collect();
        let infeasible: Vec<usize> = (0..self.trials.len())
            .filter(|&i| !self.trials[i].feasible)
            .collect();
        if feasible.is_empty() {
            return (Vec::new(), infeasible);
        }
        let objs: Vec<Vec<f64>> =
            feasible.iter().map(|&i| self.trials[i].objectives.clone()).collect();
        let ranks = nondominated_rank(&objs);
        let mut order: Vec<usize> = (0..feasible.len()).collect();
        order.sort_by_key(|&k| ranks[k]);
        let n_good = ((feasible.len() as f64 * self.cfg.gamma).ceil() as usize)
            .clamp(1, 25)
            .min(feasible.len());
        let good: Vec<usize> = order[..n_good].iter().map(|&k| feasible[k]).collect();
        let mut bad: Vec<usize> = order[n_good..].iter().map(|&k| feasible[k]).collect();
        bad.extend(infeasible);
        (good, bad)
    }

    /// log-density of `v` in dimension `d` under the Parzen estimator
    /// built from trials `set`.
    fn log_density(&self, d: usize, v: f64, set: &[usize]) -> f64 {
        match &self.space[d].kind {
            ParamKind::Float { lo, hi } => {
                let range = (hi - lo).max(1e-12);
                // Scott-ish bandwidth with a uniform prior component
                let bw = range / (set.len() as f64).powf(0.2).max(1.0) * 0.5;
                let mut acc = 1.0 / range; // prior
                for &i in set {
                    let z = (v - self.trials[i].x[d]) / bw;
                    acc += (-0.5 * z * z).exp() / (bw * (2.0 * std::f64::consts::PI).sqrt());
                }
                (acc / (set.len() as f64 + 1.0)).ln()
            }
            kind => {
                // discrete: smoothed categorical over the legal values
                let values = discrete_values(kind);
                let k = values.len() as f64;
                let mut count = 1.0; // Laplace smoothing
                for &i in set {
                    if close(self.trials[i].x[d], v) {
                        count += 1.0;
                    }
                }
                (count / (set.len() as f64 + k)).ln()
            }
        }
    }

    /// Sample dimension `d` from the good-set Parzen estimator.
    fn sample_dim(&mut self, d: usize, good: &[usize]) -> f64 {
        let kind = self.space[d].kind.clone();
        match kind {
            ParamKind::Float { lo, hi } => {
                if good.is_empty() || self.rng.bool(0.2) {
                    return self.rng.range(lo, hi);
                }
                let i = good[self.rng.below(good.len())];
                let center = self.trials[i].x[d];
                let bw = (hi - lo) / (good.len() as f64).powf(0.2).max(1.0) * 0.5;
                (center + bw * self.rng.normal()).clamp(lo, hi)
            }
            ref k => {
                let values = discrete_values(k);
                if good.is_empty() || self.rng.bool(0.2) {
                    return values[self.rng.below(values.len())];
                }
                // draw from smoothed empirical distribution
                let mut weights: Vec<f64> = values
                    .iter()
                    .map(|&v| {
                        1.0 + good
                            .iter()
                            .filter(|&&i| close(self.trials[i].x[d], v))
                            .count() as f64
                    })
                    .collect();
                let total: f64 = weights.iter().sum();
                for w in &mut weights {
                    *w /= total;
                }
                let mut u = self.rng.f64();
                for (v, w) in values.iter().zip(weights.iter()) {
                    if u < *w {
                        return *v;
                    }
                    u -= w;
                }
                *values.last().unwrap()
            }
        }
    }

    /// Propose the next configuration to evaluate.
    pub fn ask(&mut self) -> Vec<f64> {
        if self.trials.len() < self.cfg.n_startup {
            return self.random_point();
        }
        // Trial-level epsilon-exploration: candidate-level randomness
        // alone cannot escape a locked-in categorical mode, because the
        // l/g argmax rejects unexplored values before they are ever
        // *evaluated* (they have no good-set mass yet).
        if self.rng.bool(0.15) {
            return self.random_point();
        }
        let (good, bad) = self.split();
        if good.is_empty() {
            return self.random_point();
        }
        let mut best: Option<(f64, Vec<f64>)> = None;
        for _ in 0..self.cfg.n_candidates {
            let cand: Vec<f64> =
                (0..self.space.len()).map(|d| self.sample_dim(d, &good)).collect();
            let mut score = 0.0;
            for (d, &v) in cand.iter().enumerate() {
                score += self.log_density(d, v, &good) - self.log_density(d, v, &bad);
            }
            if best.as_ref().map(|(s, _)| score > *s).unwrap_or(true) {
                best = Some((score, cand));
            }
        }
        match best {
            Some((_, cand)) => cand,
            // empty candidate set (e.g. a zero candidate budget):
            // fall back to a prior sample instead of panicking —
            // ISSUE 3 satellite regression for `best.unwrap()`
            None => self.random_point(),
        }
    }

    /// Propose `n` configurations without intermediate observations
    /// (synchronous batched DSE: the caller scores the whole batch
    /// through the evaluation service, then `tell`s every result).
    /// `ask_batch(1)` is exactly one `ask`, so batch size 1 reproduces
    /// the serial ask/tell trajectory.
    pub fn ask_batch(&mut self, n: usize) -> Vec<Vec<f64>> {
        (0..n).map(|_| self.ask()).collect()
    }

    /// Current feasible Pareto front as (trial index, objectives).
    pub fn pareto_trials(&self) -> Vec<usize> {
        let feasible: Vec<usize> = (0..self.trials.len())
            .filter(|&i| self.trials[i].feasible)
            .collect();
        if feasible.is_empty() {
            return Vec::new();
        }
        let objs: Vec<Vec<f64>> =
            feasible.iter().map(|&i| self.trials[i].objectives.clone()).collect();
        super::pareto::pareto_front(&objs)
            .into_iter()
            .map(|k| feasible[k])
            .collect()
    }
}

pub(crate) fn discrete_values(kind: &ParamKind) -> Vec<f64> {
    match kind {
        ParamKind::Int { lo, hi } => (*lo..=*hi).map(|v| v as f64).collect(),
        ParamKind::Choice(vs) => vs.clone(),
        ParamKind::Cat(names) => (0..names.len()).map(|i| i as f64).collect(),
        ParamKind::Float { .. } => unreachable!("continuous"),
    }
}

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() < 1e-9
}

#[cfg(test)]
mod tests {
    use super::*;

    fn space2d() -> Vec<ParamSpec> {
        vec![
            ParamSpec { name: "x", kind: ParamKind::Float { lo: 0.0, hi: 1.0 } },
            ParamSpec { name: "y", kind: ParamKind::Float { lo: 0.0, hi: 1.0 } },
        ]
    }

    /// Bi-objective test problem: f1 = x, f2 = 1 - x + |y - 0.5|
    /// Pareto front: y = 0.5, x in [0,1].
    fn eval(p: &[f64]) -> Vec<f64> {
        vec![p[0], 1.0 - p[0] + (p[1] - 0.5).abs()]
    }

    fn run(optimizer: &mut Motpe, iters: usize) -> f64 {
        for _ in 0..iters {
            let x = optimizer.ask();
            let obj = eval(&x);
            optimizer.tell(x, obj, true);
        }
        // quality: mean |y - 0.5| over the last quarter of proposals
        let tail = optimizer.trials.len() / 4;
        let last = &optimizer.trials[optimizer.trials.len() - tail..];
        last.iter().map(|t| (t.x[1] - 0.5).abs()).sum::<f64>() / tail as f64
    }

    #[test]
    fn motpe_concentrates_near_the_front() {
        let mut m = Motpe::new(space2d(), MotpeConfig { seed: 3, ..Default::default() });
        let late_err = run(&mut m, 160);
        // random search would average |y-0.5| ~= 0.25
        assert!(late_err < 0.17, "late proposals err={late_err}");
    }

    #[test]
    fn motpe_beats_random_on_same_budget() {
        let mut m = Motpe::new(space2d(), MotpeConfig { seed: 5, ..Default::default() });
        let motpe_err = run(&mut m, 160);
        let mut rng = Rng::new(5);
        let random_err = {
            let xs: Vec<f64> = (0..40).map(|_| (rng.f64() - 0.5).abs()).collect();
            xs.iter().sum::<f64>() / xs.len() as f64
        };
        assert!(motpe_err < random_err, "{motpe_err} !< {random_err}");
    }

    #[test]
    fn empty_candidate_set_falls_back_to_prior_sample() {
        // ISSUE 3 satellite regression: with the model path active and
        // no candidates drawn, ask() used to panic on best.unwrap()
        let mut m = Motpe::new(
            space2d(),
            MotpeConfig { n_startup: 2, n_candidates: 0, seed: 1, ..Default::default() },
        );
        for _ in 0..30 {
            let x = m.ask();
            assert!(x.iter().all(|v| (0.0..=1.0).contains(v)), "prior sample in range");
            let obj = eval(&x);
            m.tell(x, obj, true);
        }
        assert_eq!(m.trials.len(), 30);
    }

    #[test]
    fn infeasible_trials_never_enter_good_set() {
        let mut m = Motpe::new(space2d(), MotpeConfig::default());
        for i in 0..40 {
            let x = m.ask();
            let obj = eval(&x);
            m.tell(x, obj, i % 2 == 0);
        }
        let (good, _bad) = m.split();
        for &g in &good {
            assert!(m.trials[g].feasible);
        }
    }

    #[test]
    fn handles_discrete_dimensions() {
        let space = vec![
            ParamSpec { name: "n", kind: ParamKind::Int { lo: 1, hi: 8 } },
            ParamSpec { name: "c", kind: ParamKind::Choice(vec![4.0, 8.0, 16.0]) },
        ];
        let mut m = Motpe::new(space, MotpeConfig { seed: 1, ..Default::default() });
        // single objective: prefer n near 6 and c == 8
        for _ in 0..120 {
            let x = m.ask();
            assert!((1.0..=8.0).contains(&x[0]) && x[0].fract() == 0.0);
            assert!([4.0, 8.0, 16.0].contains(&x[1]));
            let obj = vec![(x[0] - 6.0).abs() + if x[1] == 8.0 { 0.0 } else { 1.0 }];
            m.tell(x, obj, true);
        }
        let tail = &m.trials[90..];
        let hits = tail.iter().filter(|t| t.x[1] == 8.0).count();
        assert!(hits > tail.len() / 2, "{hits}/{}", tail.len());
    }

    #[test]
    fn ask_batch_matches_sequential_asks() {
        let mut a = Motpe::new(space2d(), MotpeConfig { seed: 9, ..Default::default() });
        let mut b = Motpe::new(space2d(), MotpeConfig { seed: 9, ..Default::default() });
        let batch = a.ask_batch(5);
        let singles: Vec<Vec<f64>> = (0..5).map(|_| b.ask()).collect();
        assert_eq!(batch, singles);
    }

    #[test]
    fn pareto_trials_are_nondominated() {
        let mut m = Motpe::new(space2d(), MotpeConfig::default());
        for _ in 0..60 {
            let x = m.ask();
            let obj = eval(&x);
            m.tell(x, obj, true);
        }
        let front = m.pareto_trials();
        assert!(!front.is_empty());
        for &i in &front {
            for &j in &front {
                if i != j {
                    assert!(!super::super::pareto::dominates(
                        &m.trials[i].objectives,
                        &m.trials[j].objectives
                    ));
                }
            }
        }
    }
}
