//! Pareto dominance utilities (minimization convention throughout).

/// True iff `a` dominates `b`: no worse in every objective, strictly
/// better in at least one.
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strictly = false;
    for (x, y) in a.iter().zip(b.iter()) {
        if x > y {
            return false;
        }
        if x < y {
            strictly = true;
        }
    }
    strictly
}

/// Indices of the non-dominated subset.
pub fn pareto_front(points: &[Vec<f64>]) -> Vec<usize> {
    (0..points.len())
        .filter(|&i| {
            !points
                .iter()
                .enumerate()
                .any(|(j, p)| j != i && dominates(p, &points[i]))
        })
        .collect()
}

/// Fast non-dominated sorting (NSGA-II style): rank 0 = the front.
pub fn nondominated_rank(points: &[Vec<f64>]) -> Vec<usize> {
    let n = points.len();
    let mut dominated_by = vec![0usize; n]; // count of dominators
    let mut dominates_list: Vec<Vec<usize>> = vec![Vec::new(); n];
    for i in 0..n {
        for j in (i + 1)..n {
            if dominates(&points[i], &points[j]) {
                dominates_list[i].push(j);
                dominated_by[j] += 1;
            } else if dominates(&points[j], &points[i]) {
                dominates_list[j].push(i);
                dominated_by[i] += 1;
            }
        }
    }
    let mut rank = vec![usize::MAX; n];
    let mut current: Vec<usize> =
        (0..n).filter(|&i| dominated_by[i] == 0).collect();
    let mut r = 0;
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            rank[i] = r;
            for &j in &dominates_list[i] {
                dominated_by[j] -= 1;
                if dominated_by[j] == 0 {
                    next.push(j);
                }
            }
        }
        current = next;
        r += 1;
    }
    rank
}

/// A maintained Pareto front of (point, payload) pairs.
#[derive(Debug, Clone, Default)]
pub struct ParetoFront {
    pub objectives: Vec<Vec<f64>>,
    pub payload: Vec<usize>,
}

impl ParetoFront {
    pub fn insert(&mut self, obj: Vec<f64>, payload: usize) -> bool {
        // A point with a NaN objective is never dominated (every
        // comparison is false), so it would sit on the front forever
        // and silently poison it; ±Inf is equally meaningless as an
        // objective value. Reject non-finite points outright —
        // ISSUE 3 satellite.
        if obj.iter().any(|v| !v.is_finite()) {
            return false;
        }
        if self
            .objectives
            .iter()
            .any(|p| dominates(p, &obj) || p == &obj)
        {
            return false;
        }
        let keep: Vec<bool> =
            self.objectives.iter().map(|p| !dominates(&obj, p)).collect();
        let mut k = keep.iter();
        self.objectives.retain(|_| *k.next().unwrap());
        let mut k = keep.iter();
        self.payload.retain(|_| *k.next().unwrap());
        self.objectives.push(obj);
        self.payload.push(payload);
        true
    }

    pub fn len(&self) -> usize {
        self.objectives.len()
    }

    pub fn is_empty(&self) -> bool {
        self.objectives.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_basic() {
        assert!(dominates(&[1.0, 1.0], &[2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0], &[2.0, 2.0]));
        assert!(!dominates(&[1.0, 3.0], &[2.0, 2.0]));
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0]));
    }

    #[test]
    fn front_extraction() {
        let pts = vec![
            vec![1.0, 4.0],
            vec![2.0, 2.0],
            vec![4.0, 1.0],
            vec![3.0, 3.0], // dominated by (2,2)
            vec![5.0, 5.0], // dominated
        ];
        let f = pareto_front(&pts);
        assert_eq!(f, vec![0, 1, 2]);
    }

    #[test]
    fn ranks_are_layered() {
        let pts = vec![
            vec![1.0, 1.0],
            vec![2.0, 2.0],
            vec![3.0, 3.0],
        ];
        assert_eq!(nondominated_rank(&pts), vec![0, 1, 2]);
    }

    #[test]
    fn maintained_front_invariant() {
        let mut front = ParetoFront::default();
        let pts = vec![
            vec![3.0, 3.0],
            vec![1.0, 4.0],
            vec![2.0, 2.0], // kills (3,3)
            vec![4.0, 1.0],
            vec![2.5, 2.5], // dominated by (2,2)
        ];
        for (i, p) in pts.iter().enumerate() {
            front.insert(p.clone(), i);
        }
        assert_eq!(front.len(), 3);
        // no member dominates another
        for i in 0..front.len() {
            for j in 0..front.len() {
                if i != j {
                    assert!(!dominates(&front.objectives[i], &front.objectives[j]));
                }
            }
        }
        assert!(!front.payload.contains(&0));
        assert!(!front.payload.contains(&4));
    }

    #[test]
    fn duplicate_insert_rejected() {
        let mut front = ParetoFront::default();
        assert!(front.insert(vec![1.0, 1.0], 0));
        assert!(!front.insert(vec![1.0, 1.0], 1));
    }

    #[test]
    fn non_finite_objectives_are_rejected() {
        // ISSUE 3 satellite regression: a NaN point is never dominated,
        // so it used to enter the front and sit there forever
        let mut front = ParetoFront::default();
        assert!(!front.insert(vec![f64::NAN, 1.0], 0));
        assert!(!front.insert(vec![1.0, f64::INFINITY], 1));
        assert!(!front.insert(vec![f64::NEG_INFINITY, 1.0], 2));
        assert!(front.is_empty(), "non-finite points must never poison the front");
        // the finite path still works after rejections
        assert!(front.insert(vec![2.0, 2.0], 3));
        assert!(front.insert(vec![1.0, 1.0], 4), "dominating point replaces");
        assert_eq!(front.len(), 1);
        assert_eq!(front.payload, vec![4]);
    }
}
