//! Design space exploration (paper §5.5, §8.4): MOTPE over architectural
//! + backend knobs, Pareto-front maintenance, Eq. 3 cost selection with
//! power/runtime/ROI constraint flags.

pub mod cost;
pub mod motpe;
pub mod pareto;
pub mod strategy;

pub use cost::{select_best, Candidate, CostSpec};
pub use motpe::{Motpe, MotpeConfig, Trial};
pub use pareto::{dominates, nondominated_rank, pareto_front, ParetoFront};
pub use strategy::{DseStrategy, EvoSearch, LhsSearch, RandomSearch, StrategyKind};

/// Knobs of a DSE run (which dimensions are explored and their ranges
/// are carried by the ParamSpec space handed to Motpe).
#[derive(Debug, Clone)]
pub struct DseConfig {
    pub iterations: usize,
    pub motpe: MotpeConfig,
    pub cost: CostSpec,
}
