//! Final configuration selection (paper Eq. 3): minimize
//! alpha * Energy + beta * Area over the feasible Pareto front, subject
//! to P < P_max and T < R_max.

#[derive(Debug, Clone, Copy)]
pub struct CostSpec {
    /// Energy weight (chip lifespan proxy).
    pub alpha: f64,
    /// Area weight (fabrication cost proxy).
    pub beta: f64,
    /// Power constraint, W.
    pub p_max: f64,
    /// Runtime constraint, s.
    pub r_max: f64,
}

impl CostSpec {
    pub fn cost(&self, energy_j: f64, area_mm2: f64) -> f64 {
        self.alpha * energy_j + self.beta * area_mm2
    }

    pub fn feasible(&self, power_w: f64, runtime_s: f64) -> bool {
        power_w < self.p_max && runtime_s < self.r_max
    }
}

/// A fully-evaluated DSE candidate.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub x: Vec<f64>,
    pub energy_j: f64,
    pub runtime_s: f64,
    pub power_w: f64,
    pub area_mm2: f64,
    /// Within the predicted ROI (two-stage gate).
    pub in_roi: bool,
}

impl Candidate {
    pub fn meets(&self, spec: &CostSpec) -> bool {
        self.in_roi && spec.feasible(self.power_w, self.runtime_s)
    }
}

/// Rank feasible, Pareto-optimal candidates by Eq. 3; returns indices
/// into `candidates`, best first.
pub fn select_best(candidates: &[Candidate], spec: &CostSpec, top_k: usize) -> Vec<usize> {
    let feasible: Vec<usize> = (0..candidates.len())
        .filter(|&i| candidates[i].meets(spec))
        .collect();
    // Pareto filter on (E, A) per the paper's constraint set
    let objs: Vec<Vec<f64>> = feasible
        .iter()
        .map(|&i| vec![candidates[i].energy_j, candidates[i].area_mm2])
        .collect();
    let front = super::pareto::pareto_front(&objs);
    let mut chosen: Vec<usize> = front.into_iter().map(|k| feasible[k]).collect();
    chosen.sort_by(|&a, &b| {
        let ca = spec.cost(candidates[a].energy_j, candidates[a].area_mm2);
        let cb = spec.cost(candidates[b].energy_j, candidates[b].area_mm2);
        ca.partial_cmp(&cb).unwrap()
    });
    chosen.truncate(top_k);
    chosen
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(e: f64, a: f64, p: f64, t: f64, roi: bool) -> Candidate {
        Candidate { x: vec![], energy_j: e, runtime_s: t, power_w: p, area_mm2: a, in_roi: roi }
    }

    #[test]
    fn constraints_filter() {
        let spec = CostSpec { alpha: 1.0, beta: 1.0, p_max: 2.0, r_max: 0.1 };
        let cands = vec![
            cand(1.0, 1.0, 1.0, 0.05, true),  // ok
            cand(0.5, 0.5, 5.0, 0.05, true),  // power violation
            cand(0.5, 0.5, 1.0, 0.50, true),  // runtime violation
            cand(0.4, 0.4, 1.0, 0.05, false), // out of ROI
        ];
        let best = select_best(&cands, &spec, 3);
        assert_eq!(best, vec![0]);
    }

    #[test]
    fn cost_orders_front_members() {
        let spec = CostSpec { alpha: 1.0, beta: 0.001, p_max: 10.0, r_max: 10.0 };
        let cands = vec![
            cand(2.0, 100.0, 1.0, 0.1, true), // cost 2.1
            cand(1.0, 800.0, 1.0, 0.1, true), // cost 1.8 <- best (alpha-dominant)
            cand(3.0, 10.0, 1.0, 0.1, true),  // cost 3.01
        ];
        let best = select_best(&cands, &spec, 3);
        assert_eq!(best[0], 1);
    }

    #[test]
    fn dominated_candidates_excluded() {
        let spec = CostSpec { alpha: 1.0, beta: 1.0, p_max: 10.0, r_max: 10.0 };
        let cands = vec![
            cand(1.0, 2.0, 1.0, 0.1, true),
            cand(2.0, 3.0, 1.0, 0.1, true), // dominated by 0
            cand(2.0, 1.0, 1.0, 0.1, true),
        ];
        let best = select_best(&cands, &spec, 5);
        assert!(!best.contains(&1));
        assert_eq!(best.len(), 2);
    }
}
