//! # fso — ML-based full-stack optimization framework for ML accelerators
//!
//! Reproduction of "An Open-Source ML-Based Full-Stack Optimization
//! Framework for Machine Learning Accelerators" (Esmaeilzadeh, Ghodrati,
//! Kahng et al., 2023) as a three-layer rust + JAX + Pallas system:
//!
//! - **L3 (this crate)**: accelerator generators, logical hierarchy
//!   graphs, the backend SP&R oracle, system-level performance/energy
//!   simulators, sampling, tree-ensemble predictors, the two-stage ROI
//!   model, MOTPE design-space exploration, and the coordinator that
//!   batches prediction traffic onto AOT-compiled executables.
//! - **L2 (python/compile/model.py, build time only)**: ANN + GCN
//!   predictor graphs with Adam, lowered once to HLO text.
//! - **L1 (python/compile/kernels/, build time only)**: Pallas kernels
//!   (fused dense, graph conv, masked pooling) behind custom VJPs.
//!
//! See DESIGN.md for the system inventory and per-experiment index.

pub mod analysis;
pub mod backend;
pub mod bench;
pub mod coordinator;
pub mod data;
pub mod dse;
pub mod generators;
pub mod metrics;
pub mod models;
pub mod runtime;
pub mod sampling;
pub mod simulators;
pub mod util;
pub mod workloads;

/// Shared helpers for unit/integration tests (artifact discovery).
pub mod test_support {
    use std::path::PathBuf;

    /// Locate the artifacts directory from a test/bench context: honours
    /// $FSO_ARTIFACTS, then looks for ./artifacts upward from CWD.
    /// Returns None when artifacts have not been built (tests that need
    /// them skip themselves).
    pub fn artifacts_dir() -> Option<PathBuf> {
        if let Some(dir) = std::env::var_os("FSO_ARTIFACTS") {
            let p = PathBuf::from(dir);
            return p.join("manifest.json").exists().then_some(p);
        }
        let mut cur = std::env::current_dir().ok()?;
        loop {
            let cand = cur.join("artifacts");
            if cand.join("manifest.json").exists() {
                return Some(cand);
            }
            if !cur.pop() {
                return None;
            }
        }
    }
}

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::backend::{BackendConfig, BackendResult, Enablement, SpnrFlow};
    pub use crate::coordinator::cache_store::CacheStore;
    pub use crate::coordinator::eval_service::{EvalService, EvalStats, Evaluation};
    pub use crate::coordinator::model_store::{ModelKey, ModelStore};
    pub use crate::coordinator::predict_server::PredictServer;
    pub use crate::data::{Dataset, Row, Split};
    pub use crate::dse::{CostSpec, DseConfig, Motpe, ParetoFront};
    pub use crate::generators::{ArchConfig, Platform};
    pub use crate::metrics::{kendall_tau, mape_stats, MapeStats};
    pub use crate::models::{Predictor, TwoStageModel};
    pub use crate::runtime::{Batcher, Engine, Manifest};
    pub use crate::sampling::{Sampler, SamplerKind};
    pub use crate::simulators::SystemMetrics;
    pub use crate::util::rng::Rng;
    pub use crate::util::tensor::Tensor;
}
