//! `fso` — launcher for the full-stack ML-accelerator optimization
//! framework (paper reproduction). Subcommands:
//!
//!   fso datagen   --platform axiline --enablement gf12 [--out data.csv] [--workload NAME]
//!   fso train     --platform vta [--metric power] [--trees-only]
//!   fso dse       --target axiline-svm|vta [--strategy motpe|random|lhs|evo] [--workload NAME]
//!   fso experiment <fig1b|fig3|fig4|fig6|fig8|fig9|fig10|fig11|fig12|tab3|tab4|tab5|all>
//!   fso store     <compact|stats> --cache-dir DIR   (persistent-store maintenance)
//!   fso serve     [--tree-router] | --listen HOST:PORT   (demos / evaluation daemon)
//!   fso client    --connect HOST:PORT   (newline-JSON client for the daemon)
//!   fso fleet     lead --target T --listen ADDR | work --connect ADDR   (distributed DSE)
//!   fso bench     <run|compare|list> --suite NAME   (perf-gate suites)
//!
//! Global: --seed N, --quick, --out-dir DIR, --artifacts DIR

use std::path::PathBuf;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use fso::backend::Enablement;
use fso::coordinator::dse_driver::SurrogateBundle;
use fso::coordinator::experiments::{self, ExpOptions};
use fso::coordinator::{
    datagen, CacheStore, Codec, DatagenConfig, EvalRouter, EvalService, ModelCacheStats,
    ModelStore, PredictServer, StorePolicy, TrainOptions, Trainer,
};
use fso::data::Metric;
use fso::dse::StrategyKind;
use fso::generators::Platform;
use fso::models::ann::glorot_init;
use fso::runtime::Engine;
use fso::util::cli::Args;

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .or_else(fso::test_support::artifacts_dir)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

fn run(args: &Args) -> Result<()> {
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "datagen" => cmd_datagen(args),
        "train" => cmd_train(args),
        "dse" => cmd_dse(args),
        "experiment" => cmd_experiment(args),
        "store" => cmd_store(args),
        "serve" => cmd_serve(args),
        "client" => cmd_client(args),
        "fleet" => cmd_fleet(args),
        "bench" => cmd_bench(args),
        _ => {
            println!("{}", HELP.trim());
            Ok(())
        }
    }
}

const HELP: &str = r#"
fso — ML-based full-stack optimization framework for ML accelerators

USAGE:
  fso datagen --platform <tabla|genesys|vta|axiline> [--enablement gf12|ng45|gf12,ng45]
              [--archs N] [--out data.csv] [--seed N] [--cache-dir DIR] [--coalesce]
              [--store-codec v1|v2] [--workload NAME]
  fso train --platform <...> [--metric power|perf|area|energy|runtime]
            [--trees-only] [--seed N] [--cache-dir DIR] [--no-model-cache]
            [--report-out FILE] [--coalesce] [--workload NAME]
  fso dse --target <axiline-svm|vta> [--quick] [--cache-dir DIR] [--no-model-cache]
          [--coalesce] [--inflight N] [--strategy motpe|random|lhs|evo]
          [--workload NAME]
  fso experiment <fig1b|fig3|fig4|fig6|fig8|fig9|fig10|fig11|fig12|tab3|tab4|tab5|all>
                 [--quick] [--out-dir results] [--seed N] [--cache-dir DIR]
                 [--no-model-cache] [--coalesce] [--inflight N]
                 [--strategy motpe|random|lhs|evo] [--workload NAME]
  fso store <compact|stats> --cache-dir DIR [--store-codec v1|v2]
            [--store-max-bytes N] [--store-max-records N] [--store-max-age N]
  fso serve [--clients N] [--rows N] [--tree-router]
  fso serve --listen HOST:PORT [--seed N] [--enablement gf12|ng45]
            [--cache-dir DIR] [--quota-burst N] [--quota-rate R]
  fso client --connect HOST:PORT
  fso fleet lead --target <axiline-svm|vta> --listen HOST:PORT [--lease-ms N]
                 [--quick] [--archs N] [--iters N] [--seed N] [--out-dir DIR]
                 [--cache-dir DIR] [--strategy ...] [--workload NAME]
  fso fleet work --connect HOST:PORT [--exit-after N]
  fso bench run     --suite NAME [--quick] [--out FILE]
  fso bench compare --suite NAME --baseline FILE [--candidate FILE]
                    [--threshold 0.15] [--derived-only] [--quick] [--out FILE]
  fso bench list

A comma-separated --enablement sweeps every listed enablement through
one process (and one --cache-dir store); --out then writes one CSV per
enablement (data.csv.gf12, data.csv.ng45). --cache-dir persists SP&R
oracle results between runs: a warm start replays cached evaluations
byte-identically and reports the disk hits in the stats line. The same
directory also carries fitted surrogate models (DIR/models/): a warm
`fso train`/`fso dse` skips refitting and tuning searches entirely and
replays bit-identical reports; --no-model-cache opts out of the model
half while keeping the oracle cache.

Long-lived stores are bounded by the lifecycle flags (accepted by every
command that takes --cache-dir): --store-max-bytes / --store-max-records
cap the live records (LRU eviction at flush), --store-max-age N evicts
records whose last persisted use is more than N store openings old
(reads persist their use-stamps only in runs that carry a budget —
pass the flags on the regular runs, not just at compact time, for true
use-age). `fso store compact`
rewrites the shards dropping tombstones and dead lines — reads before
and after a compact are identical, so warm starts are unaffected —
and `fso store stats` prints both stores' counters plus a per-codec
shard/sidecar file census.

--store-codec picks the record codec *new* shard files are written in
(accepted by every command that takes --cache-dir): v1 is the original
JSONL, v2 (the default) a compact length-prefixed binary framing of
the same records. Reads auto-detect either codec per shard, so mixed
directories stay warm; flushing or compacting a touched shard
transcodes it to the active codec (`fso store compact --store-codec
v2` migrates a whole PR 6 directory in place). Each shard also carries
a `<shard>.idx` bloom + offset sidecar for point lookups — a
disposable cache, rebuilt automatically when missing, torn, or stale;
deleting every .idx is always safe.

--coalesce turns on single-flight request coalescing (ISSUE 5):
concurrent evaluations of the same content-hash key share one
in-flight SP&R-oracle+simulator run (oracle runs == unique keys under
any thread schedule), trainers memoize identical fit requests
in-process, and the DSE overlaps MOTPE proposal generation with
in-flight scoring through a batching router (--inflight bounds the
scoring pipeline depth, default 4). Results are byte-identical to the
serial path at the same seed — only wall-clock and CPU time change.
`fso serve --tree-router` demos the cross-client router on the
tree-family surrogate (no PJRT artifacts needed).

`fso serve --listen HOST:PORT` runs the multi-tenant evaluation daemon:
a long-lived process speaking newline-delimited JSON over plain TCP
(one request document per line; see the README "Evaluation daemon"
section for the protocol grammar and endpoint table). Ops: health,
stats, predict (surrogate scores through the shared mega-batching
router), eval (ground truth through the memoized single-flight oracle),
shutdown (graceful drain). Port 0 binds an ephemeral port; the daemon
prints `listening on ADDR` to stdout. --cache-dir persists oracle
results across daemon restarts exactly as it does for batch runs.
--quota-burst/--quota-rate set the per-connection token bucket: an
exhausted bucket answers code 429 immediately — never a hang. SIGTERM
and the shutdown op share one drain path: received requests complete,
the listener stops accepting, the stores flush. With a fixed --seed,
any number of concurrent clients get byte-identical response lines and
flushed shard files. `fso client --connect ADDR` bridges stdin request
lines to response lines on stdout.

--strategy picks the optimizer driving `fso dse` and the DSE
experiments: motpe (the default, the paper's MO-TPE), random (seeded
uniform), lhs (blocked maximin Latin hypercube), evo (mu+lambda
mutation over the running Pareto set). --workload picks any registry
workload by name — mobilenet, resnet50, transformer, gcn on the DNN
platforms (GeneSys/VTA); svm, linear_regression, logistic_regression,
recsys, backprop on TABLA/Axiline — for datagen, train, dse, and the
experiments; unknown names list the registry. Every (strategy,
workload, enablement) cell keeps the determinism contract: a fixed
--seed yields byte-identical rows and Pareto fronts at any worker
count, with or without --coalesce, cold or warm --cache-dir.

`fso fleet` scales a DSE run across processes (ISSUE 10): `fso fleet
lead` runs the full experiment (same targets as `fso dse`) but ships
every full oracle miss — memo cold AND store cold — to worker
processes over the daemon protocol's claim/result/heartbeat ops, while
keeping the strategy loop, single-flight table, and stores (--cache-dir)
leader-side. `fso fleet work --connect ADDR` claims tasks under a
lease (--lease-ms on the leader), heartbeats while evaluating, and
streams back bit-exact evaluations; a worker that dies mid-task simply
has its key requeued when the lease expires. Fixed --seed + any worker
count (1, 2, 4, ...) = byte-identical CSV rows, Pareto fronts, and
flushed shard files — the single-process `fso dse` bytes. --exit-after
N makes a worker die right after its Nth claim (recovery testing).

`fso bench` drives the named perf-gate suites (see `fso bench list`):
`run` executes a suite and writes its BENCH_<suite>.json trajectory
point; `compare` runs the suite fresh (or loads --candidate) and diffs
it against --baseline, exiting nonzero when a timed row slows past
--threshold (default 15%) or a derived higher-is-better ratio drops
below it. --derived-only restricts the diff to the machine-portable
ratios — the mode for comparing against a committed baseline produced
on another machine. Suites self-check their invariants on every run
(flat_tree: flat mega-batch inference at least matches the recursive
walkers, predictions verified bit-identical before timing starts).
"#;

/// Lifecycle policy from the `--store-max-*` flags (defaults:
/// unbounded, auto-compacting once half the disk lines are dead).
fn store_policy(args: &Args) -> Result<StorePolicy> {
    let mut p = StorePolicy::default_auto();
    if let Some(v) = args.get("store-max-bytes") {
        p.max_bytes = Some(
            v.parse().with_context(|| format!("--store-max-bytes wants bytes, got {v:?}"))?,
        );
    }
    if let Some(v) = args.get("store-max-records") {
        p.max_records = Some(
            v.parse()
                .with_context(|| format!("--store-max-records wants a count, got {v:?}"))?,
        );
    }
    if let Some(v) = args.get("store-max-age") {
        p.max_age_epochs = Some(
            v.parse().with_context(|| format!("--store-max-age wants epochs, got {v:?}"))?,
        );
    }
    Ok(p)
}

/// Write codec from `--store-codec v1|v2` (default v2; reads always
/// auto-detect both, so the flag only picks what new shards look like).
fn store_codec(args: &Args) -> Result<Codec> {
    match args.get("store-codec") {
        None => Ok(Codec::V2Binary),
        Some(name) => Codec::from_name(name)
            .with_context(|| format!("--store-codec wants v1|v2, got {name:?}")),
    }
}

/// Open the persistent oracle cache named by `--cache-dir`, if given.
fn cache_store(args: &Args) -> Result<Option<Arc<CacheStore>>> {
    match args.path("cache-dir") {
        Some(dir) => Ok(Some(Arc::new(
            CacheStore::open(dir)?
                .with_policy(store_policy(args)?)
                .with_codec(store_codec(args)?),
        ))),
        None => Ok(None),
    }
}

/// Open the surrogate-model store cohabiting under `--cache-dir`
/// (`DIR/models/`), unless `--no-model-cache` opts out.
fn model_store(args: &Args) -> Result<Option<Arc<ModelStore>>> {
    if args.flag("no-model-cache") {
        return Ok(None);
    }
    match args.path("cache-dir") {
        Some(dir) => Ok(Some(Arc::new(
            ModelStore::open_under(dir)?
                .with_policy(store_policy(args)?)
                .with_codec(store_codec(args)?),
        ))),
        None => Ok(None),
    }
}

/// `fso store <compact|stats> --cache-dir DIR`: maintenance for the
/// persistent stores. Compact covers both the oracle shards and the
/// cohabiting model store (`DIR/models/`), applying any `--store-max-*`
/// budgets; stats prints both stores' counters after a full load.
fn cmd_store(args: &Args) -> Result<()> {
    let action = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .context("store action required (`fso store compact` or `fso store stats`)")?;
    let dir = args.path("cache-dir").context("--cache-dir required for `fso store`")?;
    anyhow::ensure!(dir.exists(), "no store at {}", dir.display());
    let models_dir = dir.join("models");
    match action {
        "compact" => {
            // compaction rewrites through the active codec, so
            // `--store-codec` here transcodes a whole directory in place
            let store = CacheStore::open(&dir)?
                .with_policy(store_policy(args)?)
                .with_codec(store_codec(args)?);
            println!("oracle store: {}", store.compact()?);
            if models_dir.exists() {
                let ms = ModelStore::open(&models_dir)?
                    .with_policy(store_policy(args)?)
                    .with_codec(store_codec(args)?);
                println!("model store:  {}", ms.compact()?);
            }
            Ok(())
        }
        "stats" => {
            let store = CacheStore::open(&dir)?;
            store.load_all();
            println!("oracle store ({}): {}", dir.display(), store.stats());
            println!("oracle store files: {}", codec_file_counts(&dir)?);
            if models_dir.exists() {
                let ms = ModelStore::open(&models_dir)?;
                ms.load_all();
                println!("model store ({}): {}", models_dir.display(), ms.stats());
                println!("model store files: {}", codec_file_counts(&models_dir)?);
            }
            Ok(())
        }
        other => bail!("unknown store action {other:?} (compact|stats)"),
    }
}

/// Shard-file census for `fso store stats`: how many shards sit in each
/// codec, and how many carry an `.idx` sidecar.
fn codec_file_counts(dir: &std::path::Path) -> Result<String> {
    let (mut v1, mut v2, mut idx) = (0usize, 0usize, 0usize);
    for entry in std::fs::read_dir(dir)? {
        let name = entry?.file_name();
        let name = name.to_string_lossy();
        if name.ends_with(".idx") {
            idx += 1;
        } else if name.ends_with(&format!(".{}", Codec::V1Jsonl.file_ext())) {
            v1 += 1;
        } else if name.ends_with(&format!(".{}", Codec::V2Binary.file_ext())) {
            v2 += 1;
        }
    }
    Ok(format!("{v1} v1 (jsonl) shards, {v2} v2 (fsb) shards, {idx} sidecars"))
}

fn cmd_datagen(args: &Args) -> Result<()> {
    let platform = Platform::from_name(args.get_or("platform", "axiline"))?;
    // `--enablement gf12,ng45` sweeps several enablements through
    // services sharing one cache store (and one process)
    let enablements: Vec<Enablement> = args
        .get_or("enablement", "gf12")
        .split(',')
        .map(Enablement::from_name)
        .collect::<Result<_>>()?;
    let store = cache_store(args)?;
    let mut cfgs = Vec::with_capacity(enablements.len());
    for &enablement in &enablements {
        let mut cfg = DatagenConfig::small(platform, enablement);
        cfg.n_arch = args.usize_or("archs", cfg.n_arch)?;
        cfg.seed = args.u64_or("seed", cfg.seed)?;
        cfg.coalesce = args.flag("coalesce");
        cfg.workload = args.get("workload").map(String::from);
        cfgs.push(cfg);
    }
    let t0 = std::time::Instant::now();
    let results = datagen::generate_sweep(&cfgs, store.clone())?;
    for (cfg, g) in cfgs.iter().zip(&results) {
        let tag = cfg.enablement.name();
        let in_roi = g.dataset.rows.iter().filter(|r| r.in_roi).count();
        println!(
            "[{tag}] generated {} rows ({} archs x {} backend points), {in_roi} in ROI",
            g.dataset.len(),
            g.dataset.archs.len(),
            cfg.n_backend_train + cfg.n_backend_test,
        );
        println!("[{tag}] eval service: {}", g.stats);
    }
    println!("datagen took {:.2}s", t0.elapsed().as_secs_f64());
    if let Some(out) = args.get("out") {
        if results.len() == 1 {
            results[0].dataset.write_csv(std::path::Path::new(out))?;
            println!("wrote {out}");
        } else {
            for (cfg, g) in cfgs.iter().zip(&results) {
                let path = format!("{out}.{}", cfg.enablement.name());
                g.dataset.write_csv(std::path::Path::new(&path))?;
                println!("wrote {path}");
            }
        }
    }
    if let Some(store) = &store {
        store.flush()?;
        println!("cache store: {}", store.stats());
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let platform = Platform::from_name(args.get_or("platform", "axiline"))?;
    let enablement = Enablement::from_name(args.get_or("enablement", "gf12"))?;
    let seed = args.u64_or("seed", 2023)?;
    let cfg = DatagenConfig {
        seed,
        coalesce: args.flag("coalesce"),
        workload: args.get("workload").map(String::from),
        ..DatagenConfig::small(platform, enablement)
    };
    println!("generating dataset...");
    let g = match cache_store(args)? {
        Some(store) => {
            let service = EvalService::new(cfg.enablement, cfg.seed)
                .with_workers(cfg.workers)
                .with_coalescing(cfg.coalesce)
                .with_cache_store(Arc::clone(&store));
            let g = datagen::generate_with(&service, &cfg)?;
            store.flush()?;
            println!("eval service: {}", g.stats);
            g
        }
        None => datagen::generate(&cfg)?,
    };
    let mstore = model_store(args)?;
    let trainer = if args.flag("trees-only") {
        Trainer::new(None)
    } else {
        Trainer::new(Some(Rc::new(Engine::load(&artifacts_dir(args))?)))
    }
    .with_model_store_opt(mstore.clone())
    .with_fit_coalescing_opt(args.flag("coalesce"));
    let mut opts = TrainOptions { seed, ..Default::default() };
    if args.flag("trees-only") {
        opts.menu = fso::coordinator::ModelMenu::trees_only();
    }
    let metrics: Vec<Metric> = match args.get("metric") {
        Some(name) => vec![Metric::ALL
            .into_iter()
            .find(|m| m.name() == name)
            .with_context(|| format!("unknown metric {name}"))?],
        None => Metric::ALL.to_vec(),
    };
    // the report text is accumulated separately from the cache-stats
    // lines so the CI warm-start job can byte-diff cold vs. warm
    // reports (--report-out) while still asserting the stats differ
    let mut model_cache = ModelCacheStats::default();
    let mut report_text = String::new();
    for metric in metrics {
        let report = trainer.run(&g.dataset, &g.backend_split, metric, &opts)?;
        model_cache += report.model_cache;
        let mut block = format!(
            "--- {metric} (ROI acc {:.2} / F1 {:.2}, {} eval rows) ---\n",
            report.roi.accuracy, report.roi.f1, report.eval_rows
        );
        for (model, stats) in &report.models {
            block.push_str(&format!(
                "{model:9} muAPE {:6.2}%  STD {:6.2}  MAPE {:6.2}%\n",
                stats.mu_ape, stats.std_ape, stats.max_ape
            ));
        }
        print!("{block}");
        report_text.push_str(&block);
    }
    println!("model cache: {model_cache}");
    if let Some(ms) = &mstore {
        ms.flush()?;
        println!("model store: {}", ms.stats());
    }
    if let Some(out) = args.get("report-out") {
        std::fs::write(out, &report_text)
            .with_context(|| format!("writing {out}"))?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_dse(args: &Args) -> Result<()> {
    let opts = exp_options(args)?;
    opts.ensure_out_dir()?;
    match args.get_or("target", "axiline-svm") {
        "axiline-svm" => experiments::dse::fig11_axiline_svm(&opts),
        "vta" => experiments::dse::fig12_vta(&opts),
        other => bail!("unknown DSE target {other:?}"),
    }
}

fn exp_options(args: &Args) -> Result<ExpOptions> {
    Ok(ExpOptions {
        seed: args.u64_or("seed", 2023)?,
        out_dir: PathBuf::from(args.get_or("out-dir", "results")),
        quick: args.flag("quick"),
        cache_dir: args.path("cache-dir"),
        no_model_cache: args.flag("no-model-cache"),
        store_policy: store_policy(args)?,
        coalesce: args.flag("coalesce"),
        inflight: args.usize_or("inflight", 4)?,
        strategy: StrategyKind::from_name(args.get_or("strategy", "motpe"))?,
        workload: args.get("workload").map(String::from),
        archs: opt_usize(args, "archs")?,
        iters: opt_usize(args, "iters")?,
    })
}

/// Optional integer-valued option: `None` when absent, an error when
/// present but unparseable.
fn opt_usize(args: &Args, name: &str) -> Result<Option<usize>> {
    args.get(name)
        .map(|v| v.parse().with_context(|| format!("--{name} wants an integer, got {v:?}")))
        .transpose()
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .context("experiment id required (e.g. `fso experiment tab4`)")?;
    let opts = exp_options(args)?;
    let t0 = std::time::Instant::now();
    experiments::run(id, &opts)?;
    println!("[{id}] done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

/// `fso bench <run|compare|list>`: the perf-gate CLI over
/// `fso::bench`'s named suites (see the HELP text for semantics).
fn cmd_bench(args: &Args) -> Result<()> {
    use fso::bench;
    let action = args.positional.get(1).map(|s| s.as_str()).unwrap_or("list");
    match action {
        "list" => {
            for s in bench::SUITES {
                println!("{s}  (default out: {})", bench::default_out(s));
            }
            Ok(())
        }
        "run" => {
            let suite = args.get("suite").context("--suite required for `fso bench run`")?;
            let report = bench::run_suite(suite, args.flag("quick"))?;
            print!("{}", report.render());
            bench::check_invariants(&report)?;
            let out = args
                .get("out")
                .map(String::from)
                .unwrap_or_else(|| bench::default_out(suite));
            report.save(std::path::Path::new(&out))?;
            println!("wrote {out}");
            Ok(())
        }
        "compare" => {
            let suite = args
                .get("suite")
                .context("--suite required for `fso bench compare`")?;
            let base_path = args
                .path("baseline")
                .context("--baseline required for `fso bench compare`")?;
            let baseline = bench::SuiteReport::load(&base_path)?;
            // candidate: a saved report when --candidate is given, a
            // fresh run of the suite otherwise
            let candidate = match args.path("candidate") {
                Some(p) => bench::SuiteReport::load(&p)?,
                None => {
                    let report = bench::run_suite(suite, args.flag("quick"))?;
                    bench::check_invariants(&report)?;
                    if let Some(out) = args.get("out") {
                        report.save(std::path::Path::new(out))?;
                        println!("wrote {out}");
                    }
                    report
                }
            };
            anyhow::ensure!(
                baseline.suite == suite,
                "baseline {} holds suite {:?}, not {suite:?}",
                base_path.display(),
                baseline.suite
            );
            let threshold = args.f64_or("threshold", 0.15)?;
            let cmp = bench::compare(
                &baseline,
                &candidate,
                threshold,
                args.flag("derived-only"),
            )?;
            for line in &cmp.lines {
                println!("{line}");
            }
            if cmp.regressions.is_empty() {
                println!(
                    "perf gate passed ({} checks, threshold {:.0}%)",
                    cmp.lines.len(),
                    threshold * 100.0
                );
                Ok(())
            } else {
                for r in &cmp.regressions {
                    eprintln!("REGRESSION: {r}");
                }
                bail!("{} perf regression(s) past the threshold", cmp.regressions.len());
            }
        }
        other => bail!("unknown bench action {other:?} (run|compare|list)"),
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    if args.get("listen").is_some() {
        return cmd_serve_daemon(args);
    }
    if args.flag("tree-router") {
        return cmd_serve_tree_router(args);
    }
    // Demo: boot the dynamic-batching predict server, fan requests in
    // from several client threads, report batching efficiency.
    let dir = artifacts_dir(args);
    let server = PredictServer::start(dir.clone())?;
    let engine = Engine::load(&dir)?;
    let variant = engine.manifest.variant("ann32x4_relu")?.clone();
    let mut rng = fso::util::rng::Rng::new(7);
    let theta = glorot_init(&variant, &mut rng);
    let theta_vec: Vec<f32> = theta.data().to_vec();
    let feat = engine.manifest.feat;

    let n_clients = args.usize_or("clients", 8)?;
    let rows_per_client = args.usize_or("rows", 100)?;
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for c in 0..n_clients {
            let client = server.client();
            let theta_vec = theta_vec.clone();
            scope.spawn(move || {
                let mut rng = fso::util::rng::Rng::new(c as u64);
                let rows: Vec<Vec<f32>> = (0..rows_per_client)
                    .map(|_| (0..feat).map(|_| rng.f32()).collect())
                    .collect();
                let out = client
                    .predict("ann32x4_relu", &theta_vec, rows)
                    .expect("predict");
                assert_eq!(out.len(), rows_per_client);
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    let stats = server.stats()?;
    println!(
        "served {} rows across {} requests in {:.3}s ({:.0} rows/s)",
        stats.rows,
        stats.requests,
        dt,
        fso::util::rate::per_sec(stats.rows, dt)
    );
    println!(
        "batches issued: {} (mean occupancy {:.1}/{})",
        stats.batches,
        stats.mean_occupancy,
        engine.manifest.batch
    );
    Ok(())
}

/// `fso serve --listen HOST:PORT`: the multi-tenant evaluation daemon
/// (ISSUE 9). One `EvalService` (memoized, single-flight, coalescing
/// on) plus one `EvalRouter` mega-batching window serve every client
/// behind a newline-JSON TCP socket; `--cache-dir` attaches the
/// DirLock-guarded persistent stores, flushed at graceful drain.
fn cmd_serve_daemon(args: &Args) -> Result<()> {
    let listen = args.get("listen").expect("checked by cmd_serve").to_string();
    let enablement = Enablement::from_name(args.get_or("enablement", "gf12"))?;
    let seed = args.u64_or("seed", 2023)?;
    let quota_burst: Option<usize> = args
        .get("quota-burst")
        .map(|v| {
            v.parse()
                .with_context(|| format!("--quota-burst wants a count, got {v:?}"))
        })
        .transpose()?;
    let quota_rate = args.f64_or("quota-rate", 0.0)?;
    // degenerate config guard (ISSUE 10 satellite): the token bucket
    // caps refill at `burst`, so burst 0 admits nothing forever — any
    // positive rate would silently turn the daemon into a 429 machine.
    // Reject up front, before the surrogate fitting below does work.
    if quota_burst == Some(0) && quota_rate > 0.0 {
        bail!(
            "--quota-burst 0 with --quota-rate {quota_rate} admits no requests ever \
             (refill is capped at the burst); raise --quota-burst or drop --quota-rate"
        );
    }
    // the predict op needs a surrogate bundle: fit the same small
    // Axiline tree family the --tree-router demo uses (offline, no
    // PJRT artifacts), deterministic in --seed
    let mut cfg = DatagenConfig::small(Platform::Axiline, enablement);
    cfg.n_arch = 6;
    cfg.n_backend_train = 8;
    cfg.n_backend_test = 2;
    cfg.seed = seed;
    eprintln!("[serve] fitting the tree surrogate bundle for the predict op...");
    let g = datagen::generate(&cfg)?;
    let bundle = SurrogateBundle::fit(&g.dataset, &g.backend_split, 7)?;
    let cache = cache_store(args)?;
    let models = model_store(args)?;
    let service = Arc::new(
        EvalService::new(enablement, seed)
            .with_coalescing(true)
            .with_surrogate(bundle)
            .with_cache_store_opt(cache.clone())
            .with_model_store_opt(models.clone()),
    );
    let opts = fso::coordinator::ServeOptions {
        listen,
        quota_burst,
        quota_rate,
        feat_dim: g.dataset.rows.first().map_or(0, |r| r.features_vec().len()),
        test_hooks: std::env::var("FSO_SERVE_TEST_HOOKS").as_deref() == Ok("1"),
    };
    fso::coordinator::run_daemon(service, cache, models, &opts)
}

/// `fso client --connect HOST:PORT`: bridge stdin request lines to the
/// daemon and its response lines to stdout, one round trip per line —
/// the scriptable client the smoke tests and CI drive.
fn cmd_client(args: &Args) -> Result<()> {
    use std::io::{BufRead, BufReader, Write};
    let addr = args
        .get("connect")
        .context("--connect HOST:PORT required for `fso client`")?;
    let stream = std::net::TcpStream::connect(addr)
        .with_context(|| format!("connecting to daemon at {addr}"))?;
    let mut from_server = BufReader::new(stream.try_clone()?);
    let mut to_server = stream;
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    for line in stdin.lock().lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        to_server.write_all(line.as_bytes())?;
        to_server.write_all(b"\n")?;
        let mut resp = String::new();
        if from_server.read_line(&mut resp)? == 0 {
            bail!("daemon closed the connection mid-conversation");
        }
        out.write_all(resp.as_bytes())?;
    }
    out.flush()?;
    Ok(())
}

/// `fso fleet lead|work`: the distributed evaluation fleet (ISSUE 10).
/// The leader runs a DSE experiment (same targets as `fso dse`) with
/// every full oracle miss dispatched to connected workers; workers
/// claim, evaluate, and stream back bit-exact results under a
/// heartbeat-renewed lease.
fn cmd_fleet(args: &Args) -> Result<()> {
    use fso::coordinator::fleet::{self, FleetOracle, LeaderOptions};
    let action = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .context("fleet action required (`fso fleet lead` or `fso fleet work`)")?;
    match action {
        "lead" => {
            let listen = args
                .get("listen")
                .context("--listen HOST:PORT required for `fso fleet lead`")?
                .to_string();
            let lease_ms = args.u64_or("lease-ms", fleet::DEFAULT_LEASE_MS)?;
            anyhow::ensure!(lease_ms > 0, "--lease-ms must be positive");
            let opts = exp_options(args)?;
            opts.ensure_out_dir()?;
            let target = args.get_or("target", "axiline-svm").to_string();
            // display enablement mirrors the target's experiment
            // (fig11 explores NG45, fig12 GF12); workers get the real
            // enablement/seed inside every task
            let enablement = match target.as_str() {
                "axiline-svm" => Enablement::Ng45,
                "vta" => Enablement::Gf12,
                other => bail!("unknown fleet target {other:?} (axiline-svm|vta)"),
            };
            let lopts = LeaderOptions { listen, lease_ms };
            fleet::run_leader(enablement, opts.seed, &lopts, |queue| {
                let remote = Some(Arc::new(FleetOracle::new(queue)) as Arc<dyn fso::coordinator::RemoteOracle>);
                match target.as_str() {
                    "axiline-svm" => experiments::dse::fig11_axiline_svm_with(&opts, remote),
                    _ => experiments::dse::fig12_vta_with(&opts, remote),
                }
            })
        }
        "work" => {
            let connect = args
                .get("connect")
                .context("--connect HOST:PORT required for `fso fleet work`")?;
            let exit_after = match args.get("exit-after") {
                None => None,
                Some(v) => Some(
                    v.parse::<usize>()
                        .with_context(|| format!("--exit-after wants a count, got {v:?}"))?,
                ),
            };
            fleet::run_worker(connect, exit_after)
        }
        other => bail!("unknown fleet action {other:?} (lead|work)"),
    }
}

/// `fso serve --tree-router`: demo the generic `EvalRouter` (ISSUE 5)
/// on the tree-family surrogate — no PJRT artifacts needed. Client
/// threads submit single feature rows; the router coalesces whatever
/// cohabits its drain window into metric-major mega-batches.
fn cmd_serve_tree_router(args: &Args) -> Result<()> {
    let mut cfg = DatagenConfig::small(Platform::Axiline, Enablement::Gf12);
    cfg.n_arch = 6;
    cfg.n_backend_train = 8;
    cfg.n_backend_test = 2;
    println!("fitting a small tree surrogate for the router demo...");
    let g = datagen::generate(&cfg)?;
    let bundle = SurrogateBundle::fit(&g.dataset, &g.backend_split, 7)?;
    let service = Arc::new(
        EvalService::new(Enablement::Gf12, cfg.seed).with_surrogate(bundle),
    );
    let router = EvalRouter::start(Arc::clone(&service));
    let feats: Vec<Vec<f64>> =
        g.dataset.rows.iter().map(|r| r.features_vec()).collect();

    let n_clients = args.usize_or("clients", 8)?;
    let rows_per_client = args.usize_or("rows", 100)?;
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for c in 0..n_clients {
            let client = router.client();
            let feats = &feats;
            scope.spawn(move || {
                for k in 0..rows_per_client {
                    let row = feats[(c * rows_per_client + k) % feats.len()].clone();
                    let out = client.predict(vec![row]).expect("router predict");
                    assert_eq!(out.len(), 1);
                }
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    let s = service.stats();
    println!(
        "routed {} rows across {} requests in {:.3}s ({:.0} rows/s)",
        s.router_rows,
        s.router_requests,
        dt,
        fso::util::rate::per_sec(s.router_rows, dt)
    );
    println!(
        "mega-batches issued: {} (mean occupancy {:.1})",
        s.router_batches,
        s.router_occupancy()
    );
    drop(router);
    Ok(())
}
