//! `fso` — launcher for the full-stack ML-accelerator optimization
//! framework (paper reproduction). Subcommands:
//!
//!   fso datagen   --platform axiline --enablement gf12 [--out data.csv]
//!   fso train     --platform vta [--metric power] [--trees-only]
//!   fso dse       --target axiline-svm|vta [--iters N]
//!   fso experiment <fig1b|fig3|fig4|fig6|fig8|fig9|fig10|fig11|fig12|tab3|tab4|tab5|all>
//!   fso serve     --demo      (dynamic-batching predict server demo)
//!
//! Global: --seed N, --quick, --out-dir DIR, --artifacts DIR

use std::path::PathBuf;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use fso::backend::Enablement;
use fso::coordinator::experiments::{self, ExpOptions};
use fso::coordinator::{datagen, DatagenConfig, PredictServer, TrainOptions, Trainer};
use fso::data::Metric;
use fso::generators::Platform;
use fso::models::ann::glorot_init;
use fso::runtime::Engine;
use fso::util::cli::Args;

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .or_else(fso::test_support::artifacts_dir)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

fn run(args: &Args) -> Result<()> {
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "datagen" => cmd_datagen(args),
        "train" => cmd_train(args),
        "dse" => cmd_dse(args),
        "experiment" => cmd_experiment(args),
        "serve" => cmd_serve(args),
        _ => {
            println!("{}", HELP.trim());
            Ok(())
        }
    }
}

const HELP: &str = r#"
fso — ML-based full-stack optimization framework for ML accelerators

USAGE:
  fso datagen --platform <tabla|genesys|vta|axiline> [--enablement gf12|ng45]
              [--archs N] [--out data.csv] [--seed N]
  fso train --platform <...> [--metric power|perf|area|energy|runtime]
            [--trees-only] [--seed N]
  fso dse --target <axiline-svm|vta> [--quick]
  fso experiment <fig1b|fig3|fig4|fig6|fig8|fig9|fig10|fig11|fig12|tab3|tab4|tab5|all>
                 [--quick] [--out-dir results] [--seed N]
  fso serve [--clients N] [--rows N]
"#;

fn cmd_datagen(args: &Args) -> Result<()> {
    let platform = Platform::from_name(args.get_or("platform", "axiline"))?;
    let enablement = Enablement::from_name(args.get_or("enablement", "gf12"))?;
    let mut cfg = DatagenConfig::small(platform, enablement);
    cfg.n_arch = args.usize_or("archs", cfg.n_arch)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    let t0 = std::time::Instant::now();
    let g = datagen::generate(&cfg)?;
    println!(
        "generated {} rows ({} archs x {} backend points) in {:.2}s",
        g.dataset.len(),
        g.dataset.archs.len(),
        cfg.n_backend_train + cfg.n_backend_test,
        t0.elapsed().as_secs_f64()
    );
    let in_roi = g.dataset.rows.iter().filter(|r| r.in_roi).count();
    println!("ROI rows: {in_roi}/{}", g.dataset.len());
    if let Some(out) = args.get("out") {
        g.dataset.write_csv(std::path::Path::new(out))?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let platform = Platform::from_name(args.get_or("platform", "axiline"))?;
    let enablement = Enablement::from_name(args.get_or("enablement", "gf12"))?;
    let seed = args.u64_or("seed", 2023)?;
    let cfg = DatagenConfig { seed, ..DatagenConfig::small(platform, enablement) };
    println!("generating dataset...");
    let g = datagen::generate(&cfg)?;
    let trainer = if args.flag("trees-only") {
        Trainer::new(None)
    } else {
        Trainer::new(Some(Rc::new(Engine::load(&artifacts_dir(args))?)))
    };
    let mut opts = TrainOptions { seed, ..Default::default() };
    if args.flag("trees-only") {
        opts.menu = fso::coordinator::ModelMenu::trees_only();
    }
    let metrics: Vec<Metric> = match args.get("metric") {
        Some(name) => vec![Metric::ALL
            .into_iter()
            .find(|m| m.name() == name)
            .with_context(|| format!("unknown metric {name}"))?],
        None => Metric::ALL.to_vec(),
    };
    for metric in metrics {
        let report = trainer.run(&g.dataset, &g.backend_split, metric, &opts)?;
        println!(
            "--- {metric} (ROI acc {:.2} / F1 {:.2}, {} eval rows) ---",
            report.roi.accuracy, report.roi.f1, report.eval_rows
        );
        for (model, stats) in &report.models {
            println!(
                "{model:9} muAPE {:6.2}%  STD {:6.2}  MAPE {:6.2}%",
                stats.mu_ape, stats.std_ape, stats.max_ape
            );
        }
    }
    Ok(())
}

fn cmd_dse(args: &Args) -> Result<()> {
    let opts = exp_options(args)?;
    opts.ensure_out_dir()?;
    match args.get_or("target", "axiline-svm") {
        "axiline-svm" => experiments::dse::fig11_axiline_svm(&opts),
        "vta" => experiments::dse::fig12_vta(&opts),
        other => bail!("unknown DSE target {other:?}"),
    }
}

fn exp_options(args: &Args) -> Result<ExpOptions> {
    Ok(ExpOptions {
        seed: args.u64_or("seed", 2023)?,
        out_dir: PathBuf::from(args.get_or("out-dir", "results")),
        quick: args.flag("quick"),
    })
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .context("experiment id required (e.g. `fso experiment tab4`)")?;
    let opts = exp_options(args)?;
    let t0 = std::time::Instant::now();
    experiments::run(id, &opts)?;
    println!("[{id}] done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    // Demo: boot the dynamic-batching predict server, fan requests in
    // from several client threads, report batching efficiency.
    let dir = artifacts_dir(args);
    let server = PredictServer::start(dir.clone())?;
    let engine = Engine::load(&dir)?;
    let variant = engine.manifest.variant("ann32x4_relu")?.clone();
    let mut rng = fso::util::rng::Rng::new(7);
    let theta = glorot_init(&variant, &mut rng);
    let theta_vec: Vec<f32> = theta.data().to_vec();
    let feat = engine.manifest.feat;

    let n_clients = args.usize_or("clients", 8)?;
    let rows_per_client = args.usize_or("rows", 100)?;
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for c in 0..n_clients {
            let client = server.client();
            let theta_vec = theta_vec.clone();
            scope.spawn(move || {
                let mut rng = fso::util::rng::Rng::new(c as u64);
                let rows: Vec<Vec<f32>> = (0..rows_per_client)
                    .map(|_| (0..feat).map(|_| rng.f32()).collect())
                    .collect();
                let out = client
                    .predict("ann32x4_relu", &theta_vec, rows)
                    .expect("predict");
                assert_eq!(out.len(), rows_per_client);
            });
        }
    });
    let dt = t0.elapsed().as_secs_f64();
    let stats = server.stats()?;
    println!(
        "served {} rows across {} requests in {:.3}s ({:.0} rows/s)",
        stats.rows,
        stats.requests,
        dt,
        stats.rows as f64 / dt
    );
    println!(
        "batches issued: {} (mean occupancy {:.1}/{})",
        stats.batches,
        stats.mean_occupancy,
        engine.manifest.batch
    );
    Ok(())
}
