//! Backend SP&R oracle (paper's Synopsys DC + Cadence Innovus flow on
//! GF12 / NanGate45): analytic synthesis + place-and-route models that
//! reproduce the *behavioural shapes* the paper's evaluation depends on —
//! the ROI f_effective response (Fig. 3c/4), utilization congestion
//! cliffs, macro-heavy floorplan penalties, post-synthesis vs post-route
//! miscorrelation (Fig. 1b), and deterministic per-design tool noise.
//!
//! See DESIGN.md §2 (substitution table) and §6 (model equations).

pub mod enablement;
pub mod flow;
pub mod noise;
pub mod pnr;
pub mod synthesis;

pub use enablement::{Enablement, TechCoeffs};
pub use flow::{roi_epsilon, BackendConfig, FlowResult, SpnrFlow};
pub use noise::NoiseModel;
pub use pnr::{BackendResult, PowerBreakdown};
pub use synthesis::SynthResult;
