//! Place & route + post-route optimization stage (the paper runs Cadence
//! Innovus 21.1 with the concurrent macro placer).
//!
//! Adds what synthesis cannot see: floorplan-dependent wirelength,
//! congestion (exploding past a utilization cliff — lower for macro-heavy
//! floorplans), clock-tree skew and power, and the characteristic
//! f_effective response of Fig. 3(c)/4:
//!
//!   - low f_target  -> positive slack (tool over-delivers), f_eff > f_target
//!   - mid f_target  -> f_eff ~= f_target (the ROI, Eq. 4)
//!   - high f_target -> f_eff saturates below f_target, with noisy outcomes
//!
//! The closed form f_eff = f_max * (1 - exp(-(f_target/f_max)/tau)) with
//! tau < 1 produces exactly that shape.

use super::enablement::TechCoeffs;
use super::noise::NoiseModel;
use super::synthesis::{SynthResult, ACTIVITY};

/// f_effective response (Fig. 3c/4): a soft-min of the (slightly
/// over-delivered) target and the floorplan's achievable f_max.
///
///   boost: tools over-deliver at relaxed targets (positive slack),
///          decaying as the target tightens;
///   softmin exponent K: sharpness of the saturation knee. K=6 keeps
///          f_eff within ~5% of f_target across the broad mid band (the
///          paper's wide "region of balance") and plateaus at f_max.
pub const OVERDELIVERY: f64 = 0.25;
pub const OVERDELIVERY_DECAY: f64 = 0.25;
pub const SOFTMIN_K: f64 = 6.0;

/// f_eff for a target/achievable pair.
pub fn f_effective(f_target: f64, f_max: f64) -> f64 {
    let r = f_target / f_max.max(1e-9);
    let boost = 1.0 + OVERDELIVERY * (-r / OVERDELIVERY_DECAY).exp();
    let ft = f_target * boost;
    (ft.powf(-SOFTMIN_K) + f_max.powf(-SOFTMIN_K)).powf(-1.0 / SOFTMIN_K)
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerBreakdown {
    /// Register + clock-tree internal power, W.
    pub internal_w: f64,
    /// Combinational + wire switching power, W.
    pub switching_w: f64,
    /// Leakage power, W.
    pub leakage_w: f64,
    /// SRAM macro dynamic power, W.
    pub sram_w: f64,
}

impl PowerBreakdown {
    pub fn total(&self) -> f64 {
        self.internal_w + self.switching_w + self.leakage_w + self.sram_w
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BackendResult {
    /// Effective clock frequency after post-route optimization, GHz
    /// (paper: 1 / (target period - WNS)).
    pub f_effective_ghz: f64,
    /// Achievable frequency of this floorplan (diagnostic), GHz.
    pub f_max_ghz: f64,
    /// Post-route power breakdown at the target clock.
    pub power: PowerBreakdown,
    /// Chip area, mm^2 (square die, aspect ratio 1).
    pub chip_area_mm2: f64,
    /// Std-cell area after routing-driven resizing, um^2.
    pub cell_area_um2: f64,
    /// Macro area, um^2.
    pub macro_area_um2: f64,
    /// Congestion factor applied to wire delay (>= 1).
    pub congestion: f64,
}

impl BackendResult {
    pub fn total_power_w(&self) -> f64 {
        self.power.total()
    }

    /// Paper Eq. 4 ROI membership.
    pub fn in_roi(&self, f_target_ghz: f64, epsilon: f64) -> bool {
        (self.f_effective_ghz - f_target_ghz).abs() <= epsilon * f_target_ghz
    }
}

/// Congestion multiplier: smooth but explosive past the cliff. The cliff
/// sits lower for macro-heavy floorplans (paper §5.4: ~90% breaks Axiline,
/// macro-heavy designs are sampled only up to 60%).
pub fn congestion_factor(util: f64, macro_heavy: bool) -> f64 {
    let crit = if macro_heavy { 0.62 } else { 0.87 };
    let x = util - crit;
    let sig = 1.0 / (1.0 + (-x / 0.03).exp());
    let blowup = if x > 0.0 { (x / 0.12) * (x / 0.12) } else { 0.0 };
    1.0 + 0.10 * sig + blowup
}

pub struct PnrInput<'a> {
    pub synth: &'a SynthResult,
    pub f_target_ghz: f64,
    pub util: f64,
    pub macro_heavy: bool,
    /// Total SRAM bits + port width for the macro power model.
    pub macro_bits: f64,
    pub macro_port_bits: f64,
    /// FF count and comb cells from the design aggregates.
    pub ff_count: f64,
    pub comb_cells: f64,
}

pub fn place_and_route(
    inp: &PnrInput,
    tech: &TechCoeffs,
    noise: &NoiseModel,
    design_id: u64,
    knob_bits: u64,
) -> BackendResult {
    let s = inp.synth;
    let chip_area_um2 = (s.cell_area_um2 + s.macro_area_um2) / inp.util.clamp(0.05, 0.99);
    let die_um = chip_area_um2.sqrt();

    // Critical wire: a fraction of the die diagonal, worse under
    // congestion; macro-heavy floorplans force longer detours.
    let cong = congestion_factor(inp.util, inp.macro_heavy);
    let detour = if inp.macro_heavy { 1.25 } else { 1.0 };
    let crit_wire_um = 0.45 * die_um * detour;
    let wire_delay_ps = tech.wire_ps_per_um * crit_wire_um * cong;
    let cts_skew_ps = 1.4 * tech.gate_delay_ps;

    // Achievable period; noisier when the flow is stressed (very high
    // target pressure or past the congestion cliff) — paper §5.4 treats
    // those outcomes as outliers precisely because they vary.
    // Congestion also degrades placement quality (detours, pin access),
    // not just wire RC: past the cliff the whole path stretches.
    let placement_quality = 0.7 + 0.3 * cong;
    let p_min_raw = (s.logic_delay_ps + wire_delay_ps + cts_skew_ps) * placement_quality;
    let pressure = (1000.0 / inp.f_target_ghz.max(1e-3)) / p_min_raw;
    let stressed = pressure < 1.15 || cong > 1.25;
    let sigma = if stressed { 0.05 } else { 0.012 };
    let p_min_ps = p_min_raw * noise.factor(design_id, knob_bits, "pnr_timing", sigma);

    let f_max = (1000.0 / p_min_ps).min(tech.f_ceiling_ghz);
    let r = inp.f_target_ghz / f_max;
    let f_eff = f_effective(inp.f_target_ghz, f_max);

    // Routing-driven resizing inflates cells slightly under congestion.
    let cell_area = s.cell_area_um2
        * (1.0 + 0.05 * (cong - 1.0))
        * noise.factor(design_id, knob_bits, "pnr_area", 0.008);

    // Power at the target clock (post-route parasitics: wire cap scales
    // switching with congestion and die size).
    let wire_cap_scale = 1.0 + 0.25 * (cong - 1.0) + 0.08 * (die_um / 1000.0);
    // hold/max-cap buffer insertion and clock-net strengthening grow
    // steeply as the target approaches/exceeds achievable (real flows
    // show 30-60% switching growth near f_max)
    let buffering = 1.0 + 0.30 * (r.min(1.6)).powi(3);
    let f = inp.f_target_ghz;
    let switching_w = inp.comb_cells
        * tech.cell_sw_fj
        * ACTIVITY
        * f
        * 1e-6
        * s.upsize
        * wire_cap_scale
        * buffering
        * noise.factor(design_id, knob_bits, "pnr_sw", 0.03);
    let internal_w = inp.ff_count * tech.ff_int_fj * (1.0 + tech.cts_overhead) * f * 1e-6
        * noise.factor(design_id, knob_bits, "pnr_int", 0.02);
    let sram_w = inp.macro_port_bits * tech.sram_fj_per_bit * 0.5 /* access rate */ * f * 1e-6;
    let leakage_w = (inp.comb_cells * tech.leak_nw_per_cell * s.upsize.powf(1.5)
        + inp.macro_bits / 1024.0 * tech.sram_leak_nw_per_kb)
        * 1e-9;

    BackendResult {
        f_effective_ghz: f_eff,
        f_max_ghz: f_max,
        power: PowerBreakdown { internal_w, switching_w, leakage_w, sram_w },
        chip_area_mm2: chip_area_um2 / 1e6,
        cell_area_um2: cell_area,
        macro_area_um2: s.macro_area_um2,
        congestion: cong,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::enablement::GF12;
    use crate::backend::synthesis::synthesize;
    use crate::generators::{ArchConfig, Platform};

    fn run(p: Platform, f_target: f64, util: f64) -> BackendResult {
        let cfg = ArchConfig::new(
            p,
            p.param_space().iter().map(|s| s.kind.from_unit(0.5)).collect(),
        );
        let agg = p.generate(&cfg).unwrap().aggregates();
        let n = NoiseModel::new(0);
        let synth = synthesize(&agg, f_target, &GF12, &n, 1, 1);
        let inp = PnrInput {
            synth: &synth,
            f_target_ghz: f_target,
            util,
            macro_heavy: p.macro_heavy(),
            macro_bits: agg.macro_bits,
            macro_port_bits: agg.macro_port_bits,
            ff_count: agg.ff_count,
            comb_cells: agg.comb_cells,
        };
        place_and_route(&inp, &GF12, &n, 1, 1)
    }

    #[test]
    fn low_target_gives_positive_slack() {
        let r = run(Platform::Axiline, 0.2, 0.6);
        assert!(
            r.f_effective_ghz > 0.2 * 1.05,
            "f_eff={} should exceed f_target",
            r.f_effective_ghz
        );
    }

    #[test]
    fn high_target_saturates_below() {
        let r = run(Platform::Axiline, 3.0, 0.6);
        assert!(r.f_effective_ghz < 3.0 * 0.9);
        assert!(r.f_effective_ghz <= r.f_max_ghz + 1e-9);
    }

    #[test]
    fn mid_target_lands_in_roi() {
        // scan for at least a few targets with |f_eff - f_t| <= 0.1 f_t
        let mut hits = 0;
        for i in 1..40 {
            let ft = 0.1 * i as f64;
            let r = run(Platform::Axiline, ft, 0.6);
            if r.in_roi(ft, 0.1) {
                hits += 1;
            }
        }
        assert!(hits >= 4, "only {hits} ROI points found");
    }

    #[test]
    fn util_cliff_degrades_fmax() {
        let ok = run(Platform::Axiline, 1.0, 0.6);
        let bad = run(Platform::Axiline, 1.0, 0.95);
        assert!(bad.f_max_ghz < ok.f_max_ghz);
        assert!(bad.congestion > ok.congestion);
        // macro-heavy cliff is lower
        let vta_ok = run(Platform::Vta, 1.0, 0.35);
        let vta_bad = run(Platform::Vta, 1.0, 0.75);
        assert!(vta_bad.f_max_ghz < vta_ok.f_max_ghz);
    }

    #[test]
    fn higher_util_smaller_die() {
        let lo = run(Platform::Vta, 0.8, 0.3);
        let hi = run(Platform::Vta, 0.8, 0.55);
        assert!(hi.chip_area_mm2 < lo.chip_area_mm2);
    }

    #[test]
    fn power_increases_with_target_clock() {
        let slow = run(Platform::GeneSys, 0.3, 0.4);
        let fast = run(Platform::GeneSys, 1.4, 0.4);
        assert!(fast.total_power_w() > 2.0 * slow.total_power_w());
    }

    #[test]
    fn power_components_all_positive() {
        let r = run(Platform::Tabla, 0.9, 0.4);
        assert!(r.power.internal_w > 0.0);
        assert!(r.power.switching_w > 0.0);
        assert!(r.power.leakage_w > 0.0);
        assert!(r.power.sram_w > 0.0);
    }

    #[test]
    fn congestion_monotone_in_util() {
        for heavy in [false, true] {
            let mut prev = 0.0;
            for i in 0..20 {
                let u = 0.2 + 0.04 * i as f64;
                let c = congestion_factor(u, heavy);
                assert!(c >= prev, "congestion must be nondecreasing");
                prev = c;
            }
        }
    }
}
