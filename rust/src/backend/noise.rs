//! Deterministic "tool noise": commercial SP&R flows are not smooth
//! functions of their inputs — small input changes move heuristic
//! decisions (placement seeds, buffer trees, congestion ripups) and the
//! paper leans on this (Fig. 1b miscorrelation; larger outcome variance
//! outside the ROI). We model it as config-hashed lognormal-ish
//! multipliers: fully deterministic given (seed, design, knobs, stage),
//! uncorrelated across stages, larger outside well-behaved regions.

use crate::util::rng::{hash_bytes, splitmix64};

#[derive(Debug, Clone, Copy)]
pub struct NoiseModel {
    pub seed: u64,
}

impl NoiseModel {
    pub fn new(seed: u64) -> Self {
        NoiseModel { seed }
    }

    /// A standard-normal draw keyed by (seed, design id, knob bits, stage).
    pub fn gauss(&self, design_id: u64, knob_bits: u64, stage: &str) -> f64 {
        let mut bytes = Vec::with_capacity(32 + stage.len());
        bytes.extend_from_slice(&self.seed.to_le_bytes());
        bytes.extend_from_slice(&design_id.to_le_bytes());
        bytes.extend_from_slice(&knob_bits.to_le_bytes());
        bytes.extend_from_slice(stage.as_bytes());
        let mut s = hash_bytes(&bytes);
        let u1 = (splitmix64(&mut s) >> 11) as f64 / (1u64 << 53) as f64;
        let u2 = (splitmix64(&mut s) >> 11) as f64 / (1u64 << 53) as f64;
        (-2.0 * u1.max(1e-12).ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Multiplicative noise: exp(sigma * z), clamped to +-3 sigma.
    pub fn factor(&self, design_id: u64, knob_bits: u64, stage: &str, sigma: f64) -> f64 {
        let z = self.gauss(design_id, knob_bits, stage).clamp(-3.0, 3.0);
        (sigma * z).exp()
    }
}

/// Pack backend knobs into hashable bits (quantized so that float jitter
/// below the tools' own granularity maps to the same noise draw).
pub fn knob_bits(f_target_ghz: f64, util: f64) -> u64 {
    let f_q = (f_target_ghz * 1000.0).round() as u64; // MHz granularity
    let u_q = (util * 1000.0).round() as u64;
    (f_q << 20) | u_q
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let n = NoiseModel::new(42);
        assert_eq!(n.gauss(1, 2, "syn"), n.gauss(1, 2, "syn"));
        assert_eq!(n.factor(1, 2, "pnr", 0.03), n.factor(1, 2, "pnr", 0.03));
    }

    #[test]
    fn stages_are_uncorrelated() {
        let n = NoiseModel::new(42);
        let m = 2000;
        let mut dot = 0.0;
        for i in 0..m {
            dot += n.gauss(i, 0, "syn") * n.gauss(i, 0, "pnr");
        }
        let corr = dot / m as f64;
        assert!(corr.abs() < 0.05, "corr={corr}");
    }

    #[test]
    fn factor_centered_near_one() {
        let n = NoiseModel::new(7);
        let m = 4000;
        let mean: f64 = (0..m).map(|i| n.factor(i, 3, "syn", 0.02)).sum::<f64>() / m as f64;
        assert!((mean - 1.0).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn knob_quantization_groups_close_values() {
        assert_eq!(knob_bits(1.00001, 0.70001), knob_bits(1.0, 0.7));
        assert_ne!(knob_bits(1.1, 0.7), knob_bits(1.0, 0.7));
    }

    #[test]
    fn different_seeds_different_noise() {
        assert_ne!(
            NoiseModel::new(1).gauss(5, 5, "syn"),
            NoiseModel::new(2).gauss(5, 5, "syn")
        );
    }
}
