//! Process enablements: technology coefficients for the two nodes the
//! paper implements on — GLOBALFOUNDRIES 12LP ("GF12", commercial 12 nm)
//! and NanGate45 ("NG45", open research 45 nm PDK).
//!
//! Absolute values are representative, not foundry data (the real decks
//! are license-gated); what matters for the reproduction is the *relative*
//! structure — NG45 is ~3x slower, ~8x larger per cell, and an order of
//! magnitude more energy per op — which drives the same Fig. 4 / Table 4-5
//! shapes the paper reports per enablement.

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Enablement {
    Gf12,
    Ng45,
}

impl Enablement {
    pub fn name(&self) -> &'static str {
        match self {
            Enablement::Gf12 => "gf12",
            Enablement::Ng45 => "ng45",
        }
    }

    pub fn from_name(s: &str) -> Result<Enablement> {
        match s.to_ascii_lowercase().as_str() {
            "gf12" => Ok(Enablement::Gf12),
            "ng45" => Ok(Enablement::Ng45),
            other => bail!("unknown enablement {other:?} (gf12|ng45)"),
        }
    }

    pub fn coeffs(&self) -> &'static TechCoeffs {
        match self {
            Enablement::Gf12 => &GF12,
            Enablement::Ng45 => &NG45,
        }
    }
}

impl std::fmt::Display for Enablement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Technology coefficients consumed by the synthesis + P&R models.
#[derive(Debug, Clone, PartialEq)]
pub struct TechCoeffs {
    /// FO4-ish gate delay, picoseconds.
    pub gate_delay_ps: f64,
    /// Wire delay per micron of routed length (buffered), ps/um.
    pub wire_ps_per_um: f64,
    /// Average std-cell area, um^2 (2-input NAND-equivalent).
    pub cell_area_um2: f64,
    /// Flip-flop area, um^2.
    pub ff_area_um2: f64,
    /// SRAM macro density, um^2 per bit.
    pub sram_um2_per_bit: f64,
    /// Switching energy per cell toggle, femtojoules.
    pub cell_sw_fj: f64,
    /// Flip-flop internal (clock) energy per cycle, femtojoules.
    pub ff_int_fj: f64,
    /// SRAM read/write energy, femtojoules per bit accessed.
    pub sram_fj_per_bit: f64,
    /// Leakage power density, nanowatts per std cell.
    pub leak_nw_per_cell: f64,
    /// SRAM leakage, nanowatts per kilobit.
    pub sram_leak_nw_per_kb: f64,
    /// Clock-tree energy overhead as a fraction of FF internal energy.
    pub cts_overhead: f64,
    /// Maximum practical clock frequency (GHz) for mid-size blocks —
    /// used only to shape the f_eff saturation curve.
    pub f_ceiling_ghz: f64,
    /// Off-chip interface energy, picojoules per byte (system
    /// simulators; IO pads/PHY only — DRAM device energy is outside the
    /// accelerator energy the paper's simulators report).
    pub dram_pj_per_byte: f64,
}

/// GLOBALFOUNDRIES 12LP-class coefficients.
pub static GF12: TechCoeffs = TechCoeffs {
    gate_delay_ps: 14.0,
    wire_ps_per_um: 0.09,
    cell_area_um2: 0.45,
    ff_area_um2: 1.9,
    sram_um2_per_bit: 0.035,
    cell_sw_fj: 0.55,
    ff_int_fj: 3.0,
    sram_fj_per_bit: 9.0,
    leak_nw_per_cell: 22.0,
    sram_leak_nw_per_kb: 45.0,
    cts_overhead: 0.35,
    f_ceiling_ghz: 2.6,
    dram_pj_per_byte: 4.0,
};

/// NanGate45-class coefficients (open PDK; slower, larger, hungrier).
pub static NG45: TechCoeffs = TechCoeffs {
    gate_delay_ps: 42.0,
    wire_ps_per_um: 0.22,
    cell_area_um2: 3.2,
    ff_area_um2: 13.0,
    sram_um2_per_bit: 0.28,
    cell_sw_fj: 3.8,
    ff_int_fj: 18.0,
    sram_fj_per_bit: 48.0,
    leak_nw_per_cell: 95.0,
    sram_leak_nw_per_kb: 260.0,
    cts_overhead: 0.40,
    f_ceiling_ghz: 1.1,
    dram_pj_per_byte: 7.0,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ng45_is_slower_and_bigger() {
        assert!(NG45.gate_delay_ps > 2.0 * GF12.gate_delay_ps);
        assert!(NG45.cell_area_um2 > 5.0 * GF12.cell_area_um2);
        assert!(NG45.cell_sw_fj > 3.0 * GF12.cell_sw_fj);
        assert!(NG45.f_ceiling_ghz < GF12.f_ceiling_ghz);
    }

    #[test]
    fn name_roundtrip() {
        for e in [Enablement::Gf12, Enablement::Ng45] {
            assert_eq!(Enablement::from_name(e.name()).unwrap(), e);
        }
        assert!(Enablement::from_name("tsmc5").is_err());
    }
}
