//! Logic synthesis stage (the paper runs Synopsys DC R-2020.09).
//!
//! Technology mapping of the generated design's aggregates under a target
//! clock: area/power grow with timing pressure (cell upsizing), and the
//! stage's *reported* power/fmax use no wire or congestion information —
//! which is exactly why post-synthesis numbers miscorrelate with
//! post-route reality (paper Fig. 1b); the P&R stage adds those effects
//! with independent noise.

use crate::generators::DesignAggregates;

use super::enablement::TechCoeffs;
use super::noise::NoiseModel;

/// Average switching activity factor assumed by the power model.
pub const ACTIVITY: f64 = 0.18;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SynthResult {
    /// Std-cell area after mapping/upsizing, um^2.
    pub cell_area_um2: f64,
    /// SRAM macro area, um^2.
    pub macro_area_um2: f64,
    /// Cell upsizing factor applied to meet timing (>= 1).
    pub upsize: f64,
    /// Post-synthesis *estimated* total power (W) — optimistic, no wires.
    pub syn_power_w: f64,
    /// Post-synthesis *estimated* max frequency (GHz) — optimistic.
    pub syn_fmax_ghz: f64,
    /// Intrinsic logic-path delay after upsizing, ps (pre-wire).
    pub logic_delay_ps: f64,
}

fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

/// Run the synthesis model.
///
/// `design_id` keys the deterministic tool noise (paper: run-to-run and
/// design-to-design heuristic variation).
pub fn synthesize(
    agg: &DesignAggregates,
    f_target_ghz: f64,
    tech: &TechCoeffs,
    noise: &NoiseModel,
    design_id: u64,
    knob_bits: u64,
) -> SynthResult {
    let p_target_ps = 1000.0 / f_target_ghz.max(1e-3);
    let logic_delay_raw = agg.logic_depth * tech.gate_delay_ps;

    // Timing pressure -> upsizing. Pressure ~1 means the intrinsic path
    // barely fits the target period; DC upsizes (area+power) and buys
    // back ~12% delay at full effort.
    let pressure = logic_delay_raw / p_target_ps;
    let effort = sigmoid((pressure - 0.75) * 6.0);
    let upsize = 1.0 + 0.30 * effort;
    let logic_delay_ps = logic_delay_raw * (1.0 - 0.12 * effort);

    let cell_area = (agg.comb_cells * tech.cell_area_um2 * agg.avg_fanin.max(1.0) / 2.6
        + agg.ff_count * tech.ff_area_um2)
        * upsize
        * noise.factor(design_id, knob_bits, "syn_area", 0.015);
    let macro_area = agg.macro_bits * tech.sram_um2_per_bit;

    // Post-synthesis power estimate: zero-wire-load, independent noise.
    let sw = agg.comb_cells * tech.cell_sw_fj * ACTIVITY * f_target_ghz * 1e-6 * upsize;
    let int = agg.ff_count * tech.ff_int_fj * f_target_ghz * 1e-6;
    let leak = (agg.comb_cells * tech.leak_nw_per_cell
        + agg.macro_bits / 1024.0 * tech.sram_leak_nw_per_kb)
        * 1e-9
        * upsize.powf(1.5);
    let syn_power_w =
        (sw + int + leak) * noise.factor(design_id, knob_bits, "syn_power", 0.06);

    // Optimistic fmax: logic only, no routing detour, no CTS skew.
    let syn_fmax_ghz = (1000.0 / logic_delay_ps)
        .min(tech.f_ceiling_ghz * 1.3)
        * noise.factor(design_id, knob_bits, "syn_fmax", 0.05);

    SynthResult {
        cell_area_um2: cell_area,
        macro_area_um2: macro_area,
        upsize,
        syn_power_w,
        syn_fmax_ghz,
        logic_delay_ps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::enablement::GF12;
    use crate::generators::{ArchConfig, Platform};

    fn agg() -> DesignAggregates {
        let p = Platform::Vta;
        let cfg = ArchConfig::new(
            p,
            p.param_space().iter().map(|s| s.kind.from_unit(0.5)).collect(),
        );
        p.generate(&cfg).unwrap().aggregates()
    }

    #[test]
    fn tighter_clock_costs_area_and_power() {
        let a = agg();
        let n = NoiseModel::new(0);
        let relaxed = synthesize(&a, 0.3, &GF12, &n, 1, 1);
        let tight = synthesize(&a, 2.2, &GF12, &n, 1, 1);
        assert!(tight.cell_area_um2 > relaxed.cell_area_um2);
        assert!(tight.upsize > relaxed.upsize);
        // dynamic power scales with both f and upsizing
        assert!(tight.syn_power_w > 3.0 * relaxed.syn_power_w);
    }

    #[test]
    fn upsizing_buys_back_delay() {
        let a = agg();
        let n = NoiseModel::new(0);
        let relaxed = synthesize(&a, 0.3, &GF12, &n, 1, 1);
        let tight = synthesize(&a, 2.2, &GF12, &n, 1, 1);
        assert!(tight.logic_delay_ps < relaxed.logic_delay_ps);
    }

    #[test]
    fn macro_area_independent_of_clock() {
        let a = agg();
        let n = NoiseModel::new(0);
        let x = synthesize(&a, 0.5, &GF12, &n, 1, 1);
        let y = synthesize(&a, 1.5, &GF12, &n, 1, 1);
        assert_eq!(x.macro_area_um2, y.macro_area_um2);
        assert!(x.macro_area_um2 > 0.0);
    }

    #[test]
    fn deterministic_per_design_and_knobs() {
        let a = agg();
        let n = NoiseModel::new(3);
        let x = synthesize(&a, 1.0, &GF12, &n, 7, 9);
        let y = synthesize(&a, 1.0, &GF12, &n, 7, 9);
        assert_eq!(x, y);
        let z = synthesize(&a, 1.0, &GF12, &n, 8, 9);
        assert_ne!(x.cell_area_um2, z.cell_area_um2);
    }
}
