//! The full SP&R flow: generator output -> synthesis -> P&R -> post-route
//! PPA. One call here replaces the paper's hours-long Design Compiler +
//! Innovus run for one (architecture, f_target, util) point; everything
//! downstream (dataset generation, DSE ground truth) goes through it.

use anyhow::Result;

use crate::generators::{ArchConfig, DesignAggregates};

use super::enablement::Enablement;
use super::noise::{knob_bits, NoiseModel};
use super::pnr::{place_and_route, BackendResult, PnrInput};
use super::synthesis::{synthesize, SynthResult};

/// Backend knobs sampled per paper §7.1 (target clock + floorplan util).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BackendConfig {
    pub f_target_ghz: f64,
    pub util: f64,
}

impl BackendConfig {
    pub fn new(f_target_ghz: f64, util: f64) -> Self {
        BackendConfig { f_target_ghz, util }
    }
}

/// ROI epsilon (paper §5.4): 0.1 for small std-cell designs (Axiline),
/// 0.3 for the larger macro-heavy platforms.
pub fn roi_epsilon(platform: crate::generators::Platform) -> f64 {
    if platform.macro_heavy() {
        0.3
    } else {
        0.1
    }
}

#[derive(Debug, Clone)]
pub struct SpnrFlow {
    pub enablement: Enablement,
    pub noise: NoiseModel,
}

/// Full flow output: both stages, so experiments can correlate
/// post-synthesis vs post-route (Fig. 1b).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FlowResult {
    pub synth: SynthResult,
    pub backend: BackendResult,
}

impl SpnrFlow {
    pub fn new(enablement: Enablement, seed: u64) -> Self {
        SpnrFlow { enablement, noise: NoiseModel::new(seed) }
    }

    /// Run synthesis + P&R on a generated design.
    pub fn run_on_aggregates(
        &self,
        agg: &DesignAggregates,
        design_id: u64,
        macro_heavy: bool,
        cfg: BackendConfig,
    ) -> FlowResult {
        let tech = self.enablement.coeffs();
        let kb = knob_bits(cfg.f_target_ghz, cfg.util);
        let synth = synthesize(agg, cfg.f_target_ghz, tech, &self.noise, design_id, kb);
        let inp = PnrInput {
            synth: &synth,
            f_target_ghz: cfg.f_target_ghz,
            util: cfg.util,
            macro_heavy,
            macro_bits: agg.macro_bits,
            macro_port_bits: agg.macro_port_bits,
            ff_count: agg.ff_count,
            comb_cells: agg.comb_cells,
        };
        let backend = place_and_route(&inp, tech, &self.noise, design_id, kb);
        FlowResult { synth, backend }
    }

    /// Convenience: generate the design for an architectural config and
    /// push it through the flow.
    pub fn run(&self, arch: &ArchConfig, cfg: BackendConfig) -> Result<FlowResult> {
        let tree = arch.platform.generate(arch)?;
        let agg = tree.aggregates();
        Ok(self.run_on_aggregates(&agg, arch.id_hash(), arch.platform.macro_heavy(), cfg))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators::Platform;

    fn mid_config(p: Platform) -> ArchConfig {
        ArchConfig::new(
            p,
            p.param_space().iter().map(|s| s.kind.from_unit(0.5)).collect(),
        )
    }

    #[test]
    fn flow_runs_for_all_platforms_and_enablements() {
        for p in Platform::ALL {
            for e in [Enablement::Gf12, Enablement::Ng45] {
                let flow = SpnrFlow::new(e, 1);
                let r = flow.run(&mid_config(p), BackendConfig::new(0.8, 0.45)).unwrap();
                assert!(r.backend.f_effective_ghz > 0.0, "{p}/{e}");
                assert!(r.backend.total_power_w() > 0.0, "{p}/{e}");
                assert!(r.backend.chip_area_mm2 > 0.0, "{p}/{e}");
            }
        }
    }

    #[test]
    fn ng45_is_slower_bigger_hungrier() {
        let p = Platform::Axiline;
        let arch = mid_config(p);
        let cfg = BackendConfig::new(0.8, 0.6);
        let g = SpnrFlow::new(Enablement::Gf12, 1).run(&arch, cfg).unwrap().backend;
        let n = SpnrFlow::new(Enablement::Ng45, 1).run(&arch, cfg).unwrap().backend;
        assert!(n.f_max_ghz < g.f_max_ghz);
        assert!(n.chip_area_mm2 > 3.0 * g.chip_area_mm2);
        assert!(n.total_power_w() > g.total_power_w());
    }

    #[test]
    fn deterministic_end_to_end() {
        let flow = SpnrFlow::new(Enablement::Gf12, 99);
        let arch = mid_config(Platform::GeneSys);
        let cfg = BackendConfig::new(1.1, 0.4);
        let a = flow.run(&arch, cfg).unwrap();
        let b = flow.run(&arch, cfg).unwrap();
        assert_eq!(a.backend, b.backend);
        assert_eq!(a.synth, b.synth);
    }

    #[test]
    fn seed_changes_outcomes_slightly() {
        let arch = mid_config(Platform::Vta);
        let cfg = BackendConfig::new(0.9, 0.4);
        let a = SpnrFlow::new(Enablement::Gf12, 1).run(&arch, cfg).unwrap().backend;
        let b = SpnrFlow::new(Enablement::Gf12, 2).run(&arch, cfg).unwrap().backend;
        assert_ne!(a.f_effective_ghz, b.f_effective_ghz);
        let rel = (a.f_effective_ghz - b.f_effective_ghz).abs() / a.f_effective_ghz;
        assert!(rel < 0.25, "noise should be a perturbation, not chaos: {rel}");
    }

    #[test]
    fn roi_epsilon_per_platform() {
        assert_eq!(roi_epsilon(Platform::Axiline), 0.1);
        assert_eq!(roi_epsilon(Platform::Vta), 0.3);
    }
}
