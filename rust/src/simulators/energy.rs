//! Energy accounting shared by the platform simulators (paper §5.1:
//! "the PPA characteristics feed the simulator with data such as the
//! clock frequency, energy per access for each of the on-chip buffers,
//! and dynamic and leakage power of [the] hardware components").

use crate::backend::{BackendResult, Enablement};

#[derive(Debug, Clone, Copy)]
pub struct EnergyModel {
    /// Compute + register dynamic power when busy, W.
    pub dyn_w: f64,
    /// SRAM dynamic power at full access rate, W.
    pub sram_w: f64,
    /// Leakage power (always on), W.
    pub leak_w: f64,
    /// Effective clock, GHz.
    pub f_ghz: f64,
    /// DRAM energy per byte, J.
    pub dram_j_per_byte: f64,
}

impl EnergyModel {
    pub fn new(backend: &BackendResult, enablement: Enablement) -> EnergyModel {
        let tech = enablement.coeffs();
        EnergyModel {
            dyn_w: backend.power.internal_w + backend.power.switching_w,
            sram_w: backend.power.sram_w,
            leak_w: backend.power.leakage_w,
            f_ghz: backend.f_effective_ghz,
            dram_j_per_byte: tech.dram_pj_per_byte * 1e-12,
        }
    }

    /// Seconds for `cycles` at the effective clock.
    pub fn seconds(&self, cycles: f64) -> f64 {
        cycles / (self.f_ghz * 1e9)
    }

    /// Total energy for a run: busy-gated dynamic power, access-gated
    /// SRAM power, always-on leakage, explicit DRAM traffic.
    pub fn total(
        &self,
        total_cycles: f64,
        busy_cycles: f64,
        sram_active_cycles: f64,
        dram_bytes: f64,
    ) -> f64 {
        let t_total = self.seconds(total_cycles);
        let t_busy = self.seconds(busy_cycles);
        let t_sram = self.seconds(sram_active_cycles);
        self.dyn_w * t_busy + self.sram_w * t_sram + self.leak_w * t_total
            + self.dram_j_per_byte * dram_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{BackendConfig, SpnrFlow};
    use crate::generators::{ArchConfig, Platform};

    fn model() -> EnergyModel {
        let p = Platform::Vta;
        let arch = ArchConfig::new(
            p,
            p.param_space().iter().map(|s| s.kind.from_unit(0.5)).collect(),
        );
        let r = SpnrFlow::new(Enablement::Gf12, 0)
            .run(&arch, BackendConfig::new(0.9, 0.4))
            .unwrap();
        EnergyModel::new(&r.backend, Enablement::Gf12)
    }

    #[test]
    fn idle_cycles_cost_only_leakage() {
        let m = model();
        let active = m.total(1e6, 1e6, 1e6, 0.0);
        let idle = m.total(1e6, 0.0, 0.0, 0.0);
        assert!(active > idle);
        let t = m.seconds(1e6);
        assert!((idle - m.leak_w * t).abs() < 1e-12);
    }

    #[test]
    fn dram_traffic_adds_energy() {
        let m = model();
        let without = m.total(1e6, 5e5, 5e5, 0.0);
        let with = m.total(1e6, 5e5, 5e5, 1e6);
        assert!((with - without - m.dram_j_per_byte * 1e6).abs() < 1e-12);
    }

    #[test]
    fn seconds_inverse_of_frequency() {
        let m = model();
        let t = m.seconds(m.f_ghz * 1e9);
        assert!((t - 1.0).abs() < 1e-9);
    }
}
