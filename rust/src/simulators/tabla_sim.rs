//! TABLA performance simulator: PU/PE dataflow execution of statistical
//! ML training. Operations schedule onto PU x PE engines; the global bus
//! serializes cross-PU reductions, and each epoch pays a synchronization
//! barrier (paper's TABLA template: compute engines + global bus +
//! scheduler).

use crate::backend::BackendResult;
use crate::generators::ArchConfig;
use crate::workloads::{NonDnnAlgo, NonDnnWorkload};

use super::energy::EnergyModel;
use super::SystemMetrics;

pub fn simulate_tabla(
    arch: &ArchConfig,
    _backend: &BackendResult,
    energy: &EnergyModel,
    wl: &NonDnnWorkload,
) -> SystemMetrics {
    let pu = arch.get("pu");
    let pe = arch.get("pe");
    let engines = pu * pe;

    // Dataflow efficiency: dependency chains limit ILP per algorithm
    // (backprop's layer sequence parallelizes well; recsys's scattered
    // factor updates contend on the bus).
    let ilp_eff = match wl.algo {
        NonDnnAlgo::Backprop => 0.80,
        NonDnnAlgo::Recsys => 0.55,
        _ => 0.70,
    };
    // Bus contention grows with PU count (more cross-PU reduction hops).
    let bus_eff = 1.0 / (1.0 + 0.04 * pu);

    let macs = wl.total_macs() as f64;
    let compute_cycles = macs / (engines * ilp_eff * bus_eff);

    // Cross-PU reduction per sample: log2(pu) bus beats.
    let reduce_cycles = (wl.samples * wl.epochs) as f64 * (pu.log2().ceil() + 2.0);
    // Epoch barrier + model broadcast.
    let sync_cycles = wl.epochs as f64 * (500.0 + wl.features as f64);

    // Training data streams from DRAM once per epoch (bits per feature
    // from the IO bus width).
    let in_bits = arch.get("input_bitwidth");
    let dram_bytes =
        (wl.samples * wl.epochs * wl.features) as f64 * in_bits / 8.0;
    let dram_cycles = dram_bytes * 8.0 / (in_bits * 4.0); // AXI shim width

    let total_cycles = compute_cycles.max(dram_cycles) + reduce_cycles + sync_cycles;
    let busy = compute_cycles;
    let sram_active = compute_cycles * 0.8;

    let runtime_s = energy.seconds(total_cycles);
    let energy_j = energy.total(total_cycles, busy, sram_active, dram_bytes);
    SystemMetrics {
        runtime_s,
        energy_j,
        cycles: total_cycles,
        busy_frac: (busy / total_cycles).min(1.0),
        dram_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{BackendConfig, Enablement, SpnrFlow};
    use crate::generators::Platform;

    fn run_with(pu: f64, pe: f64, wl: &NonDnnWorkload) -> SystemMetrics {
        let arch = ArchConfig::new(Platform::Tabla, vec![pu, pe, 16.0, 16.0, 0.0]);
        let r = SpnrFlow::new(Enablement::Gf12, 0)
            .run(&arch, BackendConfig::new(0.8, 0.4))
            .unwrap();
        let e = EnergyModel::new(&r.backend, Enablement::Gf12);
        simulate_tabla(&arch, &r.backend, &e, wl)
    }

    #[test]
    fn more_engines_fewer_cycles() {
        let wl = NonDnnWorkload::standard(NonDnnAlgo::Backprop, 64);
        let small = run_with(4.0, 8.0, &wl);
        let big = run_with(8.0, 16.0, &wl);
        assert!(big.cycles < small.cycles);
    }

    #[test]
    fn scaling_is_sublinear_due_to_bus() {
        // compute-bound workload: backprop (recsys is DRAM-bound, where
        // engine scaling correctly does ~nothing)
        let wl = NonDnnWorkload::standard(NonDnnAlgo::Backprop, 64);
        let small = run_with(4.0, 8.0, &wl);
        let big = run_with(8.0, 16.0, &wl);
        let speedup = small.cycles / big.cycles;
        assert!(speedup < 4.0, "4x engines cannot give {speedup}x");
        assert!(speedup > 1.5, "speedup {speedup}");
    }

    #[test]
    fn recsys_is_memory_bound() {
        let wl = NonDnnWorkload::standard(NonDnnAlgo::Recsys, 64);
        let small = run_with(4.0, 8.0, &wl);
        let big = run_with(8.0, 16.0, &wl);
        let speedup = small.cycles / big.cycles;
        assert!(speedup < 1.6, "DRAM-bound workload should not scale: {speedup}");
    }

    #[test]
    fn backprop_heavier_than_svm() {
        let svm = run_with(8.0, 8.0, &NonDnnWorkload::standard(NonDnnAlgo::Svm, 64));
        let bp = run_with(8.0, 8.0, &NonDnnWorkload::standard(NonDnnAlgo::Backprop, 64));
        assert!(bp.runtime_s > svm.runtime_s);
        assert!(bp.energy_j > svm.energy_j);
    }
}
