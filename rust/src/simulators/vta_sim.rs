//! VTA performance simulator: GEMM core for pointwise/dense work, tensor
//! ALU for depthwise/pool/activation, all off-chip traffic serialized on
//! one shared bus (paper §5.1; MobileNet-v1 is the bound workload, whose
//! depthwise layers fall to the ALU — the characteristic VTA behaviour).

use crate::backend::BackendResult;
use crate::generators::ArchConfig;
use crate::workloads::{DnnWorkload, Layer};

use super::energy::EnergyModel;
use super::systolic::gemm_cost;
use super::SystemMetrics;

pub fn simulate_vta(
    arch: &ArchConfig,
    _backend: &BackendResult,
    energy: &EnergyModel,
    net: &DnnWorkload,
) -> SystemMetrics {
    let dim = arch.get("gemm_dim");
    let wbuf = arch.get("wbuf_kb") * 1024.0;
    let ibuf = arch.get("ibuf_kb") * 1024.0;
    let obuf = arch.get("obuf_kb") * 1024.0;
    let bus_bits = arch.get("offchip_bits");

    let mut total_cycles = 0.0;
    let mut busy = 0.0;
    let mut sram_active = 0.0;
    let mut dram_bytes = 0.0;

    for layer in &net.layers {
        match layer {
            Layer::Conv { .. } | Layer::Dense { .. } | Layer::MatMul { .. } => {
                let (m, k, n) = layer.as_gemm().unwrap();
                // single shared off-chip bus: all three streams use it
                let c = gemm_cost(
                    m as f64, k as f64, n as f64, dim, dim, wbuf, ibuf, obuf, bus_bits,
                    bus_bits, bus_bits, 1.0, 1.0,
                );
                // VTA's load/compute/store modules overlap via dependency
                // queues, but the single bus serializes the streams: the
                // transfer term can hide at most half its cycles.
                let layer_cycles = c.compute_cycles.max(c.dram_cycles) + 0.5 * c.dram_cycles.min(c.compute_cycles);
                total_cycles += layer_cycles;
                busy += c.compute_cycles;
                sram_active += c.compute_cycles;
                dram_bytes += c.dram_bytes;
            }
            Layer::DwConv { .. } | Layer::Pool { .. } | Layer::Act { .. } => {
                // tensor ALU: `dim` lanes, 2 cycles per element op
                // (read-modify-write through the register file)
                let ops = (layer.macs() + layer.vector_ops()) as f64;
                let cycles = 2.0 * ops / dim;
                let bytes = (layer.input_elems() + layer.output_elems()) as f64;
                let bus_cycles = bytes * 8.0 / bus_bits;
                total_cycles += cycles.max(bus_cycles);
                busy += cycles * 0.4; // ALU is a small fraction of the die
                sram_active += cycles;
                dram_bytes += bytes;
            }
        }
    }

    let runtime_s = energy.seconds(total_cycles);
    let energy_j = energy.total(total_cycles, busy, sram_active, dram_bytes);
    SystemMetrics {
        runtime_s,
        energy_j,
        cycles: total_cycles,
        busy_frac: (busy / total_cycles).min(1.0),
        dram_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{BackendConfig, Enablement, SpnrFlow};
    use crate::generators::Platform;
    use crate::workloads::{mobilenet_v1, DnnWorkload};

    fn run_with(values: Vec<f64>, net: &DnnWorkload) -> SystemMetrics {
        let arch = ArchConfig::new(Platform::Vta, values);
        let r = SpnrFlow::new(Enablement::Gf12, 0)
            .run(&arch, BackendConfig::new(0.9, 0.4))
            .unwrap();
        let e = EnergyModel::new(&r.backend, Enablement::Gf12);
        simulate_vta(&arch, &r.backend, &e, net)
    }

    fn base() -> Vec<f64> {
        vec![16.0, 128.0, 64.0, 256.0, 256.0]
    }

    #[test]
    fn wider_bus_reduces_runtime() {
        let mut narrow = base();
        narrow[4] = 64.0;
        let mut wide = base();
        wide[4] = 512.0;
        let mn = run_with(narrow, &mobilenet_v1());
        let mw = run_with(wide, &mobilenet_v1());
        assert!(mw.cycles < mn.cycles);
    }

    #[test]
    fn depthwise_layers_are_alu_bound() {
        // a depthwise-only net vs an equal-MAC pointwise net: dw slower
        let dw_net = DnnWorkload {
            name: "dw",
            layers: vec![Layer::DwConv { h: 56, w: 56, c: 256, k: 3, stride: 1 }],
        };
        let pw_net = DnnWorkload {
            name: "pw",
            layers: vec![Layer::Conv { h: 56, w: 56, cin: 9, cout: 256, k: 1, stride: 1 }],
        };
        assert_eq!(dw_net.layers[0].macs(), pw_net.layers[0].macs());
        let md = run_with(base(), &dw_net);
        let mp = run_with(base(), &pw_net);
        assert!(
            md.cycles > 2.0 * mp.cycles,
            "depthwise {} should be much slower than pointwise {}",
            md.cycles,
            mp.cycles
        );
    }

    #[test]
    fn mobilenet_runtime_plausible() {
        let m = run_with(base(), &mobilenet_v1());
        // 0.57 GMACs on 256 MACs at ~1 GHz: >= 2.2 ms ideal
        assert!(m.runtime_s > 1e-3 && m.runtime_s < 0.5, "runtime {}s", m.runtime_s);
    }
}
