//! GeneSys performance simulator: an M x N output-stationary systolic
//! array for GEMM/conv plus an N-lane SIMD unit for vector ops, with
//! double-buffered SRAM tiles over AXI (paper §5.1). Tiling, stall and
//! traffic accounting per layer; runtime/energy from the backend PPA.

use crate::backend::BackendResult;
use crate::generators::ArchConfig;
use crate::workloads::{DnnWorkload, Layer};

use super::energy::EnergyModel;
use super::SystemMetrics;

/// Per-layer cycle/traffic accounting for one GEMM on the array.
pub struct GemmCost {
    pub compute_cycles: f64,
    pub dram_cycles: f64,
    pub dram_bytes: f64,
    pub overlapped: bool,
}

/// Cost of M x K x N GEMM on an `am x an` array with the given buffer
/// capacities (bytes) and AXI widths (bits/cycle).
#[allow(clippy::too_many_arguments)]
pub fn gemm_cost(
    m: f64,
    k: f64,
    n: f64,
    am: f64,
    an: f64,
    wbuf_bytes: f64,
    ibuf_bytes: f64,
    obuf_bytes: f64,
    w_axi_bits: f64,
    i_axi_bits: f64,
    o_axi_bits: f64,
    wbytes_per_elem: f64,
    abytes_per_elem: f64,
) -> GemmCost {
    // Output tiles of am x an; each needs the K-deep reduction.
    let m_tiles = (m / am).ceil().max(1.0);
    let n_tiles = (n / an).ceil().max(1.0);
    // pipeline fill ~ am + an per tile
    let compute_cycles = m_tiles * n_tiles * (k + am + an);

    // Weight traffic: K x N once if a K x an weight tile fits (weights
    // stream per n-tile and are reused across m-tiles), else reloaded per
    // m-tile (poor weight reuse — this is the WBUF-capacity tradeoff the
    // paper's sampling exercises).
    let w_tile_bytes = k * an * wbytes_per_elem;
    let w_reloads = if w_tile_bytes <= wbuf_bytes { 1.0 } else { m_tiles };
    let w_bytes = k * n * wbytes_per_elem * w_reloads;

    // Input traffic: M x K once if an input tile fits, else per n-tile.
    let i_tile_bytes = am * k * abytes_per_elem;
    let i_reloads = if i_tile_bytes <= ibuf_bytes { 1.0 } else { n_tiles };
    let i_bytes = m * k * abytes_per_elem * i_reloads;

    // Output traffic: M x N written once (accumulated on-chip if the
    // output tile fits, else partial sums spill twice).
    let o_tile_bytes = am * an * 4.0;
    let o_spill = if o_tile_bytes <= obuf_bytes { 1.0 } else { 2.0 };
    let o_bytes = m * n * abytes_per_elem * o_spill;

    let dram_cycles =
        w_bytes * 8.0 / w_axi_bits + i_bytes * 8.0 / i_axi_bits + o_bytes * 8.0 / o_axi_bits;

    // Double buffering hides transfer under compute when every tile fits
    // at 2x (ping-pong).
    let overlapped = 2.0 * w_tile_bytes <= wbuf_bytes && 2.0 * i_tile_bytes <= ibuf_bytes;
    GemmCost { compute_cycles, dram_cycles, dram_bytes: w_bytes + i_bytes + o_bytes, overlapped }
}

pub fn simulate_genesys(
    arch: &ArchConfig,
    _backend: &BackendResult,
    energy: &EnergyModel,
    net: &DnnWorkload,
) -> SystemMetrics {
    let am = arch.get("array_dim");
    let an = am;
    let wbits = arch.get("weight_bits");
    let abits = arch.get("act_bits");
    let wbuf = arch.get("wbuf_kb") * 1024.0;
    let ibuf = arch.get("ibuf_kb") * 1024.0;
    let obuf = arch.get("obuf_kb") * 1024.0;
    let simd_lanes = an;

    let mut total_cycles = 0.0;
    let mut busy = 0.0;
    let mut sram_active = 0.0;
    let mut dram_bytes = 0.0;

    for layer in &net.layers {
        match layer.as_gemm() {
            Some((m, k, n)) => {
                let c = gemm_cost(
                    m as f64,
                    k as f64,
                    n as f64,
                    am,
                    an,
                    wbuf,
                    ibuf,
                    obuf,
                    arch.get("wbuf_axi_bits"),
                    arch.get("ibuf_axi_bits"),
                    arch.get("obuf_axi_bits"),
                    wbits / 8.0,
                    abits / 8.0,
                );
                let layer_cycles = if c.overlapped {
                    c.compute_cycles.max(c.dram_cycles)
                } else {
                    c.compute_cycles + c.dram_cycles
                };
                total_cycles += layer_cycles;
                busy += c.compute_cycles;
                sram_active += c.compute_cycles; // buffers toggle with the array
                dram_bytes += c.dram_bytes;
            }
            None => {
                // vector work on the SIMD array (pool/act/depthwise)
                let ops = (layer.vector_ops() + layer.macs()) as f64;
                let cycles = ops / simd_lanes;
                let bytes =
                    (layer.input_elems() + layer.output_elems()) as f64 * abits / 8.0;
                let axi_cycles = bytes * 8.0 / arch.get("simd_axi_bits");
                total_cycles += cycles.max(axi_cycles);
                busy += cycles * 0.6; // SIMD is narrower than the array
                sram_active += cycles;
                dram_bytes += bytes;
            }
        }
    }

    let runtime_s = energy.seconds(total_cycles);
    let energy_j = energy.total(total_cycles, busy, sram_active, dram_bytes);
    SystemMetrics {
        runtime_s,
        energy_j,
        cycles: total_cycles,
        busy_frac: (busy / total_cycles).min(1.0),
        dram_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{BackendConfig, Enablement, SpnrFlow};
    use crate::generators::Platform;
    use crate::workloads::resnet50;

    fn run_with(values: Vec<f64>) -> SystemMetrics {
        let arch = ArchConfig::new(Platform::GeneSys, values);
        let r = SpnrFlow::new(Enablement::Gf12, 0)
            .run(&arch, BackendConfig::new(0.9, 0.4))
            .unwrap();
        let e = EnergyModel::new(&r.backend, Enablement::Gf12);
        simulate_genesys(&arch, &r.backend, &e, &resnet50())
    }

    fn base() -> Vec<f64> {
        vec![16.0, 8.0, 8.0, 128.0, 64.0, 512.0, 512.0, 128.0, 256.0, 256.0, 256.0]
    }

    #[test]
    fn bigger_array_fewer_cycles() {
        let mut small = base();
        small[0] = 8.0;
        let mut big = base();
        big[0] = 32.0;
        let ms = run_with(small);
        let mb = run_with(big);
        assert!(mb.cycles < ms.cycles, "{} !< {}", mb.cycles, ms.cycles);
    }

    #[test]
    fn tiny_wbuf_causes_weight_reloads() {
        let mut tiny = base();
        tiny[3] = 16.0; // 16 KB WBUF
        let mut roomy = base();
        roomy[3] = 256.0;
        let mt = run_with(tiny);
        let mr = run_with(roomy);
        assert!(mt.dram_bytes > mr.dram_bytes * 1.2, "{} vs {}", mt.dram_bytes, mr.dram_bytes);
    }

    #[test]
    fn gemm_cost_accounting_sane() {
        let c = gemm_cost(
            3136.0, 576.0, 64.0, 16.0, 16.0, 131072.0, 65536.0, 524288.0, 128.0, 256.0,
            256.0, 1.0, 1.0,
        );
        assert!(c.compute_cycles >= 3136.0 / 16.0 * 4.0 * 576.0);
        assert!(c.dram_bytes >= 576.0 * 64.0); // at least one weight pass
    }

    #[test]
    fn resnet_runtime_order_of_magnitude() {
        let m = run_with(base());
        // 4.1 GMACs on a 256-MAC array at ~1 GHz: >= 16 ms ideal; with
        // stalls it should land within 16-500 ms.
        assert!(
            m.runtime_s > 5e-3 && m.runtime_s < 1.0,
            "runtime {}s out of plausible band",
            m.runtime_s
        );
    }
}
