//! Axiline performance simulator: the 3-stage training pipeline. Stage 1
//! (dot product) and stage 3 (update) each process one input vector in
//! `num_cycles` cycles across `dimension` lanes; the pipeline initiation
//! interval is `num_cycles`, and a vector whose feature count exceeds
//! dimension x num_cycles takes multiple passes (paper §8.3: "the count
//! of features handled by the Axiline design is num_cycles x size").

use crate::backend::BackendResult;
use crate::generators::ArchConfig;
use crate::workloads::{NonDnnAlgo, NonDnnWorkload};

use super::energy::EnergyModel;
use super::SystemMetrics;

pub fn simulate_axiline(
    arch: &ArchConfig,
    _backend: &BackendResult,
    energy: &EnergyModel,
    wl: &NonDnnWorkload,
) -> SystemMetrics {
    let dim = arch.get("dimension");
    let cycles_cfg = arch.get("num_cycles");

    let capacity = dim * cycles_cfg;
    let passes = (wl.features as f64 / capacity).ceil().max(1.0);

    // Initiation interval: one vector enters every num_cycles (x passes).
    let ii = cycles_cfg * passes;
    // Stage-2 latency: scalar update (+ sigmoid LUT for logistic).
    let stage2 = match wl.algo {
        NonDnnAlgo::LogisticRegression => 8.0,
        NonDnnAlgo::Recsys => 6.0,
        _ => 4.0,
    };
    let fill = 2.0 * cycles_cfg + stage2; // pipeline fill/drain per epoch

    let vectors = (wl.samples * wl.epochs) as f64;
    let total_cycles = vectors * ii + wl.epochs as f64 * fill;

    // Busy: lanes actually used may be a fraction of the array, but
    // clock gating is imperfect — idle lanes still burn ~35% of their
    // dynamic power (registers + clock mesh toggle regardless).
    let used = (wl.features as f64 / passes / cycles_cfg).min(dim);
    let busy = total_cycles * (0.35 + 0.65 * (used / dim)).clamp(0.05, 1.0);

    // Input stream: features x input bits per vector, each epoch.
    let in_bits = arch.get("input_bitwidth");
    let dram_bytes = vectors * wl.features as f64 * in_bits / 8.0;

    let runtime_s = energy.seconds(total_cycles);
    let energy_j = energy.total(total_cycles, busy, 0.0 /* no SRAM */, dram_bytes);
    SystemMetrics {
        runtime_s,
        energy_j,
        cycles: total_cycles,
        busy_frac: (busy / total_cycles).min(1.0),
        dram_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{BackendConfig, Enablement, SpnrFlow};
    use crate::generators::Platform;

    fn run_with(dim: f64, cyc: f64, features: usize) -> SystemMetrics {
        let arch = ArchConfig::new(Platform::Axiline, vec![0.0, 16.0, 8.0, dim, cyc]);
        let r = SpnrFlow::new(Enablement::Gf12, 0)
            .run(&arch, BackendConfig::new(1.0, 0.6))
            .unwrap();
        let e = EnergyModel::new(&r.backend, Enablement::Gf12);
        let wl = NonDnnWorkload::standard(NonDnnAlgo::Svm, features);
        simulate_axiline(&arch, &r.backend, &e, &wl)
    }

    #[test]
    fn fewer_cycles_is_faster() {
        let slow = run_with(20.0, 20.0, 55);
        let fast = run_with(20.0, 3.0, 55);
        assert!(fast.cycles < slow.cycles);
    }

    #[test]
    fn undersized_design_needs_extra_passes() {
        // capacity 5x2=10 < 55 features -> 6 passes
        let tiny = run_with(5.0, 2.0, 55);
        let fit = run_with(30.0, 2.0, 55);
        assert!(tiny.cycles > 4.0 * fit.cycles);
    }

    #[test]
    fn oversized_design_wastes_energy_not_time() {
        let fit = run_with(28.0, 2.0, 55);
        let oversized = run_with(60.0, 2.0, 55);
        assert!((oversized.cycles - fit.cycles).abs() / fit.cycles < 0.05);
        // bigger design, same cycles: more leakage energy
        assert!(oversized.energy_j > fit.energy_j);
    }
}
