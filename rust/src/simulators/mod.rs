//! System-level performance/energy simulators (paper §5.1): given a
//! hardware configuration's post-SP&R PPA characteristics and a workload,
//! compute end-to-end runtime and energy. Integration follows the paper:
//! the simulators take the backend flow's clock frequency, per-buffer
//! access energies and dynamic/leakage power as inputs — system metrics
//! are *tied to* backend PPA, which is the paper's core modelling point.

pub mod axiline_sim;
pub mod energy;
pub mod systolic;
pub mod tabla_sim;
pub mod vta_sim;

use anyhow::{bail, Result};

use crate::backend::{BackendResult, Enablement};
use crate::generators::{ArchConfig, Platform};
use crate::workloads::{self, DnnWorkload, NonDnnWorkload, WorkloadSpec};

pub use energy::EnergyModel;

/// End-to-end system metrics for one workload execution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SystemMetrics {
    /// Wall-clock runtime, seconds.
    pub runtime_s: f64,
    /// Total energy, joules.
    pub energy_j: f64,
    /// Total cycles (diagnostic).
    pub cycles: f64,
    /// Compute-busy cycle fraction (diagnostic).
    pub busy_frac: f64,
    /// Off-chip traffic, bytes (diagnostic).
    pub dram_bytes: f64,
}

/// Default per-platform workload binding (paper §7.1: ResNet-50 on
/// GeneSys, MobileNet-v1 on VTA, the benchmark parameter for
/// TABLA/Axiline).
pub fn default_workload_features(platform: Platform) -> usize {
    match platform {
        Platform::Tabla => 64,
        Platform::Axiline => 55, // the paper's DSE example: SVM w/ 55 features
        _ => 0,
    }
}

/// Whether a platform runs DNN layer tables (systolic simulators) as
/// opposed to non-DNN training algorithms (TABLA / Axiline).
pub fn is_dnn_platform(platform: Platform) -> bool {
    matches!(platform, Platform::GeneSys | Platform::Vta)
}

/// Registry name of the workload a platform runs when nothing is
/// requested explicitly (paper §7.1 bindings).
pub fn default_workload_name(platform: Platform) -> Option<&'static str> {
    match platform {
        Platform::GeneSys => Some("resnet50"),
        Platform::Vta => Some("mobilenet"),
        // Tabla/Axiline read the per-arch `benchmark` categorical
        Platform::Tabla | Platform::Axiline => None,
    }
}

/// Run the platform-appropriate simulator on its default workload
/// binding. All workload-name resolution goes through the
/// `workloads::lookup*` registry, so an arch whose `benchmark` value
/// names nothing registered errors with the available list.
pub fn simulate(
    arch: &ArchConfig,
    backend: &BackendResult,
    enablement: Enablement,
) -> Result<SystemMetrics> {
    let name = match default_workload_name(arch.platform) {
        Some(name) => name,
        None => arch
            .benchmark()
            .ok_or_else(|| anyhow::anyhow!("{} config without benchmark", arch.platform))?,
    };
    let features = default_workload_features(arch.platform);
    match workloads::lookup_with_features(name, features)? {
        WorkloadSpec::Dnn(net) => simulate_dnn(arch, backend, enablement, &net),
        WorkloadSpec::NonDnn(wl) => simulate_nondnn(arch, backend, enablement, &wl),
    }
}

/// Simulate with an explicit DNN layer table (the `--workload` axis on
/// GeneSys / VTA: resnet50, mobilenet, transformer, gcn, ...).
pub fn simulate_dnn(
    arch: &ArchConfig,
    backend: &BackendResult,
    enablement: Enablement,
    net: &DnnWorkload,
) -> Result<SystemMetrics> {
    let energy = EnergyModel::new(backend, enablement);
    match arch.platform {
        Platform::GeneSys => Ok(systolic::simulate_genesys(arch, backend, &energy, net)),
        Platform::Vta => Ok(vta_sim::simulate_vta(arch, backend, &energy, net)),
        p => bail!("{p} is not a DNN platform"),
    }
}

/// Simulate with an explicit non-DNN workload (DSE drives this: e.g.
/// Axiline-SVM with a specific feature count).
pub fn simulate_nondnn(
    arch: &ArchConfig,
    backend: &BackendResult,
    enablement: Enablement,
    wl: &NonDnnWorkload,
) -> Result<SystemMetrics> {
    let energy = EnergyModel::new(backend, enablement);
    match arch.platform {
        Platform::Tabla => Ok(tabla_sim::simulate_tabla(arch, backend, &energy, wl)),
        Platform::Axiline => Ok(axiline_sim::simulate_axiline(arch, backend, &energy, wl)),
        p => bail!("{p} is not a non-DNN platform"),
    }
}

/// Simulate with any registry workload, dispatched by spec kind.
pub fn simulate_spec(
    arch: &ArchConfig,
    backend: &BackendResult,
    enablement: Enablement,
    wl: &WorkloadSpec,
) -> Result<SystemMetrics> {
    match wl {
        WorkloadSpec::Dnn(net) => simulate_dnn(arch, backend, enablement, net),
        WorkloadSpec::NonDnn(w) => simulate_nondnn(arch, backend, enablement, w),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{BackendConfig, SpnrFlow};

    fn mid(p: Platform) -> ArchConfig {
        ArchConfig::new(
            p,
            p.param_space().iter().map(|s| s.kind.from_unit(0.5)).collect(),
        )
    }

    #[test]
    fn all_platforms_simulate() {
        for p in Platform::ALL {
            let arch = mid(p);
            let flow = SpnrFlow::new(Enablement::Gf12, 0);
            let r = flow.run(&arch, BackendConfig::new(0.8, 0.45)).unwrap();
            let m = simulate(&arch, &r.backend, Enablement::Gf12).unwrap();
            assert!(m.runtime_s > 0.0 && m.runtime_s.is_finite(), "{p}: {m:?}");
            assert!(m.energy_j > 0.0 && m.energy_j.is_finite(), "{p}: {m:?}");
            assert!(m.cycles > 0.0);
            assert!((0.0..=1.0).contains(&m.busy_frac), "{p}: busy={}", m.busy_frac);
        }
    }

    #[test]
    fn dnn_workload_matrix_simulates() {
        for p in [Platform::GeneSys, Platform::Vta] {
            let arch = mid(p);
            let flow = SpnrFlow::new(Enablement::Gf12, 0);
            let r = flow.run(&arch, BackendConfig::new(0.8, 0.45)).unwrap();
            for name in ["mobilenet", "resnet50", "transformer", "gcn"] {
                let WorkloadSpec::Dnn(net) = workloads::lookup(name).unwrap() else {
                    panic!("{name} is registered as a DNN workload")
                };
                let m = simulate_dnn(&arch, &r.backend, Enablement::Gf12, &net).unwrap();
                assert!(m.runtime_s > 0.0 && m.runtime_s.is_finite(), "{p}/{name}: {m:?}");
                assert!(m.energy_j > 0.0 && m.energy_j.is_finite(), "{p}/{name}: {m:?}");
                assert!(m.cycles > 0.0, "{p}/{name}: {m:?}");
            }
        }
    }

    #[test]
    fn workload_platform_mismatch_errors() {
        let arch = mid(Platform::Axiline);
        let flow = SpnrFlow::new(Enablement::Gf12, 0);
        let r = flow.run(&arch, BackendConfig::new(0.8, 0.45)).unwrap();
        let WorkloadSpec::Dnn(net) = workloads::lookup("transformer").unwrap() else {
            panic!("transformer is a DNN workload")
        };
        let err = simulate_dnn(&arch, &r.backend, Enablement::Gf12, &net).unwrap_err();
        assert!(err.to_string().contains("not a DNN platform"), "{err}");

        let varch = mid(Platform::Vta);
        let vr = flow.run(&varch, BackendConfig::new(0.8, 0.45)).unwrap();
        let wl = NonDnnWorkload::standard(crate::workloads::NonDnnAlgo::Svm, 55);
        let err = simulate_nondnn(&varch, &vr.backend, Enablement::Gf12, &wl).unwrap_err();
        assert!(err.to_string().contains("not a non-DNN platform"), "{err}");
    }

    #[test]
    fn faster_clock_shorter_runtime() {
        let p = Platform::GeneSys;
        let arch = mid(p);
        let flow = SpnrFlow::new(Enablement::Gf12, 0);
        let slow = flow.run(&arch, BackendConfig::new(0.3, 0.4)).unwrap().backend;
        let fast = flow.run(&arch, BackendConfig::new(1.2, 0.4)).unwrap().backend;
        let ms = simulate(&arch, &slow, Enablement::Gf12).unwrap();
        let mf = simulate(&arch, &fast, Enablement::Gf12).unwrap();
        assert!(mf.runtime_s < ms.runtime_s);
    }

    #[test]
    fn energy_runtime_tradeoff_exists() {
        // Fig. 3(a): pushing frequency up must eventually cost energy.
        let p = Platform::Axiline;
        let arch = mid(p);
        let flow = SpnrFlow::new(Enablement::Gf12, 0);
        let lo = flow.run(&arch, BackendConfig::new(0.5, 0.6)).unwrap().backend;
        let hi = flow.run(&arch, BackendConfig::new(2.2, 0.6)).unwrap().backend;
        let ml = simulate(&arch, &lo, Enablement::Gf12).unwrap();
        let mh = simulate(&arch, &hi, Enablement::Gf12).unwrap();
        assert!(mh.runtime_s < ml.runtime_s, "higher clock must be faster");
        let e_per_t_lo = ml.energy_j / ml.runtime_s;
        let e_per_t_hi = mh.energy_j / mh.runtime_s;
        assert!(e_per_t_hi > e_per_t_lo, "higher clock must burn more power");
    }
}
