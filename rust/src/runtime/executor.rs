//! PJRT execution engine: loads HLO-text artifacts, compiles them once on
//! the CPU PJRT client, caches the executables, and runs them with
//! host-side `Tensor` inputs.
//!
//! The engine is deliberately **not** Send (PjRtClient is Rc-based); the
//! coordinator gives it a dedicated service thread and talks to it over
//! channels (see coordinator::predict_server).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::artifacts::Manifest;
use crate::util::tensor::Tensor;

pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    pub stats: RefCell<EngineStats>,
}

#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub compiles: usize,
    pub compile_ms: f64,
    pub executions: usize,
    pub execute_ms: f64,
}

impl Engine {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(EngineStats::default()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the executable for an artifact file.
    pub fn executable(&self, file: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(file) {
            return Ok(exe.clone());
        }
        let path = self.manifest.dir.join(file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?,
        );
        {
            let mut st = self.stats.borrow_mut();
            st.compiles += 1;
            st.compile_ms += t0.elapsed().as_secs_f64() * 1e3;
        }
        self.cache.borrow_mut().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact: tensors in, tensors out. All our AOT
    /// entrypoints are lowered with `return_tuple=True`, so the single
    /// result literal is a tuple that we decompose.
    pub fn run(&self, file: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let exe = self.executable(file)?;
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let t0 = Instant::now();
        let result = exe.execute::<xla::Literal>(&lits)?;
        let out_lit = result
            .first()
            .and_then(|r| r.first())
            .context("empty execution result")?
            .to_literal_sync()?;
        {
            let mut st = self.stats.borrow_mut();
            st.executions += 1;
            st.execute_ms += t0.elapsed().as_secs_f64() * 1e3;
        }
        let parts = out_lit.to_tuple()?;
        parts.iter().map(Tensor::from_literal).collect()
    }

    /// Execute with shape validation against the manifest entrypoint —
    /// used by tests and the predict server's debug mode.
    pub fn run_checked(
        &self,
        variant: &str,
        entrypoint: &str,
        inputs: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        let var = self.manifest.variant(variant)?;
        let ep = var.entrypoint(entrypoint)?;
        if inputs.len() != ep.inputs.len() {
            bail!(
                "{variant}/{entrypoint}: expected {} inputs, got {}",
                ep.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, want)) in inputs.iter().zip(ep.inputs.iter()).enumerate() {
            if t.shape() != want.as_slice() {
                bail!(
                    "{variant}/{entrypoint} input {i}: shape {:?} != manifest {:?}",
                    t.shape(),
                    want
                );
            }
        }
        let outs = self.run(&ep.file, inputs)?;
        if outs.len() != ep.outputs.len() {
            bail!(
                "{variant}/{entrypoint}: got {} outputs, manifest says {}",
                outs.len(),
                ep.outputs.len()
            );
        }
        Ok(outs)
    }

    pub fn stats(&self) -> EngineStats {
        self.stats.borrow().clone()
    }
}

/// Load a fixture tensor written by aot.py (`.npy`, f32).
pub fn load_fixture(dir: &Path, name: &str) -> Result<Tensor> {
    use xla::FromRawBytes;
    let path = dir.join("fixtures").join(format!("{name}.npy"));
    let lit = xla::Literal::read_npy(&path, &())
        .map_err(|e| anyhow::anyhow!("reading {}: {e:?}", path.display()))?;
    Tensor::from_literal(&lit)
}
