//! AOT artifact manifest: the contract between `python/compile/aot.py`
//! (build time) and the rust hot path. Parses `artifacts/manifest.json`
//! and exposes typed shape/layout information for every compiled
//! entrypoint.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Manifest version this crate understands (bump with aot.py).
pub const MANIFEST_VERSION: usize = 3;

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub batch: usize,
    pub feat: usize,
    pub nodes: usize,
    pub node_feat: usize,
    pub epoch_steps: usize,
    pub variants: BTreeMap<String, Variant>,
}

#[derive(Debug, Clone)]
pub enum ModelArch {
    Ann { hidden: Vec<usize>, act: String },
    Gcn { conv_kind: String, conv_dims: Vec<usize>, fc_hidden: Vec<usize>, embed_dim: usize },
}

#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub offset: usize,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct Entrypoint {
    pub file: String,
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

#[derive(Debug, Clone)]
pub struct Variant {
    pub name: String,
    pub arch: ModelArch,
    pub param_total: usize,
    pub param_layout: Vec<ParamEntry>,
    pub entrypoints: BTreeMap<String, Entrypoint>,
}

fn shapes(j: &Json) -> Result<Vec<Vec<usize>>> {
    j.as_arr()
        .context("expected shape list")?
        .iter()
        .map(|s| {
            s.as_arr()
                .context("expected shape")?
                .iter()
                .map(|d| d.as_usize().context("expected dim"))
                .collect()
        })
        .collect()
}

fn usizes(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .context("expected int list")?
        .iter()
        .map(|d| d.as_usize().context("expected int"))
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let version = j.get("version").as_usize().context("manifest version")?;
        if version != MANIFEST_VERSION {
            bail!("manifest version {version} != expected {MANIFEST_VERSION}; re-run `make artifacts`");
        }

        let mut variants = BTreeMap::new();
        let vobj = j.get("variants").as_obj().context("variants")?;
        for (name, v) in vobj {
            let kind = v.get("kind").as_str().context("variant kind")?;
            let arch = match kind {
                "ann" => ModelArch::Ann {
                    hidden: usizes(v.get("hidden"))?,
                    act: v.get("act").as_str().unwrap_or("relu").to_string(),
                },
                "gcn" => ModelArch::Gcn {
                    conv_kind: v.get("conv_kind").as_str().unwrap_or("gcn").to_string(),
                    conv_dims: usizes(v.get("conv_dims"))?,
                    fc_hidden: usizes(v.get("fc_hidden"))?,
                    embed_dim: v.get("embed_dim").as_usize().context("embed_dim")?,
                },
                other => bail!("unknown variant kind {other}"),
            };
            let params = v.get("params");
            let param_total = params.get("total").as_usize().context("params.total")?;
            let mut param_layout = Vec::new();
            for e in params.get("entries").as_arr().context("params.entries")? {
                param_layout.push(ParamEntry {
                    name: e.get("name").as_str().context("entry name")?.to_string(),
                    offset: e.get("offset").as_usize().context("entry offset")?,
                    shape: usizes(e.get("shape"))?,
                });
            }
            let mut entrypoints = BTreeMap::new();
            for (ep_name, ep) in v.get("entrypoints").as_obj().context("entrypoints")? {
                entrypoints.insert(
                    ep_name.clone(),
                    Entrypoint {
                        file: ep.get("file").as_str().context("ep file")?.to_string(),
                        inputs: shapes(ep.get("inputs"))?,
                        outputs: shapes(ep.get("outputs"))?,
                    },
                );
            }
            variants.insert(
                name.clone(),
                Variant { name: name.clone(), arch, param_total, param_layout, entrypoints },
            );
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            batch: j.get("batch").as_usize().context("batch")?,
            feat: j.get("feat").as_usize().context("feat")?,
            nodes: j.get("nodes").as_usize().context("nodes")?,
            node_feat: j.get("node_feat").as_usize().context("node_feat")?,
            epoch_steps: j.get("epoch_steps").as_usize().unwrap_or(8),
            variants,
        })
    }

    pub fn variant(&self, name: &str) -> Result<&Variant> {
        self.variants
            .get(name)
            .with_context(|| format!("variant {name} not in manifest ({:?})", self.variant_names()))
    }

    pub fn variant_names(&self) -> Vec<&str> {
        self.variants.keys().map(|s| s.as_str()).collect()
    }

    pub fn ann_variants(&self) -> Vec<&Variant> {
        self.variants
            .values()
            .filter(|v| matches!(v.arch, ModelArch::Ann { .. }))
            .collect()
    }

    pub fn gcn_variants(&self) -> Vec<&Variant> {
        self.variants
            .values()
            .filter(|v| matches!(v.arch, ModelArch::Gcn { .. }))
            .collect()
    }

    /// Default artifacts directory: $FSO_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("FSO_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

impl Variant {
    pub fn entrypoint(&self, name: &str) -> Result<&Entrypoint> {
        self.entrypoints
            .get(name)
            .with_context(|| format!("variant {} has no entrypoint {name}", self.name))
    }

    pub fn is_gcn(&self) -> bool {
        matches!(self.arch, ModelArch::Gcn { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> Option<Manifest> {
        let dir = crate::test_support::artifacts_dir()?;
        Manifest::load(&dir).ok()
    }

    #[test]
    fn manifest_loads_and_has_expected_constants() {
        let Some(m) = repo_artifacts() else { return };
        assert_eq!(m.batch, 32);
        assert_eq!(m.feat, 16);
        assert_eq!(m.nodes, 128);
        assert_eq!(m.node_feat, 9);
        assert!(!m.ann_variants().is_empty());
        assert!(!m.gcn_variants().is_empty());
    }

    #[test]
    fn param_layout_is_contiguous() {
        let Some(m) = repo_artifacts() else { return };
        for v in m.variants.values() {
            let mut expect = 0usize;
            for e in &v.param_layout {
                assert_eq!(e.offset, expect, "{}/{}", v.name, e.name);
                expect += e.shape.iter().product::<usize>();
            }
            assert_eq!(expect, v.param_total, "{}", v.name);
        }
    }

    #[test]
    fn every_entrypoint_file_exists() {
        let Some(m) = repo_artifacts() else { return };
        for v in m.variants.values() {
            for ep in v.entrypoints.values() {
                assert!(m.dir.join(&ep.file).exists(), "{}", ep.file);
            }
        }
    }
}
