//! Runtime layer: load AOT artifacts (HLO text) produced by
//! `python/compile/aot.py`, compile them on the PJRT CPU client via the
//! `xla` crate, and execute them from the coordinator hot path.
//!
//! Python never runs at serving/training time: `make artifacts` is the
//! only python invocation, and the rust binary is self-contained after it.

pub mod artifacts;
pub mod batcher;
pub mod executor;

pub use artifacts::{Entrypoint, Manifest, ModelArch, ParamEntry, Variant};
pub use batcher::{BatchPlan, Batcher};
pub use executor::{load_fixture, Engine, EngineStats};
