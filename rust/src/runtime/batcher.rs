//! Dynamic batching: the AOT artifacts are compiled for a fixed batch size
//! B, but callers (model evaluation, MOTPE DSE, the predict server) arrive
//! with arbitrary numbers of rows. The `Batcher` plans how a stream of
//! requests is packed into full B-row calls — padding the tail batch and
//! guaranteeing that every request is answered exactly once, in order.
//!
//! This is the vLLM-router-shaped piece of L3: requests are coalesced to
//! amortize the PJRT call overhead, and padding rows are masked out with
//! zero loss-weights / ignored outputs.

/// A planned batch: `rows` source indices, padded to `batch_size` rows.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchPlan {
    /// Source row indices occupying the first `rows.len()` slots.
    pub rows: Vec<usize>,
    /// Fixed AOT batch size (slots `rows.len()..batch_size` are padding).
    pub batch_size: usize,
}

impl BatchPlan {
    pub fn valid_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn padding(&self) -> usize {
        self.batch_size - self.rows.len()
    }
}

#[derive(Debug, Clone)]
pub struct Batcher {
    batch_size: usize,
}

impl Batcher {
    pub fn new(batch_size: usize) -> Self {
        assert!(batch_size > 0);
        Batcher { batch_size }
    }

    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    /// Split `n` requests into ceil(n / B) plans covering 0..n in order.
    pub fn plan(&self, n: usize) -> Vec<BatchPlan> {
        let mut plans = Vec::with_capacity(n.div_ceil(self.batch_size));
        let mut start = 0;
        while start < n {
            let end = (start + self.batch_size).min(n);
            plans.push(BatchPlan {
                rows: (start..end).collect(),
                batch_size: self.batch_size,
            });
            start = end;
        }
        plans
    }

    /// Pack a feature matrix (`rows` of length `width` each) according to
    /// a plan: returns a dense [B, width] buffer, padding rows zeroed.
    pub fn pack(&self, plan: &BatchPlan, rows: &[Vec<f32>], width: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.batch_size * width];
        for (slot, &src) in plan.rows.iter().enumerate() {
            debug_assert_eq!(rows[src].len(), width);
            out[slot * width..(slot + 1) * width].copy_from_slice(&rows[src]);
        }
        out
    }

    /// Per-row validity weights for a plan ([B], 1.0 = real, 0.0 = pad).
    pub fn weights(&self, plan: &BatchPlan) -> Vec<f32> {
        let mut w = vec![0.0f32; self.batch_size];
        for slot in 0..plan.rows.len() {
            w[slot] = 1.0;
        }
        w
    }

    /// Scatter a batched output [B] back into a caller-sized buffer.
    pub fn unpack(&self, plan: &BatchPlan, batch_out: &[f32], out: &mut [f32]) {
        debug_assert!(batch_out.len() >= plan.rows.len());
        for (slot, &src) in plan.rows.iter().enumerate() {
            out[src] = batch_out[slot];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_covers_all_rows_once_in_order() {
        let b = Batcher::new(8);
        for n in [0usize, 1, 7, 8, 9, 16, 100] {
            let plans = b.plan(n);
            let mut seen = Vec::new();
            for p in &plans {
                assert!(p.rows.len() <= 8);
                assert_eq!(p.batch_size, 8);
                seen.extend_from_slice(&p.rows);
            }
            assert_eq!(seen, (0..n).collect::<Vec<_>>(), "n={n}");
        }
    }

    #[test]
    fn only_tail_batch_is_partial() {
        let b = Batcher::new(4);
        let plans = b.plan(10);
        assert_eq!(plans.len(), 3);
        assert_eq!(plans[0].valid_rows(), 4);
        assert_eq!(plans[1].valid_rows(), 4);
        assert_eq!(plans[2].valid_rows(), 2);
        assert_eq!(plans[2].padding(), 2);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let b = Batcher::new(4);
        let rows: Vec<Vec<f32>> = (0..6).map(|i| vec![i as f32, i as f32 + 0.5]).collect();
        let plans = b.plan(rows.len());
        let mut out = vec![0.0f32; rows.len()];
        for p in &plans {
            let packed = b.pack(p, &rows, 2);
            // emulate identity model on column 0
            let batch_out: Vec<f32> = (0..4).map(|s| packed[s * 2]).collect();
            b.unpack(p, &batch_out, &mut out);
        }
        assert_eq!(out, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn weights_mark_padding() {
        let b = Batcher::new(4);
        let plans = b.plan(5);
        assert_eq!(b.weights(&plans[1]), vec![1.0, 0.0, 0.0, 0.0]);
    }
}
