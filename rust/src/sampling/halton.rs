//! Halton low-discrepancy sequence (paper §5.2): radical-inverse in a
//! distinct prime base per dimension, with the common leap/scramble-free
//! "skip the first points" burn-in to avoid the correlated prefix, plus a
//! seed-keyed digital shift so different seeds give different (still
//! low-discrepancy) point sets.

use crate::util::rng::Rng;

const PRIMES: [u64; 16] = [2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53];

pub struct Halton {
    dim: usize,
    index: u64,
    shift: Vec<f64>,
}

/// Van der Corput radical inverse of `n` in base `b`.
pub fn radical_inverse(mut n: u64, b: u64) -> f64 {
    let mut inv = 0.0;
    let mut denom = 1.0;
    while n > 0 {
        denom *= b as f64;
        inv += (n % b) as f64 / denom;
        n /= b;
    }
    inv
}

impl Halton {
    pub fn new(dim: usize, seed: u64) -> Halton {
        assert!(dim <= PRIMES.len(), "halton supports up to {} dims", PRIMES.len());
        let mut rng = Rng::new(seed ^ 0xA117_0BA5);
        let shift = (0..dim).map(|_| rng.f64()).collect();
        Halton { dim, index: 20, shift } // skip the first 20 (burn-in)
    }

    pub fn next_point(&mut self) -> Vec<f64> {
        self.index += 1;
        (0..self.dim)
            .map(|d| {
                let v = radical_inverse(self.index, PRIMES[d]) + self.shift[d];
                v - v.floor()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn radical_inverse_base2_known_values() {
        assert_eq!(radical_inverse(1, 2), 0.5);
        assert_eq!(radical_inverse(2, 2), 0.25);
        assert_eq!(radical_inverse(3, 2), 0.75);
        assert_eq!(radical_inverse(4, 2), 0.125);
    }

    #[test]
    fn points_distinct_and_bounded() {
        let mut h = Halton::new(6, 1);
        let pts: Vec<Vec<f64>> = (0..128).map(|_| h.next_point()).collect();
        for p in &pts {
            for &x in p {
                assert!((0.0..1.0).contains(&x));
            }
        }
        for i in 0..pts.len() {
            for j in (i + 1)..pts.len() {
                assert_ne!(pts[i], pts[j]);
            }
        }
    }

    #[test]
    fn one_dim_projection_is_even() {
        let mut h = Halton::new(1, 3);
        let n = 256;
        let mut count = 0;
        for _ in 0..n {
            if h.next_point()[0] < 0.5 {
                count += 1;
            }
        }
        let frac = count as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.05, "frac={frac}");
    }
}
