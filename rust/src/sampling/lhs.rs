//! Latin Hypercube sampling with maximin improvement (paper §5.2: "we
//! maximize the minimum pairwise distance of the sampled points").
//!
//! Each of the n samples occupies a distinct 1/n stratum per dimension;
//! the permutation assignment is then improved by random restarts +
//! pairwise swaps under the maximin criterion.

use crate::util::rng::Rng;

pub struct Lhs {
    dim: usize,
    rng: Rng,
    /// random restarts for maximin improvement
    pub restarts: usize,
    /// swap-improvement iterations per restart
    pub swaps: usize,
}

/// Stratum (bin) index of a unit-interval coordinate among `n` bins.
/// Clamped to `n - 1`: the naive `(coord * n) as usize` indexes out of
/// bounds when a coordinate equals exactly 1.0 (legal closed-interval
/// input from boundary knobs) — ISSUE 3 satellite. This is the single
/// binning rule for unit coordinates: `ParamKind::from_unit`'s
/// discrete arms route through it, as does the stratification check.
pub fn stratum(coord: f64, n: usize) -> usize {
    debug_assert!(n > 0);
    ((coord * n as f64) as usize).min(n - 1)
}

impl Lhs {
    pub fn new(dim: usize, seed: u64) -> Lhs {
        Lhs { dim, rng: Rng::new(seed ^ 0x1A5D_17C3), restarts: 6, swaps: 200 }
    }

    fn raw(&mut self, n: usize) -> Vec<Vec<f64>> {
        let mut cols: Vec<Vec<f64>> = Vec::with_capacity(self.dim);
        for _ in 0..self.dim {
            let mut strata: Vec<usize> = (0..n).collect();
            self.rng.shuffle(&mut strata);
            cols.push(
                strata
                    .iter()
                    .map(|&s| (s as f64 + self.rng.f64()) / n as f64)
                    .collect(),
            );
        }
        (0..n)
            .map(|i| (0..self.dim).map(|d| cols[d][i]).collect())
            .collect()
    }

    fn min_dist2(points: &[Vec<f64>]) -> f64 {
        let mut best = f64::INFINITY;
        for i in 0..points.len() {
            for j in (i + 1)..points.len() {
                let d: f64 = points[i]
                    .iter()
                    .zip(points[j].iter())
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                best = best.min(d);
            }
        }
        best
    }

    /// Generate n samples (regenerates the full set — LHS cannot extend).
    ///
    /// Maximin improvement is incremental (§Perf): a cached pairwise
    /// distance matrix is updated only on the two rows a swap touches,
    /// and the global min is a scan of cached values — no O(n^2 d)
    /// recomputation per candidate swap.
    pub fn sample(&mut self, n: usize) -> Vec<Vec<f64>> {
        if n == 0 {
            return Vec::new();
        }
        if n == 1 {
            return self.raw(1);
        }
        let mut best = self.raw(n);
        let mut best_score = Self::min_dist2(&best);
        for _ in 0..self.restarts {
            let mut cand = self.raw(n);
            // cached pairwise squared distances (row-major upper use)
            let mut d2 = vec![0.0f64; n * n];
            let mut fill_row = |cand: &Vec<Vec<f64>>, d2: &mut Vec<f64>, r: usize| {
                for k in 0..n {
                    if k == r {
                        continue;
                    }
                    let v: f64 = cand[r]
                        .iter()
                        .zip(cand[k].iter())
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum();
                    d2[r * n + k] = v;
                    d2[k * n + r] = v;
                }
            };
            for r in 0..n {
                fill_row(&cand, &mut d2, r);
            }
            let min_of = |d2: &Vec<f64>| -> f64 {
                let mut m = f64::INFINITY;
                for i in 0..n {
                    for k in (i + 1)..n {
                        m = m.min(d2[i * n + k]);
                    }
                }
                m
            };
            let mut cur = min_of(&d2);
            for _ in 0..self.swaps {
                let i = self.rng.below(n);
                let j = self.rng.below(n);
                if i == j {
                    continue;
                }
                let d = self.rng.below(self.dim);
                let swap_coord = |cand: &mut Vec<Vec<f64>>| {
                    let tmp = cand[i][d];
                    cand[i][d] = cand[j][d];
                    cand[j][d] = tmp;
                };
                swap_coord(&mut cand);
                fill_row(&cand, &mut d2, i);
                fill_row(&cand, &mut d2, j);
                let after = min_of(&d2);
                if after < cur {
                    swap_coord(&mut cand); // revert
                    fill_row(&cand, &mut d2, i);
                    fill_row(&cand, &mut d2, j);
                } else {
                    cur = after;
                }
            }
            if cur > best_score {
                best_score = cur;
                best = cand;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stratification_holds_per_dimension() {
        let mut lhs = Lhs::new(4, 42);
        let n = 20;
        let pts = lhs.sample(n);
        for d in 0..4 {
            let mut strata: Vec<usize> = pts.iter().map(|p| stratum(p[d], n)).collect();
            strata.sort_unstable();
            assert_eq!(strata, (0..n).collect::<Vec<_>>(), "dim {d} not stratified");
        }
    }

    #[test]
    fn maximin_improves_over_raw() {
        let mut plain = Lhs::new(3, 7);
        plain.restarts = 0;
        plain.swaps = 0;
        let mut tuned = Lhs::new(3, 7);
        let p_raw = plain.sample(16);
        let p_opt = tuned.sample(16);
        assert!(
            Lhs::min_dist2(&p_opt) >= Lhs::min_dist2(&p_raw) * 0.99,
            "maximin must not be worse"
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Lhs::new(3, 5).sample(12);
        let b = Lhs::new(3, 5).sample(12);
        assert_eq!(a, b);
    }

    #[test]
    fn stratum_clamps_the_closed_upper_boundary() {
        // (1.0 * n) as usize == n — one past the last legal bin
        assert_eq!(stratum(1.0, 20), 19);
        assert_eq!(stratum(1.0, 1), 0);
        assert_eq!(stratum(0.999_999, 20), 19);
        assert_eq!(stratum(0.0, 20), 0);
        assert_eq!(stratum(0.05, 20), 1);
        // every bin index stays in range across the closed interval
        for i in 0..=100 {
            let c = i as f64 / 100.0;
            assert!(stratum(c, 7) < 7, "coord {c}");
        }
    }

    #[test]
    fn degenerate_sizes() {
        assert!(Lhs::new(2, 1).sample(0).is_empty());
        assert_eq!(Lhs::new(2, 1).sample(1).len(), 1);
    }
}
