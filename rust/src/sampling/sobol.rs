//! Sobol low-discrepancy sequence (paper §5.2): gray-code construction
//! over per-dimension direction numbers (Joe–Kuo primitive polynomials,
//! first 16 dimensions), with a seed-keyed digital XOR scramble.

use crate::util::rng::Rng;

/// (degree, coefficient a, initial m values) for dims 2..=16; dim 1 is
/// the van der Corput base-2 sequence. From Joe & Kuo's table.
const JOE_KUO: [(u32, u32, [u32; 8]); 15] = [
    (1, 0, [1, 0, 0, 0, 0, 0, 0, 0]),
    (2, 1, [1, 3, 0, 0, 0, 0, 0, 0]),
    (3, 1, [1, 3, 1, 0, 0, 0, 0, 0]),
    (3, 2, [1, 1, 1, 0, 0, 0, 0, 0]),
    (4, 1, [1, 1, 3, 3, 0, 0, 0, 0]),
    (4, 4, [1, 3, 5, 13, 0, 0, 0, 0]),
    (5, 2, [1, 1, 5, 5, 17, 0, 0, 0]),
    (5, 4, [1, 1, 5, 5, 5, 0, 0, 0]),
    (5, 7, [1, 1, 7, 11, 19, 0, 0, 0]),
    (5, 11, [1, 1, 5, 1, 1, 0, 0, 0]),
    (5, 13, [1, 1, 1, 3, 11, 0, 0, 0]),
    (5, 14, [1, 3, 5, 5, 31, 0, 0, 0]),
    (6, 1, [1, 3, 3, 9, 7, 49, 0, 0]),
    (6, 13, [1, 1, 1, 15, 21, 21, 0, 0]),
    (6, 16, [1, 3, 1, 13, 27, 49, 0, 0]),
];

const BITS: u32 = 30;

pub struct Sobol {
    dim: usize,
    index: u64,
    /// current XOR state per dimension (gray-code update)
    state: Vec<u32>,
    /// direction numbers: dir[d][bit]
    dir: Vec<[u32; BITS as usize]>,
    /// seed-keyed digital scramble
    scramble: Vec<u32>,
}

impl Sobol {
    pub fn new(dim: usize, seed: u64) -> Sobol {
        assert!(dim <= 16, "sobol table covers 16 dims");
        let mut dir = Vec::with_capacity(dim);
        // dim 0: van der Corput
        let mut v0 = [0u32; BITS as usize];
        for (i, v) in v0.iter_mut().enumerate() {
            *v = 1 << (BITS - 1 - i as u32);
        }
        dir.push(v0);
        for d in 1..dim {
            let (s, a, m_init) = JOE_KUO[d - 1];
            let s = s as usize;
            let mut m = [0u64; BITS as usize];
            for i in 0..s {
                m[i] = m_init[i] as u64;
            }
            for i in s..BITS as usize {
                let mut val = m[i - s] ^ (m[i - s] << s);
                for k in 1..s {
                    let bit = (a >> (s - 1 - k)) & 1;
                    if bit == 1 {
                        val ^= m[i - k] << k;
                    }
                }
                m[i] = val;
            }
            let mut v = [0u32; BITS as usize];
            for i in 0..BITS as usize {
                v[i] = (m[i] << (BITS - 1 - i as u32)) as u32;
            }
            dir.push(v);
        }
        let mut rng = Rng::new(seed ^ 0x50B0_15E9_u64);
        let scramble = (0..dim).map(|_| (rng.next_u64() as u32) & ((1 << BITS) - 1)).collect();
        Sobol { dim, index: 0, state: vec![0; dim], dir, scramble }
    }

    pub fn next_point(&mut self) -> Vec<f64> {
        // Emit x_index, then advance the gray-code state: x_{i+1} =
        // x_i ^ v_{c(i)} with c(i) the lowest zero bit of i; x_0 = 0.
        // Emitting x_0 keeps the exact (t,m)-net balance over any 2^m
        // prefix (the digital scramble preserves it).
        let scale = 1.0 / (1u64 << BITS) as f64;
        let out = (0..self.dim)
            .map(|d| ((self.state[d] ^ self.scramble[d]) as f64) * scale)
            .collect();
        let c = (!self.index).trailing_zeros().min(BITS - 1);
        self.index += 1;
        for d in 0..self.dim {
            self.state[d] ^= self.dir[d][c as usize];
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unscrambled_prefix_matches_canonical() {
        // canonical unscrambled Sobol dim-2 prefix: (0.5,0.5), (0.75,0.25),
        // (0.25,0.75), ...
        let mut s = Sobol::new(2, 0);
        s.scramble = vec![0, 0];
        assert_eq!(s.next_point(), vec![0.0, 0.0]);
        assert_eq!(s.next_point(), vec![0.5, 0.5]);
        assert_eq!(s.next_point(), vec![0.75, 0.25]);
        assert_eq!(s.next_point(), vec![0.25, 0.75]);
    }

    #[test]
    fn balanced_in_every_dyadic_half() {
        let mut s = Sobol::new(8, 42);
        let n = 256;
        let pts: Vec<Vec<f64>> = (0..n).map(|_| s.next_point()).collect();
        for d in 0..8 {
            let below = pts.iter().filter(|p| p[d] < 0.5).count();
            assert_eq!(below, n / 2, "dim {d}: {below}/{n} below 0.5");
        }
    }

    #[test]
    fn pairwise_2d_projections_spread() {
        let mut s = Sobol::new(6, 1);
        let n = 64;
        let pts: Vec<Vec<f64>> = (0..n).map(|_| s.next_point()).collect();
        // each quadrant of each (i,j) projection gets n/4 +- 4 points
        for i in 0..6 {
            for j in (i + 1)..6 {
                let mut q = [0usize; 4];
                for p in &pts {
                    let qi = (p[i] >= 0.5) as usize * 2 + (p[j] >= 0.5) as usize;
                    q[qi] += 1;
                }
                for (k, &c) in q.iter().enumerate() {
                    assert!(
                        (c as i64 - (n / 4) as i64).abs() <= 4,
                        "proj ({i},{j}) quadrant {k}: {c}"
                    );
                }
            }
        }
    }

    #[test]
    fn deterministic_per_seed_and_distinct_across_seeds() {
        let a: Vec<_> = {
            let mut s = Sobol::new(3, 9);
            (0..8).map(|_| s.next_point()).collect()
        };
        let b: Vec<_> = {
            let mut s = Sobol::new(3, 9);
            (0..8).map(|_| s.next_point()).collect()
        };
        assert_eq!(a, b);
        let c: Vec<_> = {
            let mut s = Sobol::new(3, 10);
            (0..8).map(|_| s.next_point()).collect()
        };
        assert_ne!(a, c);
    }
}
