//! Sampling methods for dataset generation and DSE seeding (paper §5.2):
//! Latin Hypercube sampling with maximin improvement, and two
//! low-discrepancy sequences (Sobol, Halton). All three emit points in
//! the unit hypercube; `ParamKind::from_unit` quantizes them onto each
//! platform's architectural/backend grids so every sampler shares one
//! discretization rule.

pub mod halton;
pub mod lhs;
pub mod sobol;

pub use lhs::stratum;

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SamplerKind {
    Lhs,
    Sobol,
    Halton,
}

impl SamplerKind {
    pub const ALL: [SamplerKind; 3] = [SamplerKind::Lhs, SamplerKind::Sobol, SamplerKind::Halton];

    pub fn name(&self) -> &'static str {
        match self {
            SamplerKind::Lhs => "lhs",
            SamplerKind::Sobol => "sobol",
            SamplerKind::Halton => "halton",
        }
    }
}

/// A unit-hypercube sampler.
pub enum Sampler {
    Lhs(lhs::Lhs),
    Sobol(sobol::Sobol),
    Halton(halton::Halton),
}

impl Sampler {
    pub fn new(kind: SamplerKind, dim: usize, seed: u64) -> Sampler {
        match kind {
            SamplerKind::Lhs => Sampler::Lhs(lhs::Lhs::new(dim, seed)),
            SamplerKind::Sobol => Sampler::Sobol(sobol::Sobol::new(dim, seed)),
            SamplerKind::Halton => Sampler::Halton(halton::Halton::new(dim, seed)),
        }
    }

    /// Draw `n` points. NB: LHS regenerates the whole set for a given n
    /// (adding points would break the stratification — paper §5.2
    /// discusses exactly this LHS-vs-LDS tradeoff), while Sobol/Halton
    /// extend their sequences.
    pub fn sample(&mut self, n: usize) -> Vec<Vec<f64>> {
        match self {
            Sampler::Lhs(s) => s.sample(n),
            Sampler::Sobol(s) => (0..n).map(|_| s.next_point()).collect(),
            Sampler::Halton(s) => (0..n).map(|_| s.next_point()).collect(),
        }
    }
}

/// Map unit-cube points onto a parameter space.
pub fn quantize(points: &[Vec<f64>], space: &[crate::generators::ParamSpec]) -> Vec<Vec<f64>> {
    points
        .iter()
        .map(|p| {
            space
                .iter()
                .zip(p.iter())
                .map(|(s, &u)| s.kind.from_unit(u))
                .collect()
        })
        .collect()
}

/// Minimum pairwise L2 distance (maximin criterion diagnostic).
pub fn min_pairwise_distance(points: &[Vec<f64>]) -> f64 {
    let mut best = f64::INFINITY;
    for i in 0..points.len() {
        for j in (i + 1)..points.len() {
            let d: f64 = points[i]
                .iter()
                .zip(points[j].iter())
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            best = best.min(d.sqrt());
        }
    }
    best
}

/// Centred L2 star discrepancy proxy: mean absolute deviation of box
/// counts from volume over random axis-aligned boxes (cheap uniformity
/// diagnostic used by tests and the Table-3 experiment).
pub fn uniformity_deficit(points: &[Vec<f64>], probes: usize, seed: u64) -> f64 {
    if points.is_empty() {
        return 1.0;
    }
    let dim = points[0].len();
    let mut rng = Rng::new(seed);
    let mut acc = 0.0;
    for _ in 0..probes {
        let corner: Vec<f64> = (0..dim).map(|_| rng.f64()).collect();
        let vol: f64 = corner.iter().product();
        let inside = points
            .iter()
            .filter(|p| p.iter().zip(corner.iter()).all(|(x, c)| x <= c))
            .count() as f64
            / points.len() as f64;
        acc += (inside - vol).abs();
    }
    acc / probes as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_samplers_in_unit_cube() {
        for kind in SamplerKind::ALL {
            let mut s = Sampler::new(kind, 5, 42);
            for p in s.sample(64) {
                assert_eq!(p.len(), 5);
                for x in p {
                    assert!((0.0..1.0).contains(&x), "{kind:?}: {x}");
                }
            }
        }
    }

    #[test]
    fn samplers_beat_random_uniformity_on_average() {
        // averaged over seeds: LHS optimizes stratification + maximin
        // (not star discrepancy), so require parity there and strict
        // dominance for the LDS methods.
        let dim = 4;
        let n = 64;
        let seeds = [3u64, 7, 11, 19];
        let avg = |kind: Option<SamplerKind>| -> f64 {
            seeds
                .iter()
                .map(|&seed| {
                    let pts = match kind {
                        Some(k) => Sampler::new(k, dim, seed).sample(n),
                        None => {
                            let mut rng = Rng::new(seed);
                            (0..n).map(|_| (0..dim).map(|_| rng.f64()).collect()).collect()
                        }
                    };
                    uniformity_deficit(&pts, 512, 1)
                })
                .sum::<f64>()
                / seeds.len() as f64
        };
        let rand_deficit = avg(None);
        for kind in [SamplerKind::Sobol, SamplerKind::Halton] {
            let d = avg(Some(kind));
            assert!(d < rand_deficit, "{kind:?}: {d} !< random {rand_deficit}");
        }
        let lhs = avg(Some(SamplerKind::Lhs));
        assert!(lhs < rand_deficit * 1.15, "lhs {lhs} vs random {rand_deficit}");
    }

    #[test]
    fn quantize_respects_grids() {
        use crate::generators::Platform;
        let space = Platform::Axiline.param_space();
        let mut s = Sampler::new(SamplerKind::Lhs, space.len(), 3);
        let pts = quantize(&s.sample(32), &space);
        for p in &pts {
            assert!(p[1] == 8.0 || p[1] == 16.0, "bitwidth grid: {}", p[1]);
            assert!((5.0..=60.0).contains(&p[3]), "dimension range");
            assert_eq!(p[3].fract(), 0.0, "integer param");
        }
    }

    #[test]
    fn lds_extension_reuses_prefix() {
        // the LDS property the paper highlights: extending the sequence
        // keeps previous points
        for kind in [SamplerKind::Sobol, SamplerKind::Halton] {
            let mut a = Sampler::new(kind, 3, 42);
            let first = a.sample(8);
            let mut b = Sampler::new(kind, 3, 42);
            let longer = b.sample(16);
            assert_eq!(&longer[..8], &first[..], "{kind:?}");
        }
    }
}
