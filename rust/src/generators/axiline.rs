//! Axiline generator (paper §5.1, Table 1): hard-coded 3-stage pipelined
//! implementations of small ML training algorithms (SVM, linear/logistic
//! regression, recommender systems).
//!
//! Architectural parameters (Table 1):
//!   benchmark       ∈ {svm, linear_regression, logistic_regression, recsys}
//!   bitwidth        ∈ {8, 16}      computation unit width
//!   input bitwidth  ∈ {4, 8}       initial input width
//!   dimension       ∈ [5, 60]      stage-1/3 dimension
//!   num of cycles   ∈ [1, 25]      cycles per input vector in stage 1/3

use super::features as f;
use super::{ArchConfig, ModuleNode, ModuleTree, ParamKind, ParamSpec, Platform};

pub const BENCHMARKS: [&str; 4] =
    ["svm", "linear_regression", "logistic_regression", "recsys"];

pub fn param_space() -> Vec<ParamSpec> {
    vec![
        ParamSpec { name: "benchmark", kind: ParamKind::Cat(BENCHMARKS.to_vec()) },
        ParamSpec { name: "bitwidth", kind: ParamKind::Choice(vec![8.0, 16.0]) },
        ParamSpec { name: "input_bitwidth", kind: ParamKind::Choice(vec![4.0, 8.0]) },
        ParamSpec { name: "dimension", kind: ParamKind::Int { lo: 5, hi: 60 } },
        ParamSpec { name: "num_cycles", kind: ParamKind::Int { lo: 1, hi: 25 } },
    ]
}

pub fn generate(cfg: &ArchConfig) -> ModuleTree {
    let bits = cfg.get("bitwidth");
    let in_bits = cfg.get("input_bitwidth");
    let dim = cfg.get("dimension");
    let cycles = cfg.get("num_cycles");
    // Stage 1/3 process `dim` lanes over `num_cycles` cycles: fewer cycles
    // means more parallel MACs.
    let lanes = (dim / cycles).ceil().max(1.0);
    let is_logistic = cfg.benchmark() == Some("logistic_regression");
    let is_recsys = cfg.benchmark() == Some("recsys");

    // Stage 1: dot-product / feature-gather array.
    let mut mac = f::mac_unit(bits, 2.0 * bits + 8.0);
    mac.multiplicity = lanes;
    let stage1 = ModuleNode::with_children(
        "stage1_dot",
        f::comb_block(3.0, 1.0, bits, 40.0 * lanes, 8.0 * lanes, 2.8),
        vec![
            ModuleNode::leaf("mac_lane", mac),
            ModuleNode::leaf(
                "reduce_tree",
                f::comb_block(lanes, 1.0, 2.0 * bits, 12.0 * lanes * bits / 4.0, 2.0 * bits, 2.0),
            ),
        ],
    );

    // Stage 2: scalar nonlinearity / update rule.
    let nl_cells = if is_logistic {
        // piecewise sigmoid LUT + interpolation
        420.0 + 30.0 * bits
    } else {
        160.0 + 12.0 * bits
    };
    let stage2 = ModuleNode::with_children(
        "stage2_update",
        f::comb_block(2.0, 2.0, bits, nl_cells, 6.0 * bits, 3.1),
        vec![ModuleNode::leaf("alu", f::alu_lane(bits))],
    );

    // Stage 3: gradient apply / weight writeback array.
    let mut wmac = f::mac_unit(bits, 2.0 * bits);
    wmac.multiplicity = lanes;
    let mut stage3_children = vec![ModuleNode::leaf("update_lane", wmac)];
    if is_recsys {
        // recommender system keeps two factor vectors in flight
        let mut extra = f::alu_lane(bits);
        extra.multiplicity = lanes;
        stage3_children.push(ModuleNode::leaf("factor_lane", extra));
    }
    let stage3 = ModuleNode::with_children(
        "stage3_apply",
        f::comb_block(3.0, 1.0, bits, 30.0 * lanes, 4.0 * lanes, 2.7),
        stage3_children,
    );

    // Weight/input registers: register-file based (Axiline is std-cell
    // only — no SRAM macros: paper samples util up to 90% for it).
    let regs = ModuleNode::leaf(
        "weight_regfile",
        f::comb_block(2.0, 2.0, bits, 6.0 * dim * bits / 4.0, dim * bits, 2.2),
    );
    let input_regs = ModuleNode::leaf(
        "input_regfile",
        f::comb_block(2.0, 2.0, in_bits, 4.0 * dim * in_bits / 4.0, dim * in_bits, 2.2),
    );

    let top = ModuleNode::with_children(
        "axiline_top",
        f::comb_block(6.0, 4.0, in_bits, 90.0, 40.0, 2.5),
        vec![
            stage1,
            stage2,
            stage3,
            regs,
            input_regs,
            ModuleNode::leaf("sequencer", f::controller(10.0 + cycles, bits)),
            ModuleNode::leaf("io_shim", f::axi_iface(in_bits * 4.0)),
        ],
    );
    ModuleTree { platform: Platform::Axiline, top }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(bench: f64, bits: f64, in_bits: f64, dim: f64, cycles: f64) -> ArchConfig {
        ArchConfig::new(Platform::Axiline, vec![bench, bits, in_bits, dim, cycles])
    }

    #[test]
    fn more_lanes_more_cells() {
        let fast = Platform::Axiline.generate(&cfg(0.0, 16.0, 8.0, 60.0, 2.0)).unwrap();
        let slow = Platform::Axiline.generate(&cfg(0.0, 16.0, 8.0, 60.0, 25.0)).unwrap();
        assert!(fast.aggregates().comb_cells > 2.0 * slow.aggregates().comb_cells);
    }

    #[test]
    fn logistic_has_nonlinearity_overhead() {
        let svm = Platform::Axiline.generate(&cfg(0.0, 8.0, 4.0, 20.0, 5.0)).unwrap();
        let log = Platform::Axiline.generate(&cfg(2.0, 8.0, 4.0, 20.0, 5.0)).unwrap();
        assert!(log.aggregates().comb_cells > svm.aggregates().comb_cells);
    }

    #[test]
    fn no_macros() {
        let t = Platform::Axiline.generate(&cfg(1.0, 16.0, 8.0, 30.0, 10.0)).unwrap();
        assert_eq!(t.aggregates().macro_bits, 0.0);
    }

    #[test]
    fn node_budget() {
        for d in [5.0, 33.0, 60.0] {
            for c in [1.0, 13.0, 25.0] {
                let t = Platform::Axiline.generate(&cfg(3.0, 16.0, 8.0, d, c)).unwrap();
                assert!(t.node_count() <= 32, "{d}/{c}: {}", t.node_count());
            }
        }
    }
}
