//! Logical Hierarchy Graph (paper §6, Algorithm 1).
//!
//! The LHG is the hierarchy tree of the generated design: one node per
//! module instantiation, one undirected edge per parent→submodule
//! relation (so |E| = |V| - 1), node features per Fig. 5c. The paper
//! extracts it from a Genus "generic netlist" via a Pyverilog AST walk;
//! our generators' ModuleTree *is* that AST, and `from_tree` implements
//! Algorithm 1's depth-first AddNodeToGraph procedure verbatim.
//!
//! `to_gcn_inputs` converts the LHG into the padded dense tensors the
//! AOT-compiled GCN consumes: node feature matrix [N, 9] (log-scaled),
//! symmetric normalized adjacency D^-1/2 (A + I) D^-1/2 [N, N], and a
//! validity mask [N].

use anyhow::{ensure, Result};

use super::{ModuleNode, ModuleTree, NodeFeatures};

/// Per-node feature dimension (Fig. 5c features + fold multiplicity) —
/// must match python model.NODE_FEAT.
pub const NODE_FEAT_DIM: usize = 9;

/// Max nodes the AOT GCN accepts — must match python model.NODES.
pub const MAX_NODES: usize = 128;

#[derive(Debug, Clone)]
pub struct Lhg {
    /// Node features in Algorithm-1 DFS order (node 0 = top module).
    pub nodes: Vec<NodeFeatures>,
    /// Node names (diagnostics / t-SNE labelling).
    pub names: Vec<String>,
    /// Undirected edges (parent, child); len == nodes.len() - 1.
    pub edges: Vec<(usize, usize)>,
}

impl Lhg {
    /// Algorithm 1: AddNodeToGraph(top, G, -1, 0) by depth-first search.
    pub fn from_tree(tree: &ModuleTree) -> Lhg {
        let mut g = Lhg { nodes: Vec::new(), names: Vec::new(), edges: Vec::new() };
        fn add_node(n: &ModuleNode, g: &mut Lhg, pid: Option<usize>) {
            let id = g.nodes.len();
            g.nodes.push(n.feats);
            g.names.push(n.name.clone());
            if let Some(p) = pid {
                g.edges.push((p, id));
            }
            for c in &n.children {
                add_node(c, g, Some(id));
            }
        }
        add_node(&tree.top, &mut g, None);
        g
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Tree invariant check: |E| = |V|-1, every non-root has exactly one
    /// parent, parents precede children (DFS order).
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.nodes.is_empty(), "empty LHG");
        ensure!(
            self.edges.len() == self.nodes.len() - 1,
            "LHG must be a tree: |E|={} |V|={}",
            self.edges.len(),
            self.nodes.len()
        );
        let mut indeg = vec![0usize; self.nodes.len()];
        for &(p, c) in &self.edges {
            ensure!(p < c, "parent {p} must precede child {c} (DFS order)");
            ensure!(c < self.nodes.len(), "edge out of range");
            indeg[c] += 1;
        }
        ensure!(indeg[0] == 0, "root has a parent");
        for (i, d) in indeg.iter().enumerate().skip(1) {
            ensure!(*d == 1, "node {i} has {d} parents");
        }
        Ok(())
    }

    /// Dense GCN inputs, padded to `max_nodes`:
    /// (node_feats [max,NODE_FEAT_DIM], adj [max,max], mask [max]).
    /// Counts are log1p-scaled so the GCN sees O(1) magnitudes.
    pub fn to_gcn_inputs(
        &self,
        max_nodes: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        ensure!(
            self.nodes.len() <= max_nodes,
            "LHG has {} nodes > budget {max_nodes}",
            self.nodes.len()
        );
        let n = self.nodes.len();
        let mut feats = vec![0.0f32; max_nodes * NODE_FEAT_DIM];
        for (i, nf) in self.nodes.iter().enumerate() {
            let raw = nf.to_vec();
            for (j, v) in raw.iter().enumerate() {
                // signals/bits/cells/ffs/macros/fanin/multiplicity are all
                // nonneg counts: log1p compresses the dynamic range.
                feats[i * NODE_FEAT_DIM + j] = (v.max(0.0)).ln_1p() as f32;
            }
        }
        // adjacency with self loops
        let mut deg = vec![1.0f64; n];
        for &(p, c) in &self.edges {
            deg[p] += 1.0;
            deg[c] += 1.0;
        }
        let mut adj = vec![0.0f32; max_nodes * max_nodes];
        for i in 0..n {
            adj[i * max_nodes + i] = (1.0 / deg[i]) as f32;
        }
        for &(p, c) in &self.edges {
            let w = (1.0 / (deg[p] * deg[c]).sqrt()) as f32;
            adj[p * max_nodes + c] = w;
            adj[c * max_nodes + p] = w;
        }
        let mut mask = vec![0.0f32; max_nodes];
        for m in mask.iter_mut().take(n) {
            *m = 1.0;
        }
        Ok((feats, adj, mask))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators::{ArchConfig, Platform};

    fn lhg_for(p: Platform, u: f64) -> Lhg {
        let cfg = ArchConfig::new(
            p,
            p.param_space().iter().map(|s| s.kind.from_unit(u)).collect(),
        );
        Lhg::from_tree(&p.generate(&cfg).unwrap())
    }

    #[test]
    fn lhg_is_a_valid_tree_for_all_platforms() {
        for p in Platform::ALL {
            for u in [0.0, 0.3, 0.7, 0.99] {
                let g = lhg_for(p, u);
                g.validate().unwrap();
                assert!(g.len() <= MAX_NODES, "{p}: {}", g.len());
            }
        }
    }

    #[test]
    fn edge_count_is_v_minus_one() {
        let g = lhg_for(Platform::GeneSys, 0.5);
        assert_eq!(g.edges.len(), g.len() - 1);
    }

    #[test]
    fn root_is_top_module() {
        let g = lhg_for(Platform::Vta, 0.5);
        assert_eq!(g.names[0], "vta_top");
    }

    #[test]
    fn gcn_inputs_shapes_and_mask() {
        let g = lhg_for(Platform::Tabla, 0.5);
        let (feats, adj, mask) = g.to_gcn_inputs(MAX_NODES).unwrap();
        assert_eq!(feats.len(), MAX_NODES * NODE_FEAT_DIM);
        assert_eq!(adj.len(), MAX_NODES * MAX_NODES);
        assert_eq!(mask.len(), MAX_NODES);
        let valid: f32 = mask.iter().sum();
        assert_eq!(valid as usize, g.len());
        // padded region must be all-zero
        for i in g.len()..MAX_NODES {
            assert_eq!(mask[i], 0.0);
            for j in 0..MAX_NODES {
                assert_eq!(adj[i * MAX_NODES + j], 0.0);
                assert_eq!(adj[j * MAX_NODES + i], 0.0);
            }
        }
    }

    #[test]
    fn adjacency_is_symmetric_normalized() {
        let g = lhg_for(Platform::Axiline, 0.2);
        let n = g.len();
        let (_, adj, _) = g.to_gcn_inputs(MAX_NODES).unwrap();
        for i in 0..n {
            for j in 0..n {
                let a = adj[i * MAX_NODES + j];
                let b = adj[j * MAX_NODES + i];
                assert!((a - b).abs() < 1e-6);
            }
            assert!(adj[i * MAX_NODES + i] > 0.0, "self loop missing at {i}");
        }
        // every entry of D^-1/2 (A+I) D^-1/2 lies in [0, 1]
        for v in adj.iter() {
            assert!((0.0..=1.0).contains(v), "entry {v} out of range");
        }
    }

    #[test]
    fn different_configs_different_graphs() {
        let a = lhg_for(Platform::Axiline, 0.1);
        let b = lhg_for(Platform::Axiline, 0.9);
        let fa = a.nodes.iter().map(|n| n.comb_cells).sum::<f64>();
        let fb = b.nodes.iter().map(|n| n.comb_cells).sum::<f64>();
        assert_ne!(fa, fb);
    }

    #[test]
    fn rejects_overflow() {
        let g = lhg_for(Platform::GeneSys, 0.5);
        assert!(g.to_gcn_inputs(4).is_err());
    }
}
