//! VTA generator (paper §5.1, Table 1): the TVM hardware backend — a
//! GEMM core (16x16 int8 by default), a vector ALU, fetch/load/compute/
//! store command modules, and weight/input/output SRAM buffers sharing
//! one off-chip bus.

use super::features as f;
use super::{ArchConfig, ModuleNode, ModuleTree, ParamKind, ParamSpec, Platform};

pub fn param_space() -> Vec<ParamSpec> {
    vec![
        // VTA fixes data widths (Table 1: weight/act 8b, acc 32b); the
        // tunables are buffer capacities and off-chip bandwidth.
        ParamSpec { name: "gemm_dim", kind: ParamKind::Choice(vec![8.0, 16.0, 32.0]) },
        ParamSpec { name: "wbuf_kb", kind: ParamKind::Int { lo: 16, hi: 256 } },
        ParamSpec { name: "ibuf_kb", kind: ParamKind::Int { lo: 16, hi: 128 } },
        ParamSpec { name: "obuf_kb", kind: ParamKind::Int { lo: 32, hi: 512 } },
        ParamSpec { name: "offchip_bits", kind: ParamKind::Int { lo: 64, hi: 512 } },
    ]
}

pub const WEIGHT_BITS: f64 = 8.0;
pub const ACC_BITS: f64 = 32.0;

pub fn generate(cfg: &ArchConfig) -> ModuleTree {
    let dim = cfg.get("gemm_dim");

    // GEMM core: dim x dim int8 MACs, folded as row x lane.
    let mut mac = f::mac_unit(WEIGHT_BITS, ACC_BITS);
    mac.multiplicity = dim;
    let mut row = f::comb_block(3.0, 3.0, WEIGHT_BITS, 20.0 * dim, 8.0 * dim, 2.5);
    row.multiplicity = dim;
    let gemm = ModuleNode::with_children(
        "gemm_core",
        f::comb_block(4.0, 2.0, WEIGHT_BITS, 260.0, 120.0, 2.7),
        vec![ModuleNode::with_children(
            "gemm_row",
            row,
            vec![ModuleNode::leaf("mac", mac)],
        )],
    );

    // Tensor ALU: dim lanes of 32-bit ops (used for depthwise/pool/relu).
    let mut lane = f::alu_lane(ACC_BITS);
    lane.multiplicity = dim;
    let alu = ModuleNode::with_children(
        "tensor_alu",
        f::comb_block(4.0, 2.0, ACC_BITS, 130.0, 60.0, 2.8),
        vec![ModuleNode::leaf("alu_lane", lane)],
    );

    let buffers = ModuleNode::with_children(
        "buffer_subsystem",
        f::comb_block(6.0, 6.0, 64.0, 260.0, 110.0, 2.4),
        vec![
            ModuleNode::leaf("wgt_buf", f::sram_macro(64.0, (cfg.get("wbuf_kb") * 8.0 / 64.0).ceil(), dim * WEIGHT_BITS)),
            ModuleNode::leaf("inp_buf", f::sram_macro(64.0, (cfg.get("ibuf_kb") * 8.0 / 64.0).ceil(), dim * WEIGHT_BITS)),
            ModuleNode::leaf("out_buf", f::sram_macro(64.0, (cfg.get("obuf_kb") * 8.0 / 64.0).ceil(), dim * ACC_BITS / 2.0)),
            ModuleNode::leaf("uop_cache", f::sram_macro(32.0, 2.0, 32.0)),
        ],
    );

    let top = ModuleNode::with_children(
        "vta_top",
        f::comb_block(10.0, 8.0, 32.0, 380.0, 160.0, 2.6),
        vec![
            gemm,
            alu,
            buffers,
            ModuleNode::leaf("fetch_module", f::controller(20.0, 32.0)),
            ModuleNode::leaf("load_module", f::controller(28.0, 32.0)),
            ModuleNode::leaf("store_module", f::controller(24.0, 32.0)),
            ModuleNode::leaf("offchip_bus", f::axi_iface(cfg.get("offchip_bits"))),
        ],
    );
    ModuleTree { platform: Platform::Vta, top }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(dim: f64, off: f64) -> ArchConfig {
        ArchConfig::new(Platform::Vta, vec![dim, 128.0, 64.0, 256.0, off])
    }

    #[test]
    fn gemm_scales_with_dim_squared() {
        let a = Platform::Vta.generate(&cfg(8.0, 256.0)).unwrap().aggregates();
        let b = Platform::Vta.generate(&cfg(32.0, 256.0)).unwrap().aggregates();
        assert!(b.comb_cells / a.comb_cells > 5.0);
    }

    #[test]
    fn offchip_width_affects_cells_not_macros() {
        let a = Platform::Vta.generate(&cfg(16.0, 64.0)).unwrap().aggregates();
        let b = Platform::Vta.generate(&cfg(16.0, 512.0)).unwrap().aggregates();
        assert!(b.comb_cells > a.comb_cells);
        assert_eq!(a.macro_bits, b.macro_bits);
    }

    #[test]
    fn node_budget() {
        let t = Platform::Vta.generate(&cfg(32.0, 512.0)).unwrap();
        assert!(t.node_count() <= 24, "{}", t.node_count());
    }
}
