//! GeneSys generator (paper §5.1, Table 1): an M x N systolic array for
//! GEMM/convolution plus an N x 1 SIMD array for vector ops, fed by four
//! SRAM buffers (WBUF/IBUF/OBUF/VMEM) over AXI.
//!
//! Following the paper's data-generation strategy (§7.1), buffer sizes
//! and AXI widths are sampled around array-dimension-proportional
//! baselines to exercise weight-reuse vs. partial-sum-reuse tradeoffs.

use super::features as f;
use super::{ArchConfig, ModuleNode, ModuleTree, ParamKind, ParamSpec, Platform};

pub fn param_space() -> Vec<ParamSpec> {
    vec![
        ParamSpec { name: "array_dim", kind: ParamKind::Choice(vec![8.0, 16.0, 32.0]) },
        ParamSpec { name: "weight_bits", kind: ParamKind::Int { lo: 4, hi: 8 } },
        ParamSpec { name: "act_bits", kind: ParamKind::Int { lo: 4, hi: 8 } },
        ParamSpec { name: "wbuf_kb", kind: ParamKind::Int { lo: 16, hi: 256 } },
        ParamSpec { name: "ibuf_kb", kind: ParamKind::Int { lo: 16, hi: 128 } },
        ParamSpec { name: "obuf_kb", kind: ParamKind::Int { lo: 128, hi: 1024 } },
        ParamSpec { name: "vmem_kb", kind: ParamKind::Int { lo: 128, hi: 1024 } },
        ParamSpec { name: "wbuf_axi_bits", kind: ParamKind::Int { lo: 64, hi: 256 } },
        ParamSpec { name: "ibuf_axi_bits", kind: ParamKind::Int { lo: 128, hi: 256 } },
        ParamSpec { name: "obuf_axi_bits", kind: ParamKind::Int { lo: 128, hi: 256 } },
        ParamSpec { name: "simd_axi_bits", kind: ParamKind::Int { lo: 128, hi: 256 } },
    ]
}

pub const ACC_BITS: f64 = 32.0;

pub fn generate(cfg: &ArchConfig) -> ModuleTree {
    let m = cfg.get("array_dim"); // systolic M == N
    let wbits = cfg.get("weight_bits");
    let abits = cfg.get("act_bits");
    let avg_bits = 0.5 * (wbits + abits);

    // Systolic array: fold one PE row (N PEs) x M rows.
    let mut pe = f::mac_unit(avg_bits, ACC_BITS);
    pe.multiplicity = m; // N PEs per row
    let mut row = f::comb_block(3.0, 3.0, avg_bits, 25.0 * m, 10.0 * m, 2.5);
    row.multiplicity = m; // M rows
    let systolic = ModuleNode::with_children(
        "systolic_array",
        f::comb_block(4.0, 2.0, avg_bits, 200.0, 80.0, 2.6),
        vec![ModuleNode::with_children(
            "pe_row",
            row,
            vec![ModuleNode::leaf("pe", pe)],
        )],
    );

    // SIMD array: N lanes of 32-bit vector ALUs (relu/pool/softmax).
    let mut lane = f::alu_lane(ACC_BITS);
    lane.multiplicity = m;
    let simd = ModuleNode::with_children(
        "simd_array",
        f::comb_block(4.0, 2.0, ACC_BITS, 150.0, 60.0, 2.8),
        vec![
            ModuleNode::leaf("vector_lane", lane),
            ModuleNode::leaf("special_fn", f::comb_block(2.0, 1.0, ACC_BITS, 900.0, 64.0, 3.3)),
        ],
    );

    // Buffers: bank count grows with capacity (64-kbit banks).
    let buffers = ModuleNode::with_children(
        "buffer_subsystem",
        f::comb_block(8.0, 8.0, 64.0, 300.0, 120.0, 2.4),
        vec![
            ModuleNode::leaf("wbuf", f::sram_macro(64.0, (cfg.get("wbuf_kb") * 8.0 / 64.0).ceil(), cfg.get("wbuf_axi_bits"))),
            ModuleNode::leaf("ibuf", f::sram_macro(64.0, (cfg.get("ibuf_kb") * 8.0 / 64.0).ceil(), cfg.get("ibuf_axi_bits"))),
            ModuleNode::leaf("obuf", f::sram_macro(64.0, (cfg.get("obuf_kb") * 8.0 / 64.0).ceil(), cfg.get("obuf_axi_bits"))),
            ModuleNode::leaf("vmem", f::sram_macro(64.0, (cfg.get("vmem_kb") * 8.0 / 64.0).ceil(), cfg.get("simd_axi_bits"))),
        ],
    );

    let dma = ModuleNode::with_children(
        "axi_subsystem",
        f::comb_block(8.0, 8.0, 128.0, 250.0, 100.0, 2.5),
        vec![
            ModuleNode::leaf("wbuf_axi", f::axi_iface(cfg.get("wbuf_axi_bits"))),
            ModuleNode::leaf("ibuf_axi", f::axi_iface(cfg.get("ibuf_axi_bits"))),
            ModuleNode::leaf("obuf_axi", f::axi_iface(cfg.get("obuf_axi_bits"))),
            ModuleNode::leaf("simd_axi", f::axi_iface(cfg.get("simd_axi_bits"))),
        ],
    );

    let top = ModuleNode::with_children(
        "genesys_top",
        f::comb_block(12.0, 10.0, 32.0, 400.0, 180.0, 2.6),
        vec![
            systolic,
            simd,
            buffers,
            dma,
            ModuleNode::leaf("instruction_ctrl", f::controller(48.0, 32.0)),
            ModuleNode::leaf("tile_walker", f::controller(24.0, 16.0)),
            ModuleNode::leaf("noc_fabric", f::interconnect(6.0, 128.0)),
        ],
    );
    ModuleTree { platform: Platform::GeneSys, top }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(array: f64, wkb: f64) -> ArchConfig {
        ArchConfig::new(
            Platform::GeneSys,
            vec![array, 8.0, 8.0, wkb, 64.0, 256.0, 256.0, 128.0, 128.0, 128.0, 128.0],
        )
    }

    #[test]
    fn array_dim_scales_quadratically_via_fold() {
        let small = Platform::GeneSys.generate(&cfg(8.0, 64.0)).unwrap().aggregates();
        let big = Platform::GeneSys.generate(&cfg(32.0, 64.0)).unwrap().aggregates();
        // PEs: row multiplicity m times per-row PE multiplicity m
        let ratio = big.comb_cells / small.comb_cells;
        assert!(ratio > 6.0, "ratio={ratio}");
    }

    #[test]
    fn buffer_capacity_becomes_macro_bits() {
        let a = Platform::GeneSys.generate(&cfg(16.0, 16.0)).unwrap().aggregates();
        let b = Platform::GeneSys.generate(&cfg(16.0, 256.0)).unwrap().aggregates();
        assert!(b.macro_bits > a.macro_bits);
        // wbuf went from 16KB to 256KB = +240KB = +1.97 Mbit
        let delta = b.macro_bits - a.macro_bits;
        assert!((delta - 240.0 * 8.0 * 1024.0).abs() < 70_000.0, "delta={delta}");
    }

    #[test]
    fn node_budget() {
        let t = Platform::GeneSys.generate(&cfg(32.0, 256.0)).unwrap();
        assert!(t.node_count() <= 32, "{}", t.node_count());
    }
}
