//! Node-feature construction helpers shared by the four generators, plus
//! the unified 16-dim feature vector fed to the learned predictors.

use super::{ArchConfig, NodeFeatures, ParamKind};

/// Unified model feature vector length (must match python model.FEAT).
pub const FEAT_DIM: usize = 16;

/// A combinational block: `cells` gates with average fan-in `fanin`,
/// `bits`-wide datapath, `ffs` pipeline registers.
pub fn comb_block(
    in_signals: f64,
    out_signals: f64,
    bits: f64,
    cells: f64,
    ffs: f64,
    fanin: f64,
) -> NodeFeatures {
    NodeFeatures {
        in_signals,
        out_signals,
        avg_in_bits: bits,
        avg_out_bits: bits,
        comb_cells: cells,
        ff_count: ffs,
        macro_count: 0.0,
        avg_comb_inputs: fanin,
        multiplicity: 1.0,
    }
}

/// An SRAM buffer: `banks` macros of `kbits_per_bank` kilobits each,
/// plus a small amount of glue logic. Convention: bits-per-bank ride in
/// `avg_out_bits` (in kilobits) so ModuleTree::macro_bits can recover the
/// total capacity (see generators/mod.rs).
pub fn sram_macro(kbits_per_bank: f64, banks: f64, port_bits: f64) -> NodeFeatures {
    NodeFeatures {
        in_signals: 4.0,
        out_signals: 2.0,
        avg_in_bits: port_bits,
        avg_out_bits: kbits_per_bank,
        comb_cells: 120.0 + 4.0 * port_bits, // address decode + mux glue
        ff_count: 32.0 + port_bits,          // output registers
        macro_count: banks,
        avg_comb_inputs: 2.6,
        multiplicity: 1.0,
    }
}

/// A `bits x bits` multiply-accumulate unit: cells scale quadratically
/// with operand width (array multiplier), depth logarithmically.
pub fn mac_unit(bits: f64, acc_bits: f64) -> NodeFeatures {
    let cells = 9.0 * bits * bits + 4.0 * acc_bits;
    NodeFeatures {
        in_signals: 3.0,
        out_signals: 1.0,
        avg_in_bits: bits,
        avg_out_bits: acc_bits,
        comb_cells: cells,
        ff_count: acc_bits + 2.0 * bits,
        macro_count: 0.0,
        avg_comb_inputs: 3.2,
        multiplicity: 1.0,
    }
}

/// A `bits`-wide ALU lane (add/sub/compare/shift + small LUT ops).
pub fn alu_lane(bits: f64) -> NodeFeatures {
    NodeFeatures {
        in_signals: 3.0,
        out_signals: 1.0,
        avg_in_bits: bits,
        avg_out_bits: bits,
        comb_cells: 38.0 * bits,
        ff_count: 3.0 * bits,
        macro_count: 0.0,
        avg_comb_inputs: 2.9,
        multiplicity: 1.0,
    }
}

/// Control FSM / sequencer of `states` states over `bits`-wide datapaths.
pub fn controller(states: f64, bits: f64) -> NodeFeatures {
    NodeFeatures {
        in_signals: 8.0,
        out_signals: 12.0,
        avg_in_bits: bits / 2.0,
        avg_out_bits: 4.0,
        comb_cells: 60.0 * states,
        ff_count: 12.0 * states,
        macro_count: 0.0,
        avg_comb_inputs: 3.4,
        multiplicity: 1.0,
    }
}

/// Bus / interconnect fabric joining `ports` agents at `bits` width.
pub fn interconnect(ports: f64, bits: f64) -> NodeFeatures {
    NodeFeatures {
        in_signals: ports,
        out_signals: ports,
        avg_in_bits: bits,
        avg_out_bits: bits,
        comb_cells: 22.0 * ports * bits.sqrt() * 4.0,
        ff_count: 2.0 * ports * bits.sqrt(),
        macro_count: 0.0,
        avg_comb_inputs: 2.4,
        multiplicity: 1.0,
    }
}

/// AXI/DMA interface at `bits` data width.
pub fn axi_iface(bits: f64) -> NodeFeatures {
    NodeFeatures {
        in_signals: 9.0,
        out_signals: 9.0,
        avg_in_bits: bits,
        avg_out_bits: bits,
        comb_cells: 30.0 * bits,
        ff_count: 6.0 * bits,
        macro_count: 0.0,
        avg_comb_inputs: 2.7,
        multiplicity: 1.0,
    }
}

/// The unified 16-dim feature vector (paper Eq. 1/2 inputs):
/// [0..12)  architectural parameters, unit-normalized, zero-padded
/// [12]     f_target (GHz)
/// [13]     floorplan utilization
/// [14]     log-scaled total cell count of the generated design
/// [15]     log-scaled total SRAM macro bits
pub fn unified_features(
    cfg: &ArchConfig,
    f_target_ghz: f64,
    util: f64,
    total_cells: f64,
    macro_bits: f64,
) -> [f64; FEAT_DIM] {
    let mut out = [0.0; FEAT_DIM];
    let space = cfg.platform.param_space();
    for (i, (spec, v)) in space.iter().zip(cfg.values.iter()).enumerate().take(12) {
        out[i] = match &spec.kind {
            ParamKind::Cat(_) => spec.kind.to_unit(*v),
            kind => kind.to_unit(*v),
        };
    }
    out[12] = f_target_ghz;
    out[13] = util;
    out[14] = (total_cells.max(1.0)).ln() / 20.0;
    out[15] = (macro_bits + 1.0).ln() / 25.0;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators::Platform;

    #[test]
    fn unified_features_are_bounded() {
        for p in Platform::ALL {
            let space = p.param_space();
            assert!(space.len() <= 12, "{p}: too many params for the feature layout");
            let cfg = ArchConfig::new(
                p,
                space.iter().map(|s| s.kind.from_unit(0.99)).collect(),
            );
            let tree = p.generate(&cfg).unwrap();
            let agg = tree.aggregates();
            let f = unified_features(&cfg, 1.5, 0.6, agg.comb_cells, agg.macro_bits);
            for (i, v) in f.iter().enumerate() {
                assert!(v.is_finite() && *v >= 0.0 && *v <= 2.5, "{p} feat[{i}]={v}");
            }
        }
    }

    #[test]
    fn feature_vector_distinguishes_backend_knobs() {
        let p = Platform::Axiline;
        let cfg = ArchConfig::new(
            p,
            p.param_space().iter().map(|s| s.kind.from_unit(0.5)).collect(),
        );
        let a = unified_features(&cfg, 0.5, 0.4, 1e5, 0.0);
        let b = unified_features(&cfg, 1.5, 0.8, 1e5, 0.0);
        assert_ne!(a[12], b[12]);
        assert_ne!(a[13], b[13]);
        assert_eq!(a[..12], b[..12]);
    }

    #[test]
    fn mac_scales_quadratically() {
        let small = mac_unit(4.0, 32.0);
        let big = mac_unit(8.0, 32.0);
        // array multiplier dominates: ratio approaches 4x as the
        // accumulator term amortizes
        let ratio = big.comb_cells / small.comb_cells;
        assert!(ratio > 2.0 && ratio < 4.5, "ratio={ratio}");
    }

    #[test]
    fn sram_macro_encodes_capacity() {
        let n = sram_macro(64.0, 4.0, 128.0);
        assert_eq!(n.macro_count, 4.0);
        assert_eq!(n.avg_out_bits, 64.0); // kilobits per bank
    }
}
