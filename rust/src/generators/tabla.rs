//! TABLA generator (paper §5.1, Table 1): a template-based accelerator
//! for non-DNN statistical ML training — PUs (processing units), each
//! holding a ring of PEs (processing engines) with ALUs and register
//! files, a global bus, and on-chip model/data memories (SRAM macros).

use super::features as f;
use super::{ArchConfig, ModuleNode, ModuleTree, ParamKind, ParamSpec, Platform};

pub const BENCHMARKS: [&str; 2] = ["recsys", "backprop"];

pub fn param_space() -> Vec<ParamSpec> {
    vec![
        ParamSpec { name: "pu", kind: ParamKind::Choice(vec![4.0, 8.0]) },
        ParamSpec { name: "pe", kind: ParamKind::Choice(vec![8.0, 16.0]) },
        ParamSpec { name: "bitwidth", kind: ParamKind::Choice(vec![8.0, 16.0]) },
        ParamSpec { name: "input_bitwidth", kind: ParamKind::Choice(vec![16.0, 32.0]) },
        ParamSpec { name: "benchmark", kind: ParamKind::Cat(BENCHMARKS.to_vec()) },
    ]
}

pub fn generate(cfg: &ArchConfig) -> ModuleTree {
    let pu = cfg.get("pu");
    let pe = cfg.get("pe");
    let bits = cfg.get("bitwidth");
    let in_bits = cfg.get("input_bitwidth");
    let is_backprop = cfg.benchmark() == Some("backprop");

    // One PE: ALU + small multiplier + register file + neighbour links.
    let mut pe_node = f::comb_block(4.0, 4.0, bits, 0.0, 0.0, 0.0);
    {
        let mac = f::mac_unit(bits, 2.0 * bits);
        let alu = f::alu_lane(bits);
        pe_node.comb_cells = mac.comb_cells + alu.comb_cells + 10.0 * bits /* regfile mux */;
        pe_node.ff_count = mac.ff_count + alu.ff_count + 16.0 * bits /* 16-entry RF */;
        pe_node.avg_comb_inputs = 3.0;
        pe_node.multiplicity = pe;
    }

    // One PU: PE ring + intra-PU bus + PU controller (folded x pu).
    let mut pu_shell = f::comb_block(6.0, 6.0, bits, 180.0 + 14.0 * pe, 60.0 + 6.0 * pe, 2.6);
    pu_shell.multiplicity = pu;
    let pu_node = ModuleNode::with_children(
        "pu",
        pu_shell,
        vec![
            ModuleNode::leaf("pe", pe_node),
            ModuleNode::leaf("pe_ring_bus", f::interconnect(pe, bits)),
            ModuleNode::leaf("pu_ctrl", f::controller(16.0, bits)),
        ],
    );

    // Model/data buffers: backprop needs a bigger model memory (layers).
    let model_kb = if is_backprop { 128.0 } else { 64.0 } * (bits / 8.0);
    let data_kb = 32.0 * (in_bits / 16.0);
    let mem = ModuleNode::with_children(
        "memory_subsystem",
        f::comb_block(6.0, 6.0, in_bits, 250.0, 90.0, 2.4),
        vec![
            ModuleNode::leaf("model_mem", f::sram_macro(64.0, (model_kb * 8.0 / 64.0).ceil(), bits * pe)),
            ModuleNode::leaf("data_mem", f::sram_macro(64.0, (data_kb * 8.0 / 64.0).ceil(), in_bits * 4.0)),
        ],
    );

    let top = ModuleNode::with_children(
        "tabla_top",
        f::comb_block(10.0, 8.0, in_bits, 320.0, 140.0, 2.6),
        vec![
            pu_node,
            mem,
            ModuleNode::leaf("global_bus", f::interconnect(pu + 2.0, bits * 2.0)),
            ModuleNode::leaf("scheduler", f::controller(40.0, 16.0)),
            ModuleNode::leaf("axi_shim", f::axi_iface(in_bits * 2.0)),
        ],
    );
    ModuleTree { platform: Platform::Tabla, top }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(pu: f64, pe: f64, bits: f64, bench: f64) -> ArchConfig {
        ArchConfig::new(Platform::Tabla, vec![pu, pe, bits, 16.0, bench])
    }

    #[test]
    fn pe_count_folds_multiply() {
        let small = Platform::Tabla.generate(&cfg(4.0, 8.0, 8.0, 0.0)).unwrap().aggregates();
        let big = Platform::Tabla.generate(&cfg(8.0, 16.0, 8.0, 0.0)).unwrap().aggregates();
        // 4x the PEs (32 -> 128)
        let ratio = big.comb_cells / small.comb_cells;
        assert!(ratio > 2.5 && ratio < 5.0, "ratio={ratio}");
    }

    #[test]
    fn backprop_needs_more_model_memory() {
        let rec = Platform::Tabla.generate(&cfg(4.0, 8.0, 16.0, 0.0)).unwrap().aggregates();
        let bp = Platform::Tabla.generate(&cfg(4.0, 8.0, 16.0, 1.0)).unwrap().aggregates();
        assert!(bp.macro_bits > rec.macro_bits);
    }

    #[test]
    fn node_budget() {
        let t = Platform::Tabla.generate(&cfg(8.0, 16.0, 16.0, 1.0)).unwrap();
        assert!(t.node_count() <= 16, "{}", t.node_count());
    }
}
