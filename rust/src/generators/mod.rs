//! Parameterizable ML accelerator generators (paper §5.1, Table 1).
//!
//! The paper drives four RTL generators — TABLA, GeneSys, VTA, Axiline —
//! through commercial synthesis. We reproduce their *structural* output:
//! each generator maps an architectural configuration one-to-one to a
//! hierarchical module tree whose per-module features are exactly the
//! Fig. 5c node features (I/O signal counts, average bit widths,
//! combinational cell count, flip-flop count, macro count, average
//! combinational fan-in) plus a fold multiplicity. The tree doubles as
//! the AST from which Algorithm 1 extracts the logical hierarchy graph
//! (`lhg.rs`), and its aggregates feed the backend SP&R oracle.

pub mod axiline;
pub mod features;
pub mod genesys;
pub mod lhg;
pub mod tabla;
pub mod vta;

use std::fmt;

use anyhow::{bail, Result};

pub use features::{unified_features, FEAT_DIM};
pub use lhg::{Lhg, NODE_FEAT_DIM};

/// The four demonstration platforms (paper §5.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Platform {
    Tabla,
    GeneSys,
    Vta,
    Axiline,
}

impl Platform {
    pub const ALL: [Platform; 4] = [
        Platform::Tabla,
        Platform::GeneSys,
        Platform::Vta,
        Platform::Axiline,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Platform::Tabla => "tabla",
            Platform::GeneSys => "genesys",
            Platform::Vta => "vta",
            Platform::Axiline => "axiline",
        }
    }

    pub fn from_name(s: &str) -> Result<Platform> {
        match s.to_ascii_lowercase().as_str() {
            "tabla" => Ok(Platform::Tabla),
            "genesys" => Ok(Platform::GeneSys),
            "vta" => Ok(Platform::Vta),
            "axiline" => Ok(Platform::Axiline),
            other => bail!("unknown platform {other:?}"),
        }
    }

    /// Architectural parameter space (Table 1).
    pub fn param_space(&self) -> Vec<ParamSpec> {
        match self {
            Platform::Tabla => tabla::param_space(),
            Platform::GeneSys => genesys::param_space(),
            Platform::Vta => vta::param_space(),
            Platform::Axiline => axiline::param_space(),
        }
    }

    /// Generate the module tree for a configuration (the "RTL netlist").
    pub fn generate(&self, cfg: &ArchConfig) -> Result<ModuleTree> {
        cfg.validate()?;
        Ok(match self {
            Platform::Tabla => tabla::generate(cfg),
            Platform::GeneSys => genesys::generate(cfg),
            Platform::Vta => vta::generate(cfg),
            Platform::Axiline => axiline::generate(cfg),
        })
    }

    /// Whether the platform's designs are macro-heavy (large SRAM buffers)
    /// — macro-heavy designs get the lower utilization sampling window
    /// (paper Fig. 6) and the lower congestion cliff.
    pub fn macro_heavy(&self) -> bool {
        !matches!(self, Platform::Axiline)
    }
}

impl fmt::Display for Platform {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One tunable architectural parameter.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: &'static str,
    pub kind: ParamKind,
}

#[derive(Debug, Clone, PartialEq)]
pub enum ParamKind {
    /// Integer in [lo, hi].
    Int { lo: i64, hi: i64 },
    /// Continuous in [lo, hi].
    Float { lo: f64, hi: f64 },
    /// One of an explicit numeric set (e.g. PU in {4, 8}).
    Choice(Vec<f64>),
    /// One of a set of named benchmarks/algorithms.
    Cat(Vec<&'static str>),
}

impl ParamKind {
    /// Map a unit-interval sample u in [0,1) to a legal value (used by all
    /// samplers so LHS/Sobol/Halton share one quantization rule). The
    /// discrete arms index through `sampling::stratum`, which clamps the
    /// bin to n-1, so a coordinate of exactly 1.0 is legal (closed-
    /// interval inputs from boundary knobs) rather than out of bounds.
    pub fn from_unit(&self, u: f64) -> f64 {
        let u = u.clamp(0.0, 1.0);
        match self {
            ParamKind::Int { lo, hi } => {
                let n = (hi - lo + 1).max(1) as usize;
                lo.wrapping_add(crate::sampling::stratum(u, n) as i64) as f64
            }
            ParamKind::Float { lo, hi } => lo + u * (hi - lo),
            ParamKind::Choice(vals) => vals[crate::sampling::stratum(u, vals.len())],
            ParamKind::Cat(names) => crate::sampling::stratum(u, names.len()) as f64,
        }
    }

    /// Normalize a legal value back to [0,1] (feature encoding).
    pub fn to_unit(&self, v: f64) -> f64 {
        match self {
            ParamKind::Int { lo, hi } => {
                if hi == lo {
                    0.5
                } else {
                    (v - *lo as f64) / (*hi - *lo) as f64
                }
            }
            ParamKind::Float { lo, hi } => {
                if hi == lo {
                    0.5
                } else {
                    (v - lo) / (hi - lo)
                }
            }
            ParamKind::Choice(vals) => {
                let pos = vals.iter().position(|x| (x - v).abs() < 1e-9).unwrap_or(0);
                if vals.len() <= 1 {
                    0.5
                } else {
                    pos as f64 / (vals.len() - 1) as f64
                }
            }
            ParamKind::Cat(names) => {
                if names.len() <= 1 {
                    0.5
                } else {
                    v / (names.len() - 1) as f64
                }
            }
        }
    }

    pub fn is_discrete(&self) -> bool {
        !matches!(self, ParamKind::Float { .. })
    }
}

/// A point in a platform's architectural space. `values` aligns with
/// `platform.param_space()` order; categorical parameters store the
/// category index.
#[derive(Debug, Clone, PartialEq)]
pub struct ArchConfig {
    pub platform: Platform,
    pub values: Vec<f64>,
}

impl ArchConfig {
    pub fn new(platform: Platform, values: Vec<f64>) -> ArchConfig {
        ArchConfig { platform, values }
    }

    pub fn validate(&self) -> Result<()> {
        let space = self.platform.param_space();
        if self.values.len() != space.len() {
            bail!(
                "{}: config has {} values, space has {} params",
                self.platform,
                self.values.len(),
                space.len()
            );
        }
        Ok(())
    }

    /// Look up a parameter value by Table-1 name.
    pub fn get(&self, name: &str) -> f64 {
        let space = self.platform.param_space();
        let idx = space
            .iter()
            .position(|p| p.name == name)
            .unwrap_or_else(|| panic!("{}: no parameter named {name}", self.platform));
        self.values[idx]
    }

    /// Benchmark/workload name for platforms with a `benchmark` parameter.
    pub fn benchmark(&self) -> Option<&'static str> {
        let space = self.platform.param_space();
        let idx = space.iter().position(|p| p.name == "benchmark")?;
        match &space[idx].kind {
            ParamKind::Cat(names) => names.get(self.values[idx] as usize).copied(),
            _ => None,
        }
    }

    /// Stable identity hash (used for noise seeding and graph caching).
    pub fn id_hash(&self) -> u64 {
        let mut bytes = Vec::with_capacity(8 + self.values.len() * 8);
        bytes.extend_from_slice(self.platform.name().as_bytes());
        for v in &self.values {
            bytes.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        crate::util::rng::hash_bytes(&bytes)
    }
}

/// Fig. 5c node features (+ fold multiplicity), one per module.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct NodeFeatures {
    pub in_signals: f64,
    pub out_signals: f64,
    pub avg_in_bits: f64,
    pub avg_out_bits: f64,
    pub comb_cells: f64,
    pub ff_count: f64,
    pub macro_count: f64,
    pub avg_comb_inputs: f64,
    /// Number of identical sibling instances folded into this node
    /// (keeps LHGs under the AOT node budget; aggregates multiply by it).
    pub multiplicity: f64,
}

impl NodeFeatures {
    pub fn to_vec(&self) -> [f64; lhg::NODE_FEAT_DIM] {
        [
            self.in_signals,
            self.out_signals,
            self.avg_in_bits,
            self.avg_out_bits,
            self.comb_cells,
            self.ff_count,
            self.macro_count,
            self.avg_comb_inputs,
            self.multiplicity,
        ]
    }
}

/// One module instantiation in the generated design.
#[derive(Debug, Clone)]
pub struct ModuleNode {
    pub name: String,
    pub feats: NodeFeatures,
    pub children: Vec<ModuleNode>,
}

impl ModuleNode {
    pub fn leaf(name: &str, feats: NodeFeatures) -> ModuleNode {
        ModuleNode { name: name.to_string(), feats, children: Vec::new() }
    }

    pub fn with_children(name: &str, feats: NodeFeatures, children: Vec<ModuleNode>) -> ModuleNode {
        ModuleNode { name: name.to_string(), feats, children }
    }

    pub fn count(&self) -> usize {
        1 + self.children.iter().map(|c| c.count()).sum::<usize>()
    }
}

/// The generated design: module hierarchy + workload hint.
#[derive(Debug, Clone)]
pub struct ModuleTree {
    pub platform: Platform,
    pub top: ModuleNode,
}

/// Whole-design aggregates consumed by the backend SP&R oracle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DesignAggregates {
    /// Total combinational cell count (fold multiplicities applied).
    pub comb_cells: f64,
    /// Total flip-flop count.
    pub ff_count: f64,
    /// Total SRAM macro bits.
    pub macro_bits: f64,
    /// Number of SRAM macro instances.
    pub macro_count: f64,
    /// Total SRAM port width (bits accessible per cycle across buffers).
    pub macro_port_bits: f64,
    /// Logic depth estimate of the critical path (gate stages).
    pub logic_depth: f64,
    /// Average combinational fan-in (cell complexity proxy).
    pub avg_fanin: f64,
}

impl ModuleTree {
    pub fn node_count(&self) -> usize {
        self.top.count()
    }

    /// Roll the hierarchy up into backend-oracle aggregates. Multiplicity
    /// folds expand here and **compose down the tree**: a node with
    /// multiplicity m inside a parent of multiplicity p contributes
    /// p*m x its cell/FF counts (e.g. GeneSys' PE inside a folded PE row
    /// expands to m^2 PEs).
    pub fn aggregates(&self) -> DesignAggregates {
        fn walk(n: &ModuleNode, parent_m: f64, acc: &mut DesignAggregates, fanin_w: &mut f64) {
            let m = parent_m * n.feats.multiplicity.max(1.0);
            acc.comb_cells += n.feats.comb_cells * m;
            acc.ff_count += n.feats.ff_count * m;
            acc.macro_count += n.feats.macro_count * m;
            if n.feats.macro_count > 0.0 {
                // sram_macro stores its port width in avg_in_bits
                acc.macro_port_bits += n.feats.avg_in_bits * m;
            }
            acc.avg_fanin += n.feats.avg_comb_inputs * n.feats.comb_cells * m;
            *fanin_w += n.feats.comb_cells * m;
            for c in &n.children {
                walk(c, m, acc, fanin_w);
            }
        }
        let mut acc = DesignAggregates {
            comb_cells: 0.0,
            ff_count: 0.0,
            macro_bits: 0.0,
            macro_count: 0.0,
            macro_port_bits: 0.0,
            logic_depth: self.logic_depth(),
            avg_fanin: 0.0,
        };
        let mut fanin_w = 0.0;
        walk(&self.top, 1.0, &mut acc, &mut fanin_w);
        if fanin_w > 0.0 {
            acc.avg_fanin /= fanin_w;
        }
        acc.macro_bits = self.macro_bits();
        acc
    }

    /// Critical-path logic depth (gate stages) — platform- and
    /// bitwidth-dependent (multiplier arrays dominate).
    pub fn logic_depth(&self) -> f64 {
        fn max_depth(n: &ModuleNode) -> f64 {
            // stage count grows with cell-cloud size (carry/multiplier
            // arrays) and average fan-in; ~30-45 stages for MAC-class
            // blocks, which puts GF12 f_max in the 1.5-2.5 GHz band the
            // paper's designs occupy.
            let own = 6.0 + n.feats.avg_comb_inputs * (n.feats.comb_cells.max(2.0)).log2() * 0.9;
            n.children.iter().map(max_depth).fold(own, f64::max)
        }
        max_depth(&self.top)
    }

    fn macro_bits(&self) -> f64 {
        // Convention (features.rs::sram_macro): a macro node stores its
        // kilobits-per-bank in avg_out_bits and its bank count in
        // macro_count, so total bits = macro_count * avg_out_bits * 1024.
        fn walk(n: &ModuleNode, parent_m: f64) -> f64 {
            let m = parent_m * n.feats.multiplicity.max(1.0);
            let mut bits = if n.feats.macro_count > 0.0 {
                n.feats.macro_count * n.feats.avg_out_bits * 1024.0 * m
            } else {
                0.0
            };
            for c in &n.children {
                bits += walk(c, m);
            }
            bits
        }
        walk(&self.top, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn default_config(p: Platform) -> ArchConfig {
        let values: Vec<f64> = p
            .param_space()
            .iter()
            .map(|s| s.kind.from_unit(0.5))
            .collect();
        ArchConfig::new(p, values)
    }

    #[test]
    fn from_unit_accepts_the_closed_upper_boundary() {
        // ISSUE 3 satellite: the discrete arms used to index with
        // (u * n) as usize, which is out of bounds at u == 1.0
        for p in Platform::ALL {
            for spec in p.param_space() {
                let v = spec.kind.from_unit(1.0);
                assert!(v.is_finite(), "{p}/{}: {v}", spec.name);
                if !matches!(spec.kind, ParamKind::Float { .. }) {
                    // discrete kinds: 1.0 lands in the last bin
                    assert_eq!(
                        v,
                        spec.kind.from_unit(0.999_999_999),
                        "{p}/{}: 1.0 must land in the last bin",
                        spec.name
                    );
                }
            }
        }
    }

    #[test]
    fn every_platform_generates() {
        for p in Platform::ALL {
            let cfg = default_config(p);
            let tree = p.generate(&cfg).unwrap();
            assert!(tree.node_count() >= 5, "{p}: too few modules");
            assert!(tree.node_count() <= 128, "{p}: exceeds LHG budget");
            let agg = tree.aggregates();
            assert!(agg.comb_cells > 0.0);
            assert!(agg.ff_count > 0.0);
            assert!(agg.logic_depth > 1.0);
        }
    }

    #[test]
    fn config_to_design_is_deterministic() {
        let cfg = default_config(Platform::GeneSys);
        let a = Platform::GeneSys.generate(&cfg).unwrap().aggregates();
        let b = Platform::GeneSys.generate(&cfg).unwrap().aggregates();
        assert_eq!(a, b);
    }

    #[test]
    fn bigger_configs_make_bigger_designs() {
        let p = Platform::GeneSys;
        let lo: Vec<f64> = p.param_space().iter().map(|s| s.kind.from_unit(0.05)).collect();
        let hi: Vec<f64> = p.param_space().iter().map(|s| s.kind.from_unit(0.95)).collect();
        let small = p.generate(&ArchConfig::new(p, lo)).unwrap().aggregates();
        let large = p.generate(&ArchConfig::new(p, hi)).unwrap().aggregates();
        assert!(large.comb_cells > small.comb_cells);
        assert!(large.macro_bits > small.macro_bits);
    }

    #[test]
    fn macro_heavy_platforms_have_macros() {
        for p in Platform::ALL {
            let agg = p.generate(&default_config(p)).unwrap().aggregates();
            if p.macro_heavy() {
                assert!(agg.macro_bits > 0.0, "{p}");
            }
        }
    }

    #[test]
    fn unit_mapping_roundtrip() {
        let kinds = [
            ParamKind::Int { lo: 4, hi: 60 },
            ParamKind::Float { lo: 0.2, hi: 0.9 },
            ParamKind::Choice(vec![4.0, 8.0, 16.0]),
            ParamKind::Cat(vec!["a", "b", "c"]),
        ];
        for kind in &kinds {
            for i in 0..50 {
                let u = i as f64 / 50.0;
                let v = kind.from_unit(u);
                let un = kind.to_unit(v);
                assert!((0.0..=1.0).contains(&un), "{kind:?} u={u} v={v} un={un}");
                // re-quantizing a legal value must be idempotent
                let v2 = kind.from_unit(un.min(1.0 - 1e-9));
                if let ParamKind::Float { .. } = kind {
                    assert!((v - v2).abs() < 1e-6);
                }
            }
        }
    }

    #[test]
    fn id_hash_distinguishes_configs() {
        let a = default_config(Platform::Vta);
        let mut b = a.clone();
        b.values[0] += 1.0;
        assert_ne!(a.id_hash(), b.id_hash());
        assert_eq!(a.id_hash(), default_config(Platform::Vta).id_hash());
    }

    #[test]
    fn benchmark_lookup() {
        let p = Platform::Axiline;
        let mut cfg = default_config(p);
        let space = p.param_space();
        let bidx = space.iter().position(|s| s.name == "benchmark").unwrap();
        cfg.values[bidx] = 0.0;
        assert!(cfg.benchmark().is_some());
    }
}
