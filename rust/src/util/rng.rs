//! Deterministic PRNG (xoshiro256**) + splitmix64 hashing.
//!
//! Everything stochastic in the framework — sampling, model training
//! splits, the backend oracle's "tool noise", MOTPE candidate draws —
//! flows through this module so that experiments are reproducible from a
//! single seed.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

/// splitmix64 step — also used standalone to hash configuration tuples
/// into deterministic per-design noise (backend::noise).
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Hash an arbitrary byte string to u64 (FNV-1a then splitmix finalize).
pub fn hash_bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    let mut s = h;
    splitmix64(&mut s)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (stable under reordering of calls).
    pub fn fork(&self, stream: u64) -> Rng {
        let mut sm = self.s[0] ^ stream.wrapping_mul(0x9e3779b97f4a7c15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (small-n) use cases.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from 0..n (partial Fisher–Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forks_are_independent_streams() {
        let root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    /// Pearson correlation of two equal-length samples.
    fn pearson(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len() as f64;
        let ma = a.iter().sum::<f64>() / n;
        let mb = b.iter().sum::<f64>() / n;
        let mut cov = 0.0;
        let mut va = 0.0;
        let mut vb = 0.0;
        for (x, y) in a.iter().zip(b) {
            cov += (x - ma) * (y - mb);
            va += (x - ma) * (x - ma);
            vb += (y - mb) * (y - mb);
        }
        cov / (va.sqrt() * vb.sqrt()).max(1e-300)
    }

    #[test]
    fn adjacent_fork_streams_neither_collide_nor_correlate() {
        // ISSUE 2 satellite: per-trial oracle streams and cache keys
        // both derive from `fork`, so adjacent trial indices must give
        // statistically independent streams, not shifted copies.
        const STREAMS: usize = 16;
        const DRAWS: usize = 256;
        let root = Rng::new(2023);
        let streams: Vec<Vec<u64>> = (0..STREAMS as u64)
            .map(|t| {
                let mut r = root.fork(t);
                (0..DRAWS).map(|_| r.next_u64()).collect()
            })
            .collect();

        // overlap check: no value appears twice anywhere across the
        // fleet of streams (4096 draws from a 2^64 space: a collision
        // would mean two trials share flow noise / cache-key material)
        let mut seen = std::collections::BTreeSet::new();
        for (t, s) in streams.iter().enumerate() {
            for &v in s {
                assert!(seen.insert(v), "stream {t} repeats value {v:#x}");
            }
        }

        // adjacent-stream correlation on the unit-interval projection
        for t in 0..STREAMS - 1 {
            let to_unit = |s: &[u64]| -> Vec<f64> {
                s.iter().map(|&v| (v >> 11) as f64 * (1.0 / (1u64 << 53) as f64)).collect()
            };
            let r = pearson(&to_unit(&streams[t]), &to_unit(&streams[t + 1]));
            assert!(
                r.abs() < 0.3, // ~4.8 sigma for n=256: fails only on real structure
                "streams {t} and {} correlate: r={r}",
                t + 1
            );
        }

        // chi-square uniformity of each stream's low nibble (16 bins,
        // df=15; 60 is far past the p=0.001 critical value 37.7, so
        // only gross non-uniformity — e.g. a stuck counter — trips it)
        for (t, s) in streams.iter().enumerate() {
            let mut bins = [0usize; 16];
            for &v in s {
                bins[(v & 15) as usize] += 1;
            }
            let expected = DRAWS as f64 / 16.0;
            let chi2: f64 = bins
                .iter()
                .map(|&c| {
                    let d = c as f64 - expected;
                    d * d / expected
                })
                .sum();
            assert!(chi2 < 60.0, "stream {t} low-nibble chi2={chi2}");
        }
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn choose_k_distinct_in_range() {
        let mut r = Rng::new(9);
        for _ in 0..50 {
            let v = r.choose_k(10, 5);
            let mut s = v.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 5);
            assert!(v.iter().all(|&x| x < 10));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..20).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn hash_bytes_stable_and_spread() {
        assert_eq!(hash_bytes(b"abc"), hash_bytes(b"abc"));
        assert_ne!(hash_bytes(b"abc"), hash_bytes(b"abd"));
    }
}
