//! Guarded throughput formatting for CLI status lines (ISSUE 9
//! satellite). Every "N rows/s" print in the binary goes through
//! [`per_sec`], so an instant run or a zero-row run can never emit
//! `NaN` or `inf` into a line a script might parse.

/// `count / dt` with the denominator clamped away from zero. `--rows 0`
/// on a fast machine yields `0` (not `NaN`), and a sub-nanosecond run
/// yields a huge-but-finite rate (not `inf`).
pub fn per_sec(count: usize, dt_secs: f64) -> f64 {
    count as f64 / dt_secs.max(1e-9)
}

#[cfg(test)]
mod tests {
    use super::per_sec;

    #[test]
    fn guarded_rate_is_always_finite() {
        // the two demo-bug inputs: zero rows in zero time, and rows in
        // zero time (the unguarded form printed NaN / inf)
        assert_eq!(per_sec(0, 0.0), 0.0);
        assert!(per_sec(100, 0.0).is_finite());
        assert!(per_sec(100, 0.0) > 0.0);
        // and the ordinary case is an ordinary division
        assert_eq!(per_sec(500, 2.0), 250.0);
        // formatted the way the status lines print it, no NaN/inf text
        for (n, dt) in [(0usize, 0.0f64), (7, 0.0), (0, 1.5), (123, 0.25)] {
            let line = format!("{:.0} rows/s", per_sec(n, dt));
            assert!(!line.contains("NaN") && !line.contains("inf"), "bad line: {line}");
        }
    }
}
