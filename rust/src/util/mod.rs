//! Self-contained utility substrates (the offline registry only carries
//! the `xla` closure, so JSON / CLI / RNG / thread-pool / property-testing
//! helpers are implemented here rather than pulled from crates.io).

pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rate;
pub mod rng;
pub mod tensor;
