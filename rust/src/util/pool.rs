//! Scoped parallel map over a fixed worker count (rayon/tokio are
//! unavailable offline; dataset generation and benchmark sweeps use this).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Parallel map: applies `f` to 0..n across `workers` threads, preserving
/// index order in the output. `f` must be Sync; results are collected
/// into a Vec<T>.
pub fn par_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<T>>> =
        Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i);
                results.lock().unwrap()[i] = Some(r);
            });
        }
    });
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|x| x.expect("worker skipped an index"))
        .collect()
}

/// Default worker count: available parallelism minus one, at least 1.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = par_map(100, 4, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn handles_empty_and_single() {
        assert!(par_map(0, 4, |i| i).is_empty());
        assert_eq!(par_map(1, 4, |i| i + 1), vec![1]);
    }

    #[test]
    fn workers_more_than_items() {
        assert_eq!(par_map(3, 64, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn actually_runs_concurrently_when_asked() {
        use std::sync::atomic::AtomicUsize;
        static PEAK: AtomicUsize = AtomicUsize::new(0);
        static CUR: AtomicUsize = AtomicUsize::new(0);
        par_map(8, 4, |i| {
            let c = CUR.fetch_add(1, Ordering::SeqCst) + 1;
            PEAK.fetch_max(c, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(20));
            CUR.fetch_sub(1, Ordering::SeqCst);
            i
        });
        assert!(PEAK.load(Ordering::SeqCst) >= 2);
    }
}
