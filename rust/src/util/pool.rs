//! Scoped parallel map over a fixed worker count (rayon/tokio are
//! unavailable offline; dataset generation and benchmark sweeps use this).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Extract a human-readable message from a panic payload (`panic!`
/// carries `&str` or `String`; anything else gets a placeholder).
/// Shared with `coordinator::coalesce`, which propagates a
/// single-flight leader's panic to its waiters.
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Parallel map: applies `f` to 0..n across `workers` threads, preserving
/// index order in the output. `f` must be Sync; results are collected
/// into a Vec<T>.
///
/// A panic inside `f` is re-raised on the calling thread with the
/// worker's payload message and failing index attached (a bare
/// scope-join panic would say only "a scoped thread panicked", which
/// makes a poisoned oracle run undiagnosable from CI logs). The first
/// panic wins; remaining workers stop picking up new indices.
pub fn par_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let poisoned = AtomicBool::new(false);
    let first_panic: Mutex<Option<(usize, String)>> = Mutex::new(None);
    let results: Mutex<Vec<Option<T>>> =
        Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                if poisoned.load(Ordering::Relaxed) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                match catch_unwind(AssertUnwindSafe(|| f(i))) {
                    Ok(r) => results.lock().unwrap()[i] = Some(r),
                    Err(payload) => {
                        let mut slot = first_panic.lock().unwrap();
                        if slot.is_none() {
                            *slot = Some((i, panic_message(payload.as_ref())));
                        }
                        poisoned.store(true, Ordering::Relaxed);
                        break;
                    }
                }
            });
        }
    });
    if let Some((i, msg)) = first_panic.into_inner().unwrap() {
        panic!("par_map worker panicked at index {i}: {msg}");
    }
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|x| x.expect("worker skipped an index"))
        .collect()
}

/// Default worker count: available parallelism minus one, at least 1.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = par_map(100, 4, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn handles_empty_and_single() {
        assert!(par_map(0, 4, |i| i).is_empty());
        assert_eq!(par_map(1, 4, |i| i + 1), vec![1]);
    }

    #[test]
    fn workers_more_than_items() {
        assert_eq!(par_map(3, 64, |i| i), vec![0, 1, 2]);
    }

    /// The panic tests swap the global panic hook; serialize them so
    /// concurrent test threads can't interleave take/set pairs.
    static HOOK_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn worker_panic_propagates_payload_and_index() {
        let _guard = HOOK_LOCK.lock().unwrap();
        // silence the default hook while the expected panic fires
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let caught = std::panic::catch_unwind(|| {
            par_map(8, 4, |i| {
                if i == 5 {
                    panic!("oracle poisoned at trial {i}");
                }
                i
            })
        });
        std::panic::set_hook(prev);
        let payload = caught.expect_err("par_map must propagate worker panics");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .expect("re-raised panic carries a String message");
        assert!(msg.contains("index 5"), "missing index: {msg}");
        assert!(msg.contains("oracle poisoned at trial 5"), "missing payload: {msg}");
    }

    #[test]
    fn serial_path_panics_transparently() {
        let _guard = HOOK_LOCK.lock().unwrap();
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let caught = std::panic::catch_unwind(|| {
            par_map(3, 1, |i| {
                if i == 2 {
                    panic!("serial boom");
                }
                i
            })
        });
        std::panic::set_hook(prev);
        let payload = caught.expect_err("serial par_map must panic");
        let msg = panic_message(payload.as_ref());
        assert!(msg.contains("serial boom"), "{msg}");
    }

    #[test]
    fn actually_runs_concurrently_when_asked() {
        use std::sync::atomic::AtomicUsize;
        static PEAK: AtomicUsize = AtomicUsize::new(0);
        static CUR: AtomicUsize = AtomicUsize::new(0);
        par_map(8, 4, |i| {
            let c = CUR.fetch_add(1, Ordering::SeqCst) + 1;
            PEAK.fetch_max(c, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(20));
            CUR.fetch_sub(1, Ordering::SeqCst);
            i
        });
        assert!(PEAK.load(Ordering::SeqCst) >= 2);
    }
}
