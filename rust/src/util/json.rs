//! Minimal JSON parser/serializer (serde is unavailable in the offline
//! registry). Supports the full JSON grammar; used for the AOT manifest,
//! experiment results, and dataset persistence.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric read that honours the non-finite sentinel: `Display`
    /// writes NaN/±Inf as `null` (JSON has no spelling for them), so
    /// a re-loaded record surfaces them here as NaN. Still `None` for
    /// strings, bools, arrays, and objects.
    pub fn as_f64_or_nan(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            Json::Null => Some(f64::NAN),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn arr_str(v: &[String]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Str(x.clone())).collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy raw continuation bytes
                    let len = if c >= 0xf0 {
                        4
                    } else if c >= 0xe0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if !n.is_finite() {
                    // NaN/±Inf have no JSON representation; "{n}" would
                    // emit unparseable output. Write the null sentinel
                    // so records (e.g. cache-store shards) survive a
                    // re-load; readers recover NaN via `as_f64_or_nan`.
                    write!(f, "null")
                } else if n.fract() == 0.0
                    && n.abs() < 1e15
                    && (*n != 0.0 || n.is_sign_positive())
                {
                    // integral fast-path; -0.0 is excluded (casting to
                    // i64 would drop the sign bit and break the exact
                    // round-trip the cache store relies on)
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").idx(2).get("b").as_str(), Some("c"));
        assert_eq!(j.get("d"), &Json::Null);
        assert_eq!(j.get("missing"), &Json::Null);
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"x": [1.5, "two", false, null], "y": {"z": 3}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn non_finite_floats_roundtrip_via_null_sentinel() {
        // serializing NaN/±Inf used to emit `NaN`/`inf` — unparseable
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let j = Json::obj(vec![("m", Json::Num(bad))]);
            let text = j.to_string();
            assert_eq!(text, r#"{"m":null}"#, "got {text}");
            let back = Json::parse(&text).expect("sentinel output must re-parse");
            assert_eq!(back.get("m"), &Json::Null);
            let v = back.get("m").as_f64_or_nan().unwrap();
            assert!(v.is_nan(), "sentinel decodes to NaN, got {v}");
        }
        // as_f64_or_nan still rejects non-numeric values outright
        assert_eq!(Json::Str("x".into()).as_f64_or_nan(), None);
        assert_eq!(Json::Bool(true).as_f64_or_nan(), None);
    }

    #[test]
    fn finite_floats_roundtrip_bit_exactly() {
        // the cache store depends on exact f64 round-trips: Rust's
        // shortest-round-trip Display + exact str::parse
        let vals = [
            0.1,
            1.0 / 3.0,
            -2.5e-9,
            6.02214076e23,
            1.0000000000000002, // 1.0 + ulp
            -0.0,
            123456789.0,
            2.0f64.powi(-40),
        ];
        for &v in &vals {
            let text = Json::Num(v).to_string();
            let back = Json::parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(
                back.to_bits(),
                v.to_bits(),
                "value {v} reparsed as {back} (via {text})"
            );
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
        let j = Json::parse("\"caf\u{e9}\"").unwrap();
        assert_eq!(j.as_str(), Some("café"));
    }

    #[test]
    fn display_escapes_control_chars() {
        let s = Json::Str("a\"b\\c\nd".into()).to_string();
        assert_eq!(Json::parse(&s).unwrap().as_str(), Some("a\"b\\c\nd"));
    }
}
