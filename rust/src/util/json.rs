//! Minimal JSON parser/serializer (serde is unavailable in the offline
//! registry). Supports the full JSON grammar; used for the AOT manifest,
//! experiment results, and dataset persistence.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric read that honours the non-finite sentinel: `Display`
    /// writes NaN/±Inf as `null` (JSON has no spelling for them), so
    /// a re-loaded record surfaces them here as NaN. Still `None` for
    /// strings, bools, arrays, and objects.
    pub fn as_f64_or_nan(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            Json::Null => Some(f64::NAN),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn arr_str(v: &[String]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Str(x.clone())).collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy raw continuation bytes
                    let len = if c >= 0xf0 {
                        4
                    } else if c >= 0xe0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if !n.is_finite() {
                    // NaN/±Inf have no JSON representation; "{n}" would
                    // emit unparseable output. Write the null sentinel
                    // so records (e.g. cache-store shards) survive a
                    // re-load; readers recover NaN via `as_f64_or_nan`.
                    write!(f, "null")
                } else if n.fract() == 0.0
                    && n.abs() < 1e15
                    && (*n != 0.0 || n.is_sign_positive())
                {
                    // integral fast-path; -0.0 is excluded (casting to
                    // i64 would drop the sign bit and break the exact
                    // round-trip the cache store relies on)
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// One event from the forward-only streaming tokenizer. String-ish
/// tokens borrow from the input (`Cow::Borrowed`) unless the literal
/// contains escapes, in which case they decode into an owned buffer
/// with semantics identical to the tree parser.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonToken<'a> {
    ObjBegin,
    ObjEnd,
    ArrBegin,
    ArrEnd,
    /// An object key (the following value tokens belong to it).
    Key(std::borrow::Cow<'a, str>),
    Str(std::borrow::Cow<'a, str>),
    Num(f64),
    Bool(bool),
    Null,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum TokState {
    /// Expecting a value (document start, after ':', after ',' in an array).
    Value,
    /// Expecting a value or ']' (right after '[').
    ValueOrEnd,
    /// Expecting a key or '}' (right after '{').
    KeyOrEnd,
    /// Expecting a key (after ',' in an object).
    Key,
    /// Expecting ',' or a container close.
    AfterValue,
    /// The document value is complete; only whitespace may remain.
    Done,
}

/// Forward-only, zero-copy JSON tokenizer over raw bytes. Accepts and
/// rejects exactly the documents `Json::parse` does — numbers go
/// through the same byte-scan + `str::parse::<f64>` so f64 values are
/// bit-identical, and escaped strings reuse the tree parser's decoder.
/// Unlike the tree parser it never allocates a value tree, so shard
/// loads can skim envelopes and skip bodies (see `lazy_get`).
pub struct JsonTokenizer<'a> {
    bytes: &'a [u8],
    pos: usize,
    /// Open-container stack, `b'{'` / `b'['` per frame.
    stack: Vec<u8>,
    state: TokState,
}

impl<'a> JsonTokenizer<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        JsonTokenizer { bytes, pos: 0, stack: Vec::new(), state: TokState::Value }
    }

    /// Current byte offset (end of the last token consumed).
    pub fn pos(&self) -> usize {
        self.pos
    }

    fn terr(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn literal(&mut self, lit: &str) -> Result<(), JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(())
        } else {
            Err(self.terr(&format!("expected '{lit}'")))
        }
    }

    /// Decode a string literal. Fast path: no escapes, borrow the span
    /// between the quotes (validated UTF-8). Slow path: rewind to the
    /// opening quote and delegate to the tree parser's `string()` so
    /// escape semantics (incl. `\u` replacement chars) stay identical.
    fn cow_string(&mut self) -> Result<std::borrow::Cow<'a, str>, JsonError> {
        if self.peek() != Some(b'"') {
            return Err(self.terr("expected '\"'"));
        }
        let open = self.pos;
        self.pos += 1;
        let start = self.pos;
        loop {
            match self.peek() {
                None => return Err(self.terr("unterminated string")),
                Some(b'"') => {
                    let span = &self.bytes[start..self.pos];
                    self.pos += 1;
                    let s = std::str::from_utf8(span).map_err(|_| self.terr("bad utf8"))?;
                    return Ok(std::borrow::Cow::Borrowed(s));
                }
                Some(b'\\') => {
                    // escape found: fall back to the allocating decoder
                    let mut p = Parser { bytes: self.bytes, pos: open };
                    let s = p.string()?;
                    self.pos = p.pos;
                    return Ok(std::borrow::Cow::Owned(s));
                }
                Some(_) => self.pos += 1,
            }
        }
    }

    /// Byte-for-byte mirror of `Parser::number` so acceptance (e.g.
    /// `"1e"` fails, `"1e999"` parses to inf) and the resulting bits
    /// agree with the tree parser.
    fn number(&mut self) -> Result<f64, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map_err(|_| self.terr("bad number"))
    }

    fn after_value(&mut self) {
        self.state = if self.stack.is_empty() { TokState::Done } else { TokState::AfterValue };
    }

    fn value_token(&mut self) -> Result<JsonToken<'a>, JsonError> {
        match self.peek() {
            Some(b'n') => {
                self.literal("null")?;
                self.after_value();
                Ok(JsonToken::Null)
            }
            Some(b't') => {
                self.literal("true")?;
                self.after_value();
                Ok(JsonToken::Bool(true))
            }
            Some(b'f') => {
                self.literal("false")?;
                self.after_value();
                Ok(JsonToken::Bool(false))
            }
            Some(b'"') => {
                let s = self.cow_string()?;
                self.after_value();
                Ok(JsonToken::Str(s))
            }
            Some(b'[') => {
                self.pos += 1;
                self.stack.push(b'[');
                self.state = TokState::ValueOrEnd;
                Ok(JsonToken::ArrBegin)
            }
            Some(b'{') => {
                self.pos += 1;
                self.stack.push(b'{');
                self.state = TokState::KeyOrEnd;
                Ok(JsonToken::ObjBegin)
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => {
                let n = self.number()?;
                self.after_value();
                Ok(JsonToken::Num(n))
            }
            _ => Err(self.terr("unexpected character")),
        }
    }

    fn key_token(&mut self) -> Result<JsonToken<'a>, JsonError> {
        let k = self.cow_string()?;
        self.skip_ws();
        if self.peek() != Some(b':') {
            return Err(self.terr("expected ':'"));
        }
        self.pos += 1;
        self.state = TokState::Value;
        Ok(JsonToken::Key(k))
    }

    /// Pull the next token. `Ok(None)` exactly once, when the document
    /// value is complete and only trailing whitespace remained.
    #[allow(clippy::should_implement_trait)]
    pub fn next(&mut self) -> Result<Option<JsonToken<'a>>, JsonError> {
        loop {
            self.skip_ws();
            match self.state {
                TokState::Done => {
                    return if self.pos == self.bytes.len() {
                        Ok(None)
                    } else {
                        Err(self.terr("trailing characters"))
                    };
                }
                TokState::Value => return self.value_token().map(Some),
                TokState::ValueOrEnd => {
                    if self.peek() == Some(b']') {
                        self.pos += 1;
                        self.stack.pop();
                        self.after_value();
                        return Ok(Some(JsonToken::ArrEnd));
                    }
                    return self.value_token().map(Some);
                }
                TokState::KeyOrEnd => {
                    if self.peek() == Some(b'}') {
                        self.pos += 1;
                        self.stack.pop();
                        self.after_value();
                        return Ok(Some(JsonToken::ObjEnd));
                    }
                    return self.key_token().map(Some);
                }
                TokState::Key => return self.key_token().map(Some),
                TokState::AfterValue => match self.peek() {
                    Some(b',') => {
                        self.pos += 1;
                        self.state = if self.stack.last() == Some(&b'{') {
                            TokState::Key
                        } else {
                            TokState::Value
                        };
                        continue;
                    }
                    Some(b'}') if self.stack.last() == Some(&b'{') => {
                        self.pos += 1;
                        self.stack.pop();
                        self.after_value();
                        return Ok(Some(JsonToken::ObjEnd));
                    }
                    Some(b']') if self.stack.last() == Some(&b'[') => {
                        self.pos += 1;
                        self.stack.pop();
                        self.after_value();
                        return Ok(Some(JsonToken::ArrEnd));
                    }
                    _ => {
                        return Err(self.terr(if self.stack.last() == Some(&b'{') {
                            "expected ',' or '}'"
                        } else {
                            "expected ',' or ']'"
                        }));
                    }
                },
            }
        }
    }

    /// Consume one whole value (scalar or full container subtree) at a
    /// value position without decoding it, returning its byte span.
    /// This is the lazy-body primitive: the caller keeps the raw slice
    /// and tree-parses it only on materialization.
    pub fn value_span(&mut self) -> Result<(usize, usize), JsonError> {
        if self.state != TokState::Value {
            return Err(self.terr("value_span outside value position"));
        }
        self.skip_ws();
        let start = self.pos;
        let depth0 = self.stack.len();
        self.value_token()?;
        while self.stack.len() > depth0 {
            match self.next()? {
                Some(_) => {}
                None => return Err(self.terr("unexpected end of value")),
            }
        }
        Ok((start, self.pos))
    }
}

/// Scan a top-level JSON object for `key` and return the raw byte span
/// of its value, validating the whole document structurally (so torn
/// tails error) without building any value tree. Duplicate keys follow
/// the tree parser: last one wins. `Ok(None)` if the key is absent.
pub fn lazy_get<'a>(bytes: &'a [u8], key: &str) -> Result<Option<&'a [u8]>, JsonError> {
    let mut t = JsonTokenizer::new(bytes);
    match t.next()? {
        Some(JsonToken::ObjBegin) => {}
        _ => return Err(JsonError { pos: 0, msg: "expected top-level object".to_string() }),
    }
    let mut found: Option<(usize, usize)> = None;
    loop {
        match t.next()? {
            Some(JsonToken::Key(k)) => {
                let hit = k.as_ref() == key;
                let span = t.value_span()?;
                if hit {
                    found = Some(span);
                }
            }
            Some(JsonToken::ObjEnd) => break,
            _ => unreachable!("object position yields keys or the close"),
        }
    }
    // drain the trailing-garbage check so a torn tail never half-succeeds
    if t.next()?.is_some() {
        return Err(JsonError { pos: t.pos(), msg: "trailing characters".to_string() });
    }
    Ok(found.map(|(s, e)| &bytes[s..e]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").idx(2).get("b").as_str(), Some("c"));
        assert_eq!(j.get("d"), &Json::Null);
        assert_eq!(j.get("missing"), &Json::Null);
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"x": [1.5, "two", false, null], "y": {"z": 3}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn non_finite_floats_roundtrip_via_null_sentinel() {
        // serializing NaN/±Inf used to emit `NaN`/`inf` — unparseable
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let j = Json::obj(vec![("m", Json::Num(bad))]);
            let text = j.to_string();
            assert_eq!(text, r#"{"m":null}"#, "got {text}");
            let back = Json::parse(&text).expect("sentinel output must re-parse");
            assert_eq!(back.get("m"), &Json::Null);
            let v = back.get("m").as_f64_or_nan().unwrap();
            assert!(v.is_nan(), "sentinel decodes to NaN, got {v}");
        }
        // as_f64_or_nan still rejects non-numeric values outright
        assert_eq!(Json::Str("x".into()).as_f64_or_nan(), None);
        assert_eq!(Json::Bool(true).as_f64_or_nan(), None);
    }

    #[test]
    fn finite_floats_roundtrip_bit_exactly() {
        // the cache store depends on exact f64 round-trips: Rust's
        // shortest-round-trip Display + exact str::parse
        let vals = [
            0.1,
            1.0 / 3.0,
            -2.5e-9,
            6.02214076e23,
            1.0000000000000002, // 1.0 + ulp
            -0.0,
            123456789.0,
            2.0f64.powi(-40),
        ];
        for &v in &vals {
            let text = Json::Num(v).to_string();
            let back = Json::parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(
                back.to_bits(),
                v.to_bits(),
                "value {v} reparsed as {back} (via {text})"
            );
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
        let j = Json::parse("\"caf\u{e9}\"").unwrap();
        assert_eq!(j.as_str(), Some("café"));
    }

    #[test]
    fn display_escapes_control_chars() {
        let s = Json::Str("a\"b\\c\nd".into()).to_string();
        assert_eq!(Json::parse(&s).unwrap().as_str(), Some("a\"b\\c\nd"));
    }

    fn tokens(src: &str) -> Result<Vec<String>, JsonError> {
        let mut t = JsonTokenizer::new(src.as_bytes());
        let mut out = Vec::new();
        while let Some(tok) = t.next()? {
            out.push(format!("{tok:?}"));
        }
        Ok(out)
    }

    #[test]
    fn tokenizer_streams_nested_documents() {
        let toks = tokens(r#"{"a": [1, -2.5e2, "x\n"], "b": {"c": null}, "d": true}"#).unwrap();
        assert_eq!(
            toks,
            vec![
                "ObjBegin",
                "Key(\"a\")",
                "ArrBegin",
                "Num(1.0)",
                "Num(-250.0)",
                "Str(\"x\\n\")",
                "ArrEnd",
                "Key(\"b\")",
                "ObjBegin",
                "Key(\"c\")",
                "Null",
                "ObjEnd",
                "Key(\"d\")",
                "Bool(true)",
                "ObjEnd",
            ]
        );
    }

    #[test]
    fn tokenizer_borrows_escape_free_strings() {
        let src = r#"["plain", "esc\t"]"#;
        let mut t = JsonTokenizer::new(src.as_bytes());
        assert_eq!(t.next().unwrap(), Some(JsonToken::ArrBegin));
        match t.next().unwrap() {
            Some(JsonToken::Str(std::borrow::Cow::Borrowed(s))) => assert_eq!(s, "plain"),
            other => panic!("expected borrowed str, got {other:?}"),
        }
        match t.next().unwrap() {
            Some(JsonToken::Str(std::borrow::Cow::Owned(s))) => assert_eq!(s, "esc\t"),
            other => panic!("expected owned str, got {other:?}"),
        }
    }

    #[test]
    fn tokenizer_rejects_what_the_tree_parser_rejects() {
        for bad in ["{", "[1,]", "1 2", "", "{\"a\"}", "[1 2]", "{\"a\":1,}", "tru", "1e"] {
            assert!(Json::parse(bad).is_err(), "tree parser accepted {bad:?}");
            assert!(tokens(bad).is_err(), "tokenizer accepted {bad:?}");
        }
    }

    #[test]
    fn value_span_skips_whole_subtrees() {
        let src = r#"{"k": {"deep": [1, {"x": "}"}]}, "n": 7}"#;
        let mut t = JsonTokenizer::new(src.as_bytes());
        assert_eq!(t.next().unwrap(), Some(JsonToken::ObjBegin));
        assert!(matches!(t.next().unwrap(), Some(JsonToken::Key(_))));
        let (s, e) = t.value_span().unwrap();
        assert_eq!(&src[s..e], r#"{"deep": [1, {"x": "}"}]}"#);
        assert!(matches!(t.next().unwrap(), Some(JsonToken::Key(_))));
        let (s, e) = t.value_span().unwrap();
        assert_eq!(&src[s..e], "7");
        assert_eq!(t.next().unwrap(), Some(JsonToken::ObjEnd));
        assert_eq!(t.next().unwrap(), None);
    }

    #[test]
    fn lazy_get_finds_spans_without_tree_parsing() {
        let src = br#"{"v":1,"kind":"eval","key":"00ff","used":3,"body":{"w":[1.5,null]}}"#;
        assert_eq!(lazy_get(src, "kind").unwrap(), Some(&b"\"eval\""[..]));
        assert_eq!(lazy_get(src, "used").unwrap(), Some(&b"3"[..]));
        assert_eq!(lazy_get(src, "body").unwrap(), Some(&br#"{"w":[1.5,null]}"#[..]));
        assert_eq!(lazy_get(src, "missing").unwrap(), None);
        // duplicate keys: last wins, matching BTreeMap insert order
        assert_eq!(lazy_get(br#"{"a":1,"a":2}"#, "a").unwrap(), Some(&b"2"[..]));
        // torn tails must error, never return a partial span
        for cut in 1..src.len() {
            assert!(lazy_get(&src[..cut], "v").is_err(), "accepted torn prefix len {cut}");
        }
        assert!(lazy_get(b"[1,2]", "a").is_err(), "top level must be an object");
    }

    #[test]
    fn tokenizer_numbers_are_bit_identical_to_tree_parser() {
        for src in ["0.1", "-0.0", "1e999", "-2.5e-9", "6.02214076e23", "123456789"] {
            let tree = Json::parse(src).unwrap().as_f64().unwrap();
            let mut t = JsonTokenizer::new(src.as_bytes());
            match t.next().unwrap() {
                Some(JsonToken::Num(n)) => assert_eq!(
                    n.to_bits(),
                    tree.to_bits(),
                    "tokenizer {n} != tree {tree} for {src}"
                ),
                other => panic!("expected number, got {other:?}"),
            }
            assert_eq!(t.next().unwrap(), None);
        }
    }
}
