//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} wants an integer, got {v:?}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} wants an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name} wants a number, got {v:?}")),
        }
    }

    /// Optional path-valued option (e.g. `--cache-dir DIR`).
    pub fn path(&self, name: &str) -> Option<std::path::PathBuf> {
        self.get(name).map(std::path::PathBuf::from)
    }

    pub fn required(&self, name: &str) -> Result<&str> {
        match self.get(name) {
            Some(v) => Ok(v),
            None => bail!("missing required option --{name}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn mixed_forms() {
        // NB: a bare `--flag` followed by a non-option token is parsed as
        // `--key value`; callers put positionals first or use `--flag=`.
        let a = parse("run pos1 --seed 7 --out=dir --verbose");
        assert_eq!(a.positional, vec!["run", "pos1"]);
        assert_eq!(a.get("seed"), Some("7"));
        assert_eq!(a.get("out"), Some("dir"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse("--n 5 --x 1.5");
        assert_eq!(a.usize_or("n", 0).unwrap(), 5);
        assert_eq!(a.f64_or("x", 0.0).unwrap(), 1.5);
        assert_eq!(a.usize_or("missing", 9).unwrap(), 9);
        assert!(a.required("absent").is_err());
    }

    #[test]
    fn trailing_flag_not_eating_next_flag() {
        let a = parse("--a --b v");
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }

    #[test]
    fn path_option() {
        let a = parse("--cache-dir /tmp/fso-cache");
        assert_eq!(
            a.path("cache-dir"),
            Some(std::path::PathBuf::from("/tmp/fso-cache"))
        );
        assert_eq!(a.path("out-dir"), None);
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse("--n nope");
        assert!(a.usize_or("n", 0).is_err());
    }
}
