//! Small dense f32 tensor used as the host-side interchange type between
//! the coordinator and PJRT literals. Not a general ndarray — just what the
//! framework needs: shaped storage, row-major indexing, literal conversion.

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Row-major strided index for 2-D tensors.
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j] = v;
    }

    /// Row-major strided index for 3-D tensors.
    pub fn at3(&self, i: usize, j: usize, k: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 3);
        self.data[(i * self.shape[1] + j) * self.shape[2] + k]
    }

    pub fn set3(&mut self, i: usize, j: usize, k: usize, v: f32) {
        debug_assert_eq!(self.shape.len(), 3);
        self.data[(i * self.shape[1] + j) * self.shape[2] + k] = v;
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.shape.is_empty() {
            // rank-0: reshape to scalar
            Ok(lit.reshape(&[])?)
        } else {
            let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
            Ok(lit.reshape(&dims)?)
        }
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Tensor::from_vec(&dims, data)
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_accounting() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.shape(), &[2, 3]);
    }

    #[test]
    fn from_vec_rejects_mismatch() {
        assert!(Tensor::from_vec(&[2, 2], vec![1.0; 3]).is_err());
    }

    #[test]
    fn indexing_2d_3d() {
        let mut t = Tensor::zeros(&[2, 3]);
        t.set2(1, 2, 5.0);
        assert_eq!(t.at2(1, 2), 5.0);
        assert_eq!(t.data()[5], 5.0);
        let mut u = Tensor::zeros(&[2, 3, 4]);
        u.set3(1, 2, 3, 7.0);
        assert_eq!(u.at3(1, 2, 3), 7.0);
        assert_eq!(u.data()[23], 7.0);
    }

    #[test]
    fn max_abs_diff_works() {
        let a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::from_vec(&[3], vec![1.0, 2.5, 2.0]).unwrap();
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }
}
