//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `check(cases, seed, |rng| ...)` runs a property across `cases` random
//! inputs; on failure it reports the failing case index and the fork seed
//! so the case can be replayed deterministically:
//!
//! ```no_run
//! // (no_run: doctest binaries don't inherit the xla rpath link-args)
//! use fso::util::prop::check;
//! check(64, 0xC0FFEE, |rng| {
//!     let n = rng.below(100) + 1;
//!     let plans = fso::runtime::Batcher::new(8).plan(n);
//!     let total: usize = plans.iter().map(|p| p.rows.len()).sum();
//!     assert_eq!(total, n);
//! });
//! ```

use crate::util::rng::Rng;

/// Run `property` on `cases` independently-seeded RNG forks; panic with a
/// replayable seed on the first failure.
pub fn check<F: Fn(&mut Rng)>(cases: usize, seed: u64, property: F) {
    let root = Rng::new(seed);
    for case in 0..cases {
        let mut rng = root.fork(case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut rng)
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| err.downcast_ref::<&str>().copied())
                .unwrap_or("panic");
            panic!(
                "property failed on case {case}/{cases} (replay: seed={seed:#x}, fork={case}): {msg}"
            );
        }
    }
}

/// Replay a single failing case.
pub fn replay<F: FnMut(&mut Rng)>(seed: u64, fork: u64, mut property: F) {
    let mut rng = Rng::new(seed).fork(fork);
    property(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        check(32, 1, |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property failed on case")]
    fn reports_failing_case() {
        check(64, 2, |rng| {
            let x = rng.below(10);
            assert!(x < 9, "x was {x}");
        });
    }

    #[test]
    fn replay_reproduces_case_stream() {
        let mut seen = Vec::new();
        check(4, 3, |rng| {
            // property records, never fails
            let v = rng.next_u64();
            let _ = v;
        });
        replay(3, 2, |rng| seen.push(rng.next_u64()));
        replay(3, 2, |rng| seen.push(rng.next_u64()));
        assert_eq!(seen[0], seen[1]);
    }
}
