//! L3 coordinator: dataset generation, model-training orchestration,
//! the dynamic-batching prediction server, the MOTPE DSE driver, and
//! the per-table/figure experiment drivers (DESIGN.md §5).

pub mod datagen;
pub mod dse_driver;
pub mod experiments;
pub mod predict_server;
pub mod trainer;

pub use datagen::{generate, DatagenConfig, GeneratedData};
pub use dse_driver::{DseDriver, DseProblem, SurrogateBundle};
pub use predict_server::{PredictClient, PredictServer, ServerStats};
pub use trainer::{EvalReport, ModelMenu, TrainOptions, Trainer};
