//! L3 coordinator: dataset generation, model-training orchestration,
//! the parallel memoizing evaluation service, the single-flight /
//! cross-client request-coalescing layer (`coalesce`), the
//! dynamic-batching prediction server, the MOTPE DSE driver, the
//! per-table/figure experiment drivers (DESIGN.md §5), and the shared
//! persistent-store subsystem both durable caches are built on
//! (`store`).

pub mod cache_store;
pub mod coalesce;
pub mod datagen;
pub mod dse_driver;
pub mod eval_service;
pub mod experiments;
pub mod fleet;
pub mod model_store;
pub mod predict_server;
pub mod server;
pub mod store;
pub mod trainer;

pub use cache_store::{CacheStore, CacheStoreStats};
pub use coalesce::{EvalRouter, RouterClient, SingleFlight};
pub use datagen::{generate, generate_sweep, generate_with, DatagenConfig, GeneratedData};
pub use dse_driver::{DseDriver, DseProblem, SurrogateBundle};
pub use eval_service::{EvalService, EvalStats, Evaluation, RemoteOracle, SurrogatePoint};
pub use fleet::{run_leader, run_worker, FleetOracle, FleetQueue, LeaderOptions};
pub use model_store::{ModelKey, ModelStore, ModelStoreStats};
pub use predict_server::{PredictClient, PredictServer, ServerStats};
pub use server::{run_daemon, ServeOptions, ServeStats};
pub use store::{Codec, CompactReport, StorePolicy, StoreStats};
pub use trainer::{EvalReport, ModelCacheStats, ModelMenu, TrainOptions, Trainer};
