//! Request coalescing for oracle + surrogate traffic (ISSUE 5): the
//! front-end that sits between DSE/datagen workers and the
//! `EvalService` hot paths.
//!
//! Two mechanisms, both invisible to results:
//!
//! - **Single-flight dedup** ([`SingleFlight`]): concurrent callers
//!   that miss the memo on the *same* content-hash key elect one
//!   leader to run the expensive computation (SP&R flow + simulator);
//!   every other caller waits on the in-flight run and receives the
//!   leader's bit-identical value. A leader error is broadcast to the
//!   waiters as an error; a leader *panic* propagates to every waiter
//!   (nobody hangs on a dead flight). The `EvalService` wires this
//!   around its oracle and flow miss paths (`with_coalescing`) and
//!   reports `coalesced_hits` / `inflight_peak` / `oracle_runs` in
//!   [`super::eval_service::EvalStats`].
//!
//! - **Cross-client surrogate batching** ([`EvalRouter`]): the
//!   PJRT-only `PredictServer` dynamic-batching pattern
//!   (`coordinator::predict_server`), generalized to the tree-family
//!   surrogate. Clients submit feature rows over a channel; the
//!   router thread drains whatever is queued — its coalescing window —
//!   concatenates the rows from *all* cohabiting requests, runs one
//!   metric-major `predict_batch` mega-batch, and splits the results
//!   back per request. `SurrogateBundle::predict_batch` scores rows
//!   independently, so batch composition never changes a value; the
//!   `router_batches` counters prove the occupancy gain.
//!
//! **Determinism contract**: coalescing shares *work*, never state —
//! a coalesced run at the same seed produces byte-identical rows,
//! reports, and Pareto fronts to the serial path, and the
//! hit/miss/run counter totals are thread-schedule-independent
//! (`oracle_runs == unique keys` on any workload).
//!
//! The [`hook`] submodule (mirroring `store::fault`) lets tests force
//! exact interleavings — "N waiters queued before the leader
//! finishes", "N requests queued before the router drains" — without
//! sleeps; see `tests/coalesce.rs`.

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::eval_service::{EvalService, SurrogatePoint};
use crate::util::pool::panic_message;

/// Safety valve for the test barriers: an armed interleaving that
/// never completes (test bug) times out instead of deadlocking CI.
const HOOK_TIMEOUT: Duration = Duration::from_secs(10);

/// Test-only interleaving hooks (ISSUE 5 satellite, mirroring
/// `store::fault`): process-global and one-shot — `arm_*` schedules a
/// single forced interleaving, the next leader/drain consumes it, and
/// everything after runs normally. Tests that arm hooks must
/// serialize themselves (the hook does not know which flight or
/// router will fire next).
pub mod hook {
    use std::sync::atomic::{AtomicUsize, Ordering};

    static LEADER_BARRIER: AtomicUsize = AtomicUsize::new(0);
    static ROUTER_BARRIER: AtomicUsize = AtomicUsize::new(0);

    /// The next single-flight *leader* blocks — after winning the
    /// flight, before computing — until `waiters` callers are queued
    /// on its flight. Forces "N waiters queued before the leader
    /// finishes" without sleeps.
    pub fn arm_leader_barrier(waiters: usize) {
        LEADER_BARRIER.store(waiters, Ordering::SeqCst);
    }

    /// The next router drain holds its coalescing window open until
    /// `requests` predict requests are queued (or a shutdown arrives),
    /// forcing them into one mega-batch.
    pub fn arm_router_barrier(requests: usize) {
        ROUTER_BARRIER.store(requests, Ordering::SeqCst);
    }

    /// Cancel any pending barrier (test cleanup).
    pub fn disarm() {
        LEADER_BARRIER.store(0, Ordering::SeqCst);
        ROUTER_BARRIER.store(0, Ordering::SeqCst);
    }

    pub(super) fn take_leader_barrier() -> Option<usize> {
        let n = LEADER_BARRIER.swap(0, Ordering::SeqCst);
        if n > 0 {
            Some(n)
        } else {
            None
        }
    }

    pub(super) fn take_router_barrier() -> Option<usize> {
        let n = ROUTER_BARRIER.swap(0, Ordering::SeqCst);
        if n > 0 {
            Some(n)
        } else {
            None
        }
    }
}

/// How a [`SingleFlight::run`] call was served.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Joined<T> {
    /// This call won the flight and ran the computation itself.
    Led(T),
    /// This call waited on another caller's in-flight computation and
    /// received its bit-identical result.
    Coalesced(T),
}

/// A leader error crosses the flight as its full anyhow context chain
/// (outermost context first, root cause last), not a flattened string:
/// waiters rebuild a real error whose `{e:#}` rendering matches the
/// leader's, so cache/store context (`"loading shard 3: ..."`)
/// survives coalescing.
type ErrorChain = Vec<String>;

fn error_chain(e: &anyhow::Error) -> ErrorChain {
    e.chain().map(|c| c.to_string()).collect()
}

/// Rebuild an anyhow error from a leader's captured chain, wrapping it
/// in the waiter-side `coalesced leader failed` marker.
fn rebuild_error(chain: &[String]) -> anyhow::Error {
    let mut segments = chain.iter().rev();
    let mut err = match segments.next() {
        Some(root) => anyhow::anyhow!("{root}"),
        None => anyhow::anyhow!("unknown error"),
    };
    for ctx in segments {
        err = err.context(ctx.clone());
    }
    err.context("coalesced leader failed")
}

enum FlightState<T> {
    Running,
    Done(Result<T, ErrorChain>),
    Panicked(String),
}

/// One in-flight computation: waiters block on `cv` until the leader
/// publishes; the leader's barrier hook blocks on the same `cv` until
/// enough waiters have registered.
struct Flight<T> {
    state: Mutex<FlightState<T>>,
    cv: Condvar,
    waiters: AtomicUsize,
}

impl<T: Clone> Flight<T> {
    fn new() -> Flight<T> {
        Flight {
            state: Mutex::new(FlightState::Running),
            cv: Condvar::new(),
            waiters: AtomicUsize::new(0),
        }
    }

    /// Poison-tolerant state lock: the first waiter to re-panic with a
    /// leader panic poisons the mutex while unwinding; later waiters
    /// must still read the published state and re-panic with the
    /// *leader's* message, not a `PoisonError`.
    fn lock_state(&self) -> std::sync::MutexGuard<'_, FlightState<T>> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn publish(&self, state: FlightState<T>) {
        *self.lock_state() = state;
        self.cv.notify_all();
    }

    /// Barrier hook: hold the flight open until `need` waiters are
    /// queued (bounded by [`HOOK_TIMEOUT`]).
    fn wait_for_waiters(&self, need: usize) {
        let deadline = Instant::now() + HOOK_TIMEOUT;
        let mut guard = self.lock_state();
        while self.waiters.load(Ordering::SeqCst) < need {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (g, _) = self
                .cv
                .wait_timeout(guard, deadline - now)
                .unwrap_or_else(|p| p.into_inner());
            guard = g;
        }
    }

    /// Wait for the leader's result. `Err` carries the leader's error
    /// chain; a leader panic re-panics here so no waiter silently
    /// continues past a dead flight.
    fn join(&self) -> Result<T, ErrorChain> {
        self.waiters.fetch_add(1, Ordering::SeqCst);
        let mut guard = self.lock_state();
        // wake a leader blocked on the waiter barrier
        self.cv.notify_all();
        loop {
            match &*guard {
                FlightState::Running => {
                    guard = self.cv.wait(guard).unwrap_or_else(|p| p.into_inner())
                }
                FlightState::Done(r) => return r.clone(),
                FlightState::Panicked(msg) => {
                    // release the lock before unwinding so sibling
                    // waiters see Panicked, not a poisoned mutex
                    let msg = msg.clone();
                    drop(guard);
                    panic!("coalesced leader panicked: {msg}");
                }
            }
        }
    }

    /// Work-stealing flavor of [`Flight::join`]: instead of parking
    /// until the leader publishes, the waiter repeatedly offers itself
    /// to `steal` — which pulls one queued unit of *other* work off a
    /// shared queue and runs it to completion — and only parks (in
    /// short, re-checkable slices) once the queue is dry. Values are
    /// identical to the parked path; only idle time moves.
    fn join_stealing(&self, steal: &dyn Fn() -> bool) -> Result<T, ErrorChain> {
        self.waiters.fetch_add(1, Ordering::SeqCst);
        loop {
            {
                let guard = self.lock_state();
                // wake a leader blocked on the waiter barrier
                self.cv.notify_all();
                match &*guard {
                    FlightState::Running => {}
                    FlightState::Done(r) => return r.clone(),
                    FlightState::Panicked(msg) => {
                        let msg = msg.clone();
                        drop(guard);
                        panic!("coalesced leader panicked: {msg}");
                    }
                }
            }
            // lock released: pull one queued key and run it; if the
            // queue is dry, park briefly so a publish is seen promptly
            if !steal() {
                let guard = self.lock_state();
                if matches!(&*guard, FlightState::Running) {
                    let _ = self
                        .cv
                        .wait_timeout(guard, Duration::from_millis(1))
                        .unwrap_or_else(|p| p.into_inner());
                }
            }
        }
    }
}

/// Single-flight table: at most one computation per key is ever in
/// flight; concurrent callers for the same key coalesce onto it. Keys
/// are released as soon as their flight completes, so later callers
/// recompute (or hit whatever memo the computation fed).
pub struct SingleFlight<T> {
    flights: Mutex<HashMap<u64, Arc<Flight<T>>>>,
    inflight: AtomicUsize,
    peak: AtomicUsize,
}

impl<T: Clone> Default for SingleFlight<T> {
    fn default() -> Self {
        SingleFlight::new()
    }
}

impl<T: Clone> SingleFlight<T> {
    pub fn new() -> SingleFlight<T> {
        SingleFlight {
            flights: Mutex::new(HashMap::new()),
            inflight: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
        }
    }

    /// Highest number of concurrently in-flight leaders observed.
    pub fn inflight_peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    /// Run `compute` for `key`, or wait on another caller already
    /// running it. Exactly one caller (the leader) executes `compute`
    /// per in-flight window; waiters receive the leader's cloned
    /// value, full error context chain, or propagated panic.
    pub fn run<F>(&self, key: u64, compute: F) -> Result<Joined<T>>
    where
        F: FnOnce() -> Result<T>,
    {
        self.run_with_steal(key, compute, None)
    }

    /// [`SingleFlight::run`] with an optional work-stealing hook: when
    /// `steal` is supplied, a caller that loses the flight election
    /// pulls other queued work through it instead of idling until the
    /// leader publishes (see [`Flight::join_stealing`]). `steal`
    /// returns whether it ran a unit of work; it must never run the
    /// *waited-on* key (the flight table already guarantees one leader
    /// per key).
    pub fn run_with_steal<F>(
        &self,
        key: u64,
        compute: F,
        steal: Option<&dyn Fn() -> bool>,
    ) -> Result<Joined<T>>
    where
        F: FnOnce() -> Result<T>,
    {
        let (flight, leads) = {
            let mut map = self.flights.lock().unwrap();
            match map.entry(key) {
                Entry::Occupied(e) => (Arc::clone(e.get()), false),
                Entry::Vacant(v) => {
                    let f = Arc::new(Flight::new());
                    v.insert(Arc::clone(&f));
                    (f, true)
                }
            }
        };
        if !leads {
            let joined = match steal {
                Some(steal) => flight.join_stealing(steal),
                None => flight.join(),
            };
            return match joined {
                Ok(v) => Ok(Joined::Coalesced(v)),
                Err(chain) => Err(rebuild_error(&chain)),
            };
        }
        let depth = self.inflight.fetch_add(1, Ordering::SeqCst) + 1;
        self.peak.fetch_max(depth, Ordering::SeqCst);
        if let Some(need) = hook::take_leader_barrier() {
            flight.wait_for_waiters(need);
        }
        let outcome = catch_unwind(AssertUnwindSafe(compute));
        self.inflight.fetch_sub(1, Ordering::SeqCst);
        // release the key before publishing: a caller that arrives now
        // simply leads a fresh flight (and hits the memo the finished
        // computation fed, so no work repeats)
        self.flights.lock().unwrap().remove(&key);
        match outcome {
            Ok(Ok(v)) => {
                flight.publish(FlightState::Done(Ok(v.clone())));
                Ok(Joined::Led(v))
            }
            Ok(Err(e)) => {
                flight.publish(FlightState::Done(Err(error_chain(&e))));
                Err(e)
            }
            Err(payload) => {
                flight.publish(FlightState::Panicked(panic_message(payload.as_ref())));
                resume_unwind(payload)
            }
        }
    }
}

// ---------------------------------------------------------------------
// EvalRouter: cross-client surrogate batching
// ---------------------------------------------------------------------

type PredictReply = mpsc::Sender<Result<Vec<SurrogatePoint>, String>>;

enum RouterMsg {
    Predict {
        rows: Vec<Vec<f64>>,
        reply: PredictReply,
    },
    Shutdown,
}

/// Cheap cloneable submit handle onto a running router.
#[derive(Clone)]
pub struct RouterClient {
    tx: mpsc::Sender<RouterMsg>,
}

impl RouterClient {
    /// Score feature rows through the router's shared mega-batches.
    /// Value-identical to `EvalService::predict_batch` on the same
    /// rows — the router only changes who pays the batch overhead.
    pub fn predict(&self, rows: Vec<Vec<f64>>) -> Result<Vec<SurrogatePoint>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(RouterMsg::Predict { rows, reply })
            .context("eval router is gone")?;
        match rx.recv().context("eval router dropped an in-flight request")? {
            Ok(points) => Ok(points),
            Err(msg) => Err(anyhow::anyhow!("eval router predict failed: {msg}")),
        }
    }
}

/// Dynamic-batching router over an owned (`Arc`) service — the
/// generic sibling of `PredictServer` for tree-family surrogate
/// traffic. Drop shuts the service thread down; requests still queued
/// at shutdown receive replies or a disconnect error — never a hang.
///
/// `Sync` by construction (the submit channel sits behind a mutex), so
/// the serve daemon can hold one router in an `Arc` and mint a
/// [`RouterClient`] per connection thread.
pub struct EvalRouter {
    tx: Mutex<mpsc::Sender<RouterMsg>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl EvalRouter {
    /// Boot the router thread over a shared service (the service needs
    /// a surrogate attached for predictions to succeed).
    pub fn start(service: Arc<EvalService>) -> EvalRouter {
        let (tx, rx) = mpsc::channel();
        let handle = std::thread::spawn(move || serve(&service, &rx));
        EvalRouter { tx: Mutex::new(tx), handle: Some(handle) }
    }

    pub fn client(&self) -> RouterClient {
        RouterClient { tx: self.tx.lock().unwrap().clone() }
    }
}

impl Drop for EvalRouter {
    fn drop(&mut self) {
        let _ = self.tx.lock().unwrap().send(RouterMsg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Scoped router for borrowed services (`DseDriver::run_pipelined`):
/// the serve thread lives on `scope` and exits when every clone of
/// the returned client has been dropped — callers must drop their
/// clients before the scope closes or the scope's implicit join
/// deadlocks.
pub fn serve_scoped<'scope, 'env>(
    scope: &'scope std::thread::Scope<'scope, 'env>,
    service: &'env EvalService,
) -> RouterClient {
    let (tx, rx) = mpsc::channel();
    scope.spawn(move || serve(service, &rx));
    RouterClient { tx }
}

fn serve(service: &EvalService, rx: &mpsc::Receiver<RouterMsg>) {
    loop {
        let first = match rx.recv() {
            Ok(m) => m,
            Err(_) => return, // every client dropped
        };
        let mut pending = vec![first];
        // coalescing window: drain whatever else is queued
        while let Ok(m) = rx.try_recv() {
            pending.push(m);
        }
        // barrier hook: hold the window open until enough predict
        // requests cohabit (tests force exact batch compositions)
        if let Some(need) = hook::take_router_barrier() {
            let deadline = Instant::now() + HOOK_TIMEOUT;
            while !pending.iter().any(|m| matches!(m, RouterMsg::Shutdown)) {
                let have = pending
                    .iter()
                    .filter(|m| matches!(m, RouterMsg::Predict { .. }))
                    .count();
                if have >= need {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(m) => pending.push(m),
                    Err(_) => break, // timeout or disconnect
                }
            }
        }
        let mut shutdown = false;
        let mut requests: Vec<(Vec<Vec<f64>>, PredictReply)> = Vec::new();
        for m in pending {
            match m {
                RouterMsg::Shutdown => shutdown = true,
                RouterMsg::Predict { rows, reply } => requests.push((rows, reply)),
            }
        }
        // requests drained alongside a shutdown are still answered —
        // in-flight callers never hang on router teardown
        if !requests.is_empty() {
            run_mega_batch(service, requests);
        }
        if shutdown {
            return;
        }
    }
}

/// Concatenate every cohabiting request's rows, score them in one
/// metric-major `predict_batch` pass, and split the results back per
/// request. Row scoring is per-row independent, so cohabitation never
/// changes a value; an error is broadcast to the whole window.
fn run_mega_batch(service: &EvalService, requests: Vec<(Vec<Vec<f64>>, PredictReply)>) {
    let total: usize = requests.iter().map(|(rows, _)| rows.len()).sum();
    service.note_router_requests(requests.len(), total);
    if total == 0 {
        for (_, reply) in requests {
            let _ = reply.send(Ok(Vec::new()));
        }
        return;
    }
    // move the owned rows into the mega-batch (no row copies); only
    // the per-request lengths are needed to split the results back
    let mut mega: Vec<Vec<f64>> = Vec::with_capacity(total);
    let mut replies: Vec<(usize, PredictReply)> = Vec::with_capacity(requests.len());
    for (mut rows, reply) in requests {
        replies.push((rows.len(), reply));
        mega.append(&mut rows);
    }
    service.note_router_batch();
    match service.predict_batch(&mega) {
        Ok(points) => {
            let mut points = points.into_iter();
            for (n, reply) in replies {
                let chunk: Vec<SurrogatePoint> = points.by_ref().take(n).collect();
                let _ = reply.send(Ok(chunk));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for (_, reply) in replies {
                let _ = reply.send(Err(msg.clone()));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // hook-using interleaving tests live in tests/coalesce.rs (they
    // serialize on a process-global barrier); these cover the
    // hook-free single-flight semantics

    #[test]
    fn sequential_runs_each_lead_and_recompute() {
        let sf: SingleFlight<u64> = SingleFlight::new();
        let mut runs = 0;
        for want in [3u64, 4] {
            let got = sf
                .run(9, || {
                    runs += 1;
                    Ok(want)
                })
                .unwrap();
            assert_eq!(got, Joined::Led(want), "no concurrency, so every call leads");
        }
        assert_eq!(runs, 2, "flights release their key on completion");
        assert_eq!(sf.inflight_peak(), 1);
    }

    #[test]
    fn leader_error_is_returned_and_key_released() {
        let sf: SingleFlight<u64> = SingleFlight::new();
        let err = sf
            .run(1, || -> Result<u64> { Err(anyhow::anyhow!("tool crashed")) })
            .expect_err("leader error must surface");
        assert!(format!("{err:#}").contains("tool crashed"));
        // the key is free again: the next call computes normally
        let v = match sf.run(1, || Ok(7u64)).unwrap() {
            Joined::Led(v) | Joined::Coalesced(v) => v,
        };
        assert_eq!(v, 7);
    }

    #[test]
    fn waiter_error_rebuild_preserves_context_chain() {
        // unit-test the chain capture + rebuild round trip directly;
        // the cross-thread pin lives in tests/coalesce.rs
        let e = anyhow::anyhow!("disk exploded")
            .context("loading shard 3")
            .context("oracle cache read");
        let rebuilt = rebuild_error(&error_chain(&e));
        assert_eq!(
            format!("{rebuilt:#}"),
            "coalesced leader failed: oracle cache read: loading shard 3: disk exploded"
        );
    }

    #[test]
    fn stealing_waiter_pulls_queued_work_and_still_coalesces() {
        use std::sync::atomic::AtomicBool;
        let sf: SingleFlight<u64> = SingleFlight::new();
        let leading = AtomicBool::new(false);
        let stolen = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            let sf = &sf;
            let leading = &leading;
            let stolen = &stolen;
            scope.spawn(move || {
                sf.run(1, || {
                    leading.store(true, Ordering::SeqCst);
                    // hold the flight open until the waiter has stolen
                    while stolen.load(Ordering::SeqCst) == 0 {
                        std::thread::yield_now();
                    }
                    Ok(42)
                })
                .unwrap()
            });
            while !leading.load(Ordering::SeqCst) {
                std::thread::yield_now();
            }
            // the "queue" holds exactly one unit of other work
            let steal = || stolen.fetch_add(1, Ordering::SeqCst) == 0;
            let got = sf.run_with_steal(1, || Ok(0), Some(&steal)).unwrap();
            assert_eq!(got, Joined::Coalesced(42), "stealer still gets the leader's value");
        });
        assert!(stolen.load(Ordering::SeqCst) >= 1, "parked waiter pulled queued work");
    }

    #[test]
    fn distinct_keys_run_concurrently_and_peak_tracks_them() {
        let sf: SingleFlight<usize> = SingleFlight::new();
        let gate = std::sync::Barrier::new(2);
        std::thread::scope(|scope| {
            let sf = &sf;
            let gate = &gate;
            for k in 0..2u64 {
                scope.spawn(move || {
                    sf.run(k, || {
                        // both leaders in flight before either returns
                        gate.wait();
                        Ok(k as usize)
                    })
                    .unwrap()
                });
            }
        });
        assert_eq!(sf.inflight_peak(), 2);
    }
}
