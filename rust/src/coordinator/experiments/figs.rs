//! Figure experiments: 1b (synthesis/route miscorrelation), 3 (ROI
//! regions), 4 (f_eff curves), 6 (backend samples), 9 (arch samples),
//! 10 (extrapolation).

use anyhow::Result;

use crate::backend::{BackendConfig, Enablement, SpnrFlow};
use crate::coordinator::datagen::{self, backend_window, DatagenConfig};
use crate::data::Metric;
use crate::generators::{ArchConfig, ParamKind, Platform};
use crate::metrics::{kendall_tau, mape_stats};
use crate::models::{Gbdt, GbdtParams};
use crate::sampling::{quantize, Sampler, SamplerKind};
use crate::simulators::{simulate_nondnn, EnergyModel};
use crate::workloads::{NonDnnAlgo, NonDnnWorkload};

use super::{write_csv, ExpOptions};

fn axiline_cfg(bench: f64, bits: f64, in_bits: f64, dim: f64, cyc: f64) -> ArchConfig {
    ArchConfig::new(Platform::Axiline, vec![bench, bits, in_bits, dim, cyc])
}

/// Fig. 1b: Kendall tau between post-synthesis and post-route power /
/// effective frequency for four TABLA designs over a backend sweep.
/// Paper reports poor, inconsistent correlation (power tau: 0.61, -0.20,
/// 0.07, 0.47; f_eff tau: 0.45, -0.20, -0.16, 0.10).
pub fn fig1b_miscorrelation(opts: &ExpOptions) -> Result<()> {
    let flow = SpnrFlow::new(Enablement::Gf12, opts.seed);
    let designs = [
        ArchConfig::new(Platform::Tabla, vec![4.0, 8.0, 8.0, 16.0, 0.0]),
        ArchConfig::new(Platform::Tabla, vec![8.0, 8.0, 16.0, 16.0, 1.0]),
        ArchConfig::new(Platform::Tabla, vec![4.0, 16.0, 16.0, 32.0, 0.0]),
        ArchConfig::new(Platform::Tabla, vec![8.0, 16.0, 8.0, 32.0, 1.0]),
    ];
    // Sweep utilization at a per-design fixed target clock: a shared
    // f_target sweep would trivially correlate both stages (power scales
    // with f in both); the paper's miscorrelation is about what synthesis
    // CANNOT see — floorplan/congestion/routing effects and tool noise.
    let n_pts = if opts.quick { 12 } else { 40 };
    let mut rows = Vec::new();
    println!("design | tau(power syn,route) | tau(fmax syn, f_eff route)");
    for (di, d) in designs.iter().enumerate() {
        let f_target = 0.7 + 0.1 * di as f64;
        let mut syn_p = Vec::new();
        let mut pnr_p = Vec::new();
        let mut syn_f = Vec::new();
        let mut pnr_f = Vec::new();
        for k in 0..n_pts {
            let util = 0.2 + 0.4 * k as f64 / (n_pts - 1) as f64;
            let fr = flow.run(d, BackendConfig::new(f_target, util))?;
            syn_p.push(fr.synth.syn_power_w);
            pnr_p.push(fr.backend.total_power_w());
            syn_f.push(fr.synth.syn_fmax_ghz);
            pnr_f.push(fr.backend.f_effective_ghz);
        }
        let tau_p = kendall_tau(&syn_p, &pnr_p);
        let tau_f = kendall_tau(&syn_f, &pnr_f);
        println!("TABLA-{} | {tau_p:+.2} | {tau_f:+.2}", di + 1);
        rows.push(format!("tabla{},{tau_p},{tau_f}", di + 1));
    }
    write_csv(&opts.csv_path("fig1b"), "design,tau_power,tau_feff", &rows)?;
    Ok(())
}

/// Fig. 3: energy-vs-runtime / runtime-vs-f_target / f_eff-vs-f_target
/// for two Axiline recsys designs over 21 target clocks — exhibits the
/// three regions (runtime / balance / energy) that define the ROI.
pub fn fig3_roi_regions(opts: &ExpOptions) -> Result<()> {
    let flow = SpnrFlow::new(Enablement::Gf12, opts.seed);
    // Design-I: wide+slow; Design-II: narrow+fast (same algorithm)
    let designs = [
        ("Design-I", axiline_cfg(3.0, 16.0, 8.0, 40.0, 16.0)),
        ("Design-II", axiline_cfg(3.0, 16.0, 8.0, 20.0, 4.0)),
    ];
    let wl = NonDnnWorkload::standard(NonDnnAlgo::Recsys, 55);
    let mut rows = Vec::new();
    println!("design | f_target | f_eff | runtime_ms | energy_mJ");
    for (name, d) in &designs {
        for i in 0..21 {
            let ft = 0.2 + 0.1 * i as f64; // 0.2 .. 2.2 GHz
            let fr = flow.run(d, BackendConfig::new(ft, 0.6))?;
            let e = EnergyModel::new(&fr.backend, Enablement::Gf12);
            let sys = simulate_nondnn(d, &fr.backend, Enablement::Gf12, &wl)?;
            let _ = e;
            println!(
                "{name} | {ft:.2} | {:.3} | {:.3} | {:.3}",
                fr.backend.f_effective_ghz,
                sys.runtime_s * 1e3,
                sys.energy_j * 1e3
            );
            rows.push(format!(
                "{name},{ft},{},{},{}",
                fr.backend.f_effective_ghz, sys.runtime_s, sys.energy_j
            ));
        }
    }
    write_csv(&opts.csv_path("fig3"), "design,f_target,f_eff,runtime_s,energy_j", &rows)?;
    println!("(region of balance = band where f_eff tracks f_target; see fig3.csv)");
    Ok(())
}

/// Fig. 4: f_eff vs f_target for Axiline / VTA / TABLA on GF12, with
/// utilization varying over the Fig. 6 window.
pub fn fig4_feff_curves(opts: &ExpOptions) -> Result<()> {
    let flow = SpnrFlow::new(Enablement::Gf12, opts.seed);
    let mut rows = Vec::new();
    for p in [Platform::Axiline, Platform::Vta, Platform::Tabla] {
        let arch = ArchConfig::new(
            p,
            p.param_space().iter().map(|s| s.kind.from_unit(0.5)).collect(),
        );
        let ((f_lo, f_hi), (u_lo, u_hi)) = backend_window(p, Enablement::Gf12);
        println!("--- {p} ---");
        println!("f_target | util | f_eff");
        let n = if opts.quick { 8 } else { 21 };
        for i in 0..n {
            let t = i as f64 / (n - 1) as f64;
            let ft = f_lo + t * (f_hi - f_lo);
            let util = u_lo + t * (u_hi - u_lo); // util varies with f (paper Fig. 6)
            let fr = flow.run(&arch, BackendConfig::new(ft, util))?;
            println!("{ft:.2} | {util:.2} | {:.3}", fr.backend.f_effective_ghz);
            rows.push(format!("{p},{ft},{util},{}", fr.backend.f_effective_ghz));
        }
    }
    write_csv(&opts.csv_path("fig4"), "platform,f_target,util,f_eff", &rows)?;
    Ok(())
}

/// Fig. 6: LHS-sampled backend configurations (train/test pools).
pub fn fig6_backend_samples(opts: &ExpOptions) -> Result<()> {
    let mut rows = Vec::new();
    for p in Platform::ALL {
        let train = datagen::sample_backend(p, Enablement::Gf12, 30, opts.seed ^ 0xB1);
        let test = datagen::sample_backend(p, Enablement::Gf12, 10, opts.seed ^ 0xB2);
        println!("{p}: {} train + {} test backend points", train.len(), test.len());
        for b in &train {
            rows.push(format!("{p},train,{},{}", b.f_target_ghz, b.util));
        }
        for b in &test {
            rows.push(format!("{p},test,{},{}", b.f_target_ghz, b.util));
        }
    }
    write_csv(&opts.csv_path("fig6"), "platform,pool,f_target,util", &rows)?;
    println!("wrote {}", opts.csv_path("fig6").display());
    Ok(())
}

/// Fig. 9: Axiline architectural configurations sampled by LHS / Sobol /
/// Halton (train+val+test pools).
pub fn fig9_arch_samples(opts: &ExpOptions) -> Result<()> {
    let space = Platform::Axiline.param_space();
    let mut rows = Vec::new();
    for kind in SamplerKind::ALL {
        for (pool, n, seed) in [("train", 24, 0u64), ("val", 10, 1), ("test", 10, 2)] {
            let mut s = Sampler::new(kind, space.len(), opts.seed ^ seed ^ kind.name().len() as u64);
            let pts = quantize(&s.sample(n), &space);
            for p in pts {
                rows.push(format!(
                    "{},{pool},{},{},{},{},{}",
                    kind.name(),
                    p[0],
                    p[1],
                    p[2],
                    p[3],
                    p[4]
                ));
            }
        }
        println!("{}: sampled 24 train + 10 val + 10 test architectures", kind.name());
    }
    write_csv(
        &opts.csv_path("fig9"),
        "sampler,pool,benchmark,bitwidth,input_bitwidth,dimension,num_cycles",
        &rows,
    )?;
    Ok(())
}

/// Fig. 10 / §8.3: extrapolation study — train on small Axiline
/// dimensions, test beyond the training range; the model must degrade
/// vs the in-range protocol (the paper's argument for covering the
/// whole space with the training set).
pub fn fig10_extrapolation(opts: &ExpOptions) -> Result<()> {
    let platform = Platform::Axiline;
    let enablement = Enablement::Gf12;
    let base = DatagenConfig {
        coalesce: opts.coalesce,
        ..DatagenConfig::small(platform, enablement)
    };
    let backends_train = datagen::sample_backend(platform, enablement, 30, opts.seed ^ 0xB1);
    let backends_test = datagen::sample_backend(platform, enablement, 10, opts.seed ^ 0xB2);

    // in-range: dims sampled over the full [5, 60]
    let archs_full = datagen::sample_archs(platform, 24, SamplerKind::Lhs, opts.seed);
    // extrapolation: train dims in [5, 30], test dims in [40, 60]
    let clamp_dim = |a: &ArchConfig, lo: f64, hi: f64| {
        let mut c = a.clone();
        let di = platform
            .param_space()
            .iter()
            .position(|s| s.name == "dimension")
            .unwrap();
        c.values[di] = lo + (c.values[di] - 5.0) / 55.0 * (hi - lo);
        c.values[di] = c.values[di].round();
        c
    };
    let archs_low: Vec<ArchConfig> =
        archs_full.iter().map(|a| clamp_dim(a, 5.0, 30.0)).collect();
    let archs_high: Vec<ArchConfig> =
        archs_full.iter().take(10).map(|a| clamp_dim(a, 40.0, 60.0)).collect();

    let eval = |train_archs: Vec<ArchConfig>, test_archs: Vec<ArchConfig>| -> Result<f64> {
        let mut all = train_archs.clone();
        let n_train_archs = all.len();
        all.extend(test_archs);
        let g = datagen::build_rows(&base, all, &backends_train, &backends_test)?;
        let ds = &g.dataset;
        let train_idx: Vec<usize> = (0..ds.len())
            .filter(|&i| ds.rows[i].arch_idx < n_train_archs && ds.rows[i].in_roi)
            .collect();
        let test_idx: Vec<usize> = (0..ds.len())
            .filter(|&i| ds.rows[i].arch_idx >= n_train_archs && ds.rows[i].in_roi)
            .collect();
        let x = ds.features(&train_idx);
        let y = ds.targets(&train_idx, Metric::Power);
        let model = Gbdt::fit(&x, &y, GbdtParams::default(), opts.seed);
        let pred = model.predict(&ds.features(&test_idx));
        Ok(mape_stats(&ds.targets(&test_idx, Metric::Power), &pred).mu_ape)
    };

    let in_range = eval(archs_full.clone(), archs_full[..10].to_vec())?;
    let extrapolated = eval(archs_low, archs_high)?;
    println!("backend power muAPE, in-range test:      {in_range:.2}%");
    println!("backend power muAPE, extrapolated test:  {extrapolated:.2}%");
    println!(
        "degradation: {:.1}x (paper: extrapolation \"performs poorly\")",
        extrapolated / in_range.max(1e-9)
    );
    write_csv(
        &opts.csv_path("fig10"),
        "protocol,mu_ape_power",
        &[
            format!("in_range,{in_range}"),
            format!("extrapolated,{extrapolated}"),
        ],
    )?;
    anyhow::ensure!(
        extrapolated > in_range,
        "extrapolation should be harder than interpolation"
    );
    Ok(())
}
