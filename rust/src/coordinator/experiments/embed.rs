//! Fig. 8: t-SNE of trained GCN graph embeddings for TABLA, VTA and
//! Axiline — distinct architectural configurations must form distinct
//! clusters (same-config points across backend knobs share an LHG, so
//! the check is inter- vs intra-config separation of the learned
//! embedding + global-feature space).

use anyhow::Result;

use crate::analysis::{tsne, TsneConfig};
use crate::backend::Enablement;
use crate::coordinator::datagen::{self, DatagenConfig};
use crate::coordinator::trainer::Trainer;
use crate::data::Metric;
use crate::models::{GcnModel, GraphCache, TrainConfig};
use crate::generators::Platform;

use super::{write_csv, ExpOptions};

pub fn fig8_tsne(opts: &ExpOptions) -> Result<()> {
    let trainer = Trainer::from_artifacts()?;
    let engine = trainer.engine.as_ref().unwrap().clone();
    let platforms = if opts.quick {
        vec![Platform::Axiline]
    } else {
        vec![Platform::Tabla, Platform::Vta, Platform::Axiline]
    };
    let mut rows = Vec::new();
    for platform in platforms {
        let mut cfg = DatagenConfig::small(platform, Enablement::Gf12);
        cfg.coalesce = opts.coalesce;
        cfg.n_arch = 8;
        cfg.n_backend_train = 12;
        cfg.n_backend_test = 4;
        let g = datagen::generate(&cfg)?;
        let ds = &g.dataset;
        let cache = GraphCache::build(&ds.lhgs, engine.manifest.nodes)?;
        let mut split = g.backend_split.clone();
        ds.carve_validation(&mut split, 0.2, opts.seed);
        let train_roi = ds.roi_subset(&split.train);
        let val_roi = ds.roi_subset(&split.val);
        let mut gcn = GcnModel::new(
            engine.clone(),
            "gcn3",
            TrainConfig { max_epochs: 15, early_stop: 6, ..Default::default() },
        )?;
        let targets: Vec<f64> = ds.rows.iter().map(|r| r.target(Metric::Power)).collect();
        gcn.fit(ds, &cache, &train_roi, &val_roi, &targets)?;

        let idx: Vec<usize> = (0..ds.len()).collect();
        let mut emb = gcn.embed_rows(ds, &cache, &idx)?;
        // The pooled graph embedding is identical across backend knobs of
        // one architecture (the LHG does not depend on them); append the
        // backend features, as the full model's FC stage sees them, so
        // each configuration forms a tight — not degenerate — cluster.
        for (e, &i) in emb.iter_mut().zip(idx.iter()) {
            e.push(ds.rows[i].features[12] * 0.3);
            e.push(ds.rows[i].features[13] * 0.3);
        }
        let coords = tsne(&emb, TsneConfig { iterations: 250, ..Default::default() });

        // separation: mean inter-config / intra-config distance
        let (mut intra, mut ni) = (0.0, 0usize);
        let (mut inter, mut nx) = (0.0, 0usize);
        for i in 0..coords.len() {
            for j in (i + 1)..coords.len() {
                let d = ((coords[i][0] - coords[j][0]).powi(2)
                    + (coords[i][1] - coords[j][1]).powi(2))
                .sqrt();
                if ds.rows[i].arch_idx == ds.rows[j].arch_idx {
                    intra += d;
                    ni += 1;
                } else {
                    inter += d;
                    nx += 1;
                }
            }
        }
        let intra = intra / ni.max(1) as f64;
        let inter = inter / nx.max(1) as f64;
        println!(
            "{platform}: t-SNE inter/intra config separation = {:.2} (want >> 1)",
            inter / intra.max(1e-12)
        );
        for (i, c) in coords.iter().enumerate() {
            rows.push(format!(
                "{platform},{},{},{}",
                ds.rows[i].arch_idx, c[0], c[1]
            ));
        }
    }
    write_csv(&opts.csv_path("fig8"), "platform,arch_idx,x,y", &rows)?;
    println!("wrote {}", opts.csv_path("fig8").display());
    Ok(())
}
