//! Table experiments: Table 3 (sampling methods x sizes x models),
//! Table 4 (unseen backend configurations), Table 5 (unseen
//! architectural configurations).

use anyhow::Result;

use crate::backend::Enablement;
use crate::coordinator::datagen::{self, DatagenConfig};
use crate::coordinator::trainer::{ModelMenu, TrainOptions, Trainer};
use crate::data::{Metric, Split};
use crate::generators::Platform;
use crate::sampling::SamplerKind;

use super::{write_csv, ExpOptions};

fn fmt(v: f64) -> String {
    format!("{v:6.2}")
}

/// Apply the `--workload` override to a platform's datagen only when the
/// workload kind matches the platform (DNN layer tables on GeneSys/VTA,
/// non-DNN training specs on TABLA/Axiline). The name is validated
/// against the registry either way; incompatible cells keep their
/// default binding so a cross-platform table sweep stays runnable.
fn workload_for(opts: &ExpOptions, platform: Platform) -> Result<Option<String>> {
    match &opts.workload {
        None => Ok(None),
        Some(name) => {
            let spec = crate::workloads::lookup(name)?;
            Ok((spec.is_dnn() == crate::simulators::is_dnn_platform(platform))
                .then(|| name.clone()))
        }
    }
}

/// Table 3: Axiline-SVM, training architectures sampled by LHS / Sobol /
/// Halton at sizes 16/24/32; unseen-architecture evaluation of backend
/// power and system energy (muAPE / STD APE / MAPE) per model.
pub fn tab3_sampling_study(opts: &ExpOptions) -> Result<()> {
    let platform = Platform::Axiline;
    let base = DatagenConfig {
        coalesce: opts.coalesce,
        workload: workload_for(opts, platform)?,
        ..DatagenConfig::small(platform, Enablement::Gf12)
    };
    let trainer = Trainer::from_artifacts()?;
    let sizes: &[usize] = if opts.quick { &[16] } else { &[16, 24, 32] };
    let menu = if opts.quick {
        ModelMenu::trees_only()
    } else {
        ModelMenu { ensemble: false, ..ModelMenu::default() }
    };
    let t_opts = TrainOptions {
        menu,
        seed: opts.seed,
        ann_cfg: crate::models::TrainConfig { max_epochs: 60, early_stop: 12, ..Default::default() },
        gcn_cfg: crate::models::TrainConfig {
            max_epochs: 12,
            early_stop: 5,
            patience: 3,
            lr0: 1e-2,
            ..Default::default()
        },
        ..Default::default()
    };

    // fixed, separately-sampled val/test architectures (paper §7.2)
    let val_archs = datagen::sample_archs(platform, 10, SamplerKind::Lhs, opts.seed ^ 0x7A1);
    let test_archs = datagen::sample_archs(platform, 10, SamplerKind::Lhs, opts.seed ^ 0x7E5);
    let backends_train = datagen::sample_backend(platform, Enablement::Gf12, 30, opts.seed ^ 0xB1);
    let backends_test = datagen::sample_backend(platform, Enablement::Gf12, 10, opts.seed ^ 0xB2);

    let mut rows = Vec::new();
    println!("sampler | size | model | power muAPE/STD/MAPE | energy muAPE/STD/MAPE");
    for kind in SamplerKind::ALL {
        for &size in sizes {
            let train_archs =
                datagen::sample_archs(platform, size, kind, opts.seed ^ kind.name().len() as u64);
            let n_train = train_archs.len();
            let n_val = val_archs.len();
            let mut all = train_archs;
            all.extend(val_archs.clone());
            all.extend(test_archs.clone());
            let g = datagen::build_rows(&base, all, &backends_train, &backends_test)?;
            let ds = &g.dataset;
            // unseen-architecture split by arch pools
            let mut split = Split::default();
            for (i, r) in ds.rows.iter().enumerate() {
                if r.arch_idx < n_train {
                    split.train.push(i);
                } else if r.arch_idx < n_train + n_val {
                    split.val.push(i);
                } else {
                    split.test.push(i);
                }
            }
            for metric in [Metric::Power, Metric::Energy] {
                let report = trainer.run(ds, &split, metric, &t_opts)?;
                for (model, stats) in &report.models {
                    rows.push(format!(
                        "{},{size},{model},{},{},{},{}",
                        kind.name(),
                        metric.name(),
                        stats.mu_ape,
                        stats.std_ape,
                        stats.max_ape
                    ));
                }
            }
            // print the power+energy rows side by side per model
            let power_rows: Vec<&String> = rows
                .iter()
                .filter(|r| r.starts_with(&format!("{},{size}", kind.name())) && r.contains(",power,"))
                .collect();
            for pr in power_rows {
                let parts: Vec<&str> = pr.split(',').collect();
                let model = parts[2];
                let er = rows.iter().find(|r| {
                    r.starts_with(&format!("{},{size},{model},energy", kind.name()))
                });
                let e = er.map(|r| {
                    let p: Vec<&str> = r.split(',').collect();
                    (p[4].parse::<f64>().unwrap(), p[5].parse::<f64>().unwrap(), p[6].parse::<f64>().unwrap())
                });
                let (pm, ps, px) = (
                    parts[4].parse::<f64>().unwrap(),
                    parts[5].parse::<f64>().unwrap(),
                    parts[6].parse::<f64>().unwrap(),
                );
                if let Some((em, es, ex)) = e {
                    println!(
                        "{:6} | {size:2} | {model:8} | {}/{}/{} | {}/{}/{}",
                        kind.name(),
                        fmt(pm),
                        fmt(ps),
                        fmt(px),
                        fmt(em),
                        fmt(es),
                        fmt(ex)
                    );
                }
            }
        }
    }
    write_csv(
        &opts.csv_path("tab3"),
        "sampler,size,model,metric,mu_ape,std_ape,max_ape",
        &rows,
    )?;
    Ok(())
}

/// Shared implementation for Tables 4 and 5.
fn unseen_table(
    opts: &ExpOptions,
    unseen_backend: bool,
    csv_name: &str,
) -> Result<()> {
    let trainer = Trainer::from_artifacts()?;
    let designs: Vec<(Platform, Enablement)> = if opts.quick {
        vec![(Platform::Axiline, Enablement::Gf12)]
    } else {
        vec![
            (Platform::Tabla, Enablement::Gf12),
            (Platform::GeneSys, Enablement::Gf12),
            (Platform::Vta, Enablement::Gf12),
            (Platform::Axiline, Enablement::Gf12),
            (Platform::Axiline, Enablement::Ng45),
        ]
    };
    let menu = if opts.quick {
        ModelMenu::trees_only()
    } else {
        ModelMenu::default()
    };
    let t_opts = TrainOptions {
        menu,
        seed: opts.seed,
        // table sweeps fit 25 (design, metric) cells: trim the ANN/GCN
        // budgets (the curves plateau well before the defaults)
        ann_cfg: crate::models::TrainConfig { max_epochs: 60, early_stop: 12, ..Default::default() },
        gcn_cfg: crate::models::TrainConfig {
            max_epochs: 12,
            early_stop: 5,
            patience: 3,
            lr0: 1e-2,
            ..Default::default()
        },
        ..Default::default()
    };

    let mut rows = Vec::new();
    for (platform, enablement) in designs {
        let cfg = DatagenConfig {
            coalesce: opts.coalesce,
            workload: workload_for(opts, platform)?,
            ..DatagenConfig::small(platform, enablement)
        };
        let g = datagen::generate(&cfg)?;
        let ds = &g.dataset;
        let split = if unseen_backend {
            // the separately-sampled backend pools from datagen
            g.backend_split.clone()
        } else {
            ds.split_unseen_arch(0.2, opts.seed)
        };
        println!("--- {platform} / {enablement} ({} rows) ---", ds.len());
        println!("model | perf muAPE/MAPE | power | area | energy | runtime | ROI acc/F1");
        let mut per_model: std::collections::BTreeMap<String, Vec<(f64, f64)>> =
            Default::default();
        let mut roi = None;
        for metric in Metric::ALL {
            let report = trainer.run(ds, &split, metric, &t_opts)?;
            roi = Some(report.roi);
            for (model, stats) in &report.models {
                per_model
                    .entry(model.clone())
                    .or_default()
                    .push((stats.mu_ape, stats.max_ape));
                rows.push(format!(
                    "{platform},{enablement},{model},{},{},{},{}",
                    metric.name(),
                    stats.mu_ape,
                    stats.std_ape,
                    stats.max_ape
                ));
            }
        }
        let roi = roi.unwrap();
        for (model, stats) in &per_model {
            let cells: Vec<String> = stats
                .iter()
                .map(|(mu, mx)| format!("{mu:5.1}/{mx:5.1}"))
                .collect();
            println!(
                "{model:8} | {} | acc={:.2} f1={:.2}",
                cells.join(" | "),
                roi.accuracy,
                roi.f1
            );
        }
    }
    write_csv(
        &opts.csv_path(csv_name),
        "platform,enablement,model,metric,mu_ape,std_ape,max_ape",
        &rows,
    )?;
    Ok(())
}

/// Table 4: unseen backend configurations.
pub fn tab4_unseen_backend(opts: &ExpOptions) -> Result<()> {
    unseen_table(opts, true, "tab4")
}

/// Table 5: unseen architectural configurations.
pub fn tab5_unseen_arch(opts: &ExpOptions) -> Result<()> {
    unseen_table(opts, false, "tab5")
}
