//! Experiment drivers, one per paper table/figure (DESIGN.md §5).
//! Every driver prints the paper's rows/series to stdout and writes CSV
//! under `results/`; EXPERIMENTS.md records paper-vs-measured.

pub mod dse;
pub mod embed;
pub mod figs;
pub mod tables;

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::coordinator::StorePolicy;

/// Common experiment options from the CLI.
#[derive(Debug, Clone)]
pub struct ExpOptions {
    pub seed: u64,
    pub out_dir: PathBuf,
    /// Reduced sizes for smoke runs / CI.
    pub quick: bool,
    /// Persistent oracle cache directory (`--cache-dir`): experiments
    /// that run the SP&R oracle warm-start from it and flush back. The
    /// same directory carries the surrogate-model store (`models/`
    /// subdirectory) unless `no_model_cache` opts out.
    pub cache_dir: Option<PathBuf>,
    /// `--no-model-cache`: keep the oracle cache but skip the
    /// surrogate-model store (always refit).
    pub no_model_cache: bool,
    /// Store lifecycle policy (`--store-max-*` flags): applied to both
    /// stores opened through these options.
    pub store_policy: StorePolicy,
    /// `--coalesce` (ISSUE 5): single-flight oracle dedup plus the
    /// pipelined DSE ask/tell cadence. Byte-identical results.
    pub coalesce: bool,
    /// `--inflight N`: scoring-pipeline depth for the pipelined DSE.
    pub inflight: usize,
    /// `--strategy {motpe,random,lhs,evo}`: which optimizer drives the
    /// DSE experiments. Motpe reproduces the historical trajectories
    /// byte for byte.
    pub strategy: crate::dse::StrategyKind,
    /// `--workload <name>`: registry workload override for experiments
    /// that price system metrics. `None` keeps each platform's default
    /// binding (paper §7.1).
    pub workload: Option<String>,
    /// `--archs N`: override the datagen architecture count of the DSE
    /// experiments (fleet smoke tests shrink runs below `--quick`).
    /// `None` keeps the historical sizes, byte for byte.
    pub archs: Option<usize>,
    /// `--iters N`: override the DSE iteration budget. `None` keeps
    /// the historical budgets.
    pub iters: Option<usize>,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            seed: 2023,
            out_dir: PathBuf::from("results"),
            quick: false,
            cache_dir: None,
            no_model_cache: false,
            store_policy: StorePolicy::default_auto(),
            coalesce: false,
            inflight: 4,
            strategy: crate::dse::StrategyKind::Motpe,
            workload: None,
            archs: None,
            iters: None,
        }
    }
}

impl ExpOptions {
    pub fn ensure_out_dir(&self) -> Result<()> {
        std::fs::create_dir_all(&self.out_dir)?;
        Ok(())
    }

    pub fn csv_path(&self, name: &str) -> PathBuf {
        self.out_dir.join(format!("{name}.csv"))
    }

    /// Open the persistent oracle cache named by `cache_dir`, if any,
    /// under the configured lifecycle policy.
    pub fn open_cache(&self) -> Result<Option<std::sync::Arc<crate::coordinator::CacheStore>>> {
        match &self.cache_dir {
            Some(dir) => Ok(Some(std::sync::Arc::new(
                crate::coordinator::CacheStore::open(dir)?
                    .with_policy(self.store_policy.clone()),
            ))),
            None => Ok(None),
        }
    }

    /// Open the surrogate-model store cohabiting under `cache_dir`
    /// (`<cache_dir>/models/`), unless `no_model_cache` opts out.
    pub fn open_model_store(
        &self,
    ) -> Result<Option<std::sync::Arc<crate::coordinator::ModelStore>>> {
        if self.no_model_cache {
            return Ok(None);
        }
        match &self.cache_dir {
            Some(dir) => Ok(Some(std::sync::Arc::new(
                crate::coordinator::ModelStore::open_under(dir)?
                    .with_policy(self.store_policy.clone()),
            ))),
            None => Ok(None),
        }
    }
}

/// Dispatch by experiment id (table/figure number).
pub fn run(id: &str, opts: &ExpOptions) -> Result<()> {
    opts.ensure_out_dir()?;
    match id {
        "fig1b" => figs::fig1b_miscorrelation(opts),
        "fig3" => figs::fig3_roi_regions(opts),
        "fig4" => figs::fig4_feff_curves(opts),
        "fig6" => figs::fig6_backend_samples(opts),
        "fig8" => embed::fig8_tsne(opts),
        "fig9" => figs::fig9_arch_samples(opts),
        "fig10" => figs::fig10_extrapolation(opts),
        "fig11" => dse::fig11_axiline_svm(opts),
        "fig12" => dse::fig12_vta(opts),
        "tab3" => tables::tab3_sampling_study(opts),
        "tab4" => tables::tab4_unseen_backend(opts),
        "tab5" => tables::tab5_unseen_arch(opts),
        "all" => {
            for id in [
                "fig1b", "fig3", "fig4", "fig6", "fig9", "tab3", "tab4", "tab5", "fig10",
                "fig8", "fig11", "fig12",
            ] {
                println!("\n================ experiment {id} ================");
                run(id, opts)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment {other:?} (fig1b|fig3|fig4|fig6|fig8|fig9|fig10|fig11|fig12|tab3|tab4|tab5|all)"),
    }
}

pub(crate) fn write_csv(path: &std::path::Path, header: &str, rows: &[String]) -> Result<()> {
    let mut text = String::from(header);
    text.push('\n');
    for r in rows {
        text.push_str(r);
        text.push('\n');
    }
    std::fs::write(path, text)?;
    Ok(())
}
