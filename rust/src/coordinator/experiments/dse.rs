//! DSE experiments (paper §8.4 / Figs. 11-12): MOTPE + trained two-stage
//! surrogates explore the space; the Eq. 3 winners are ground-truthed
//! against the full SP&R oracle + simulator. The paper's check: top-3
//! predictions within 7% (Axiline-SVM/NG45) and 6% (VTA/GF12).

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::backend::Enablement;
use crate::coordinator::datagen::{self, DatagenConfig};
use crate::coordinator::dse_driver::{axiline_nondnn_problem, vta_backend_problem, DseDriver};
use crate::coordinator::eval_service::RemoteOracle;
use crate::coordinator::EvalService;
use crate::data::Metric;
use crate::dse::MotpeConfig;
use crate::generators::{ArchConfig, Platform};
use crate::workloads::{self, NonDnnWorkload, WorkloadSpec};

use super::{write_csv, ExpOptions};

fn report(
    opts: &ExpOptions,
    name: &str,
    outcome: &crate::coordinator::dse_driver::DseOutcome,
) -> Result<f64> {
    let feasible = outcome.points.iter().filter(|p| p.feasible).count();
    println!(
        "explored {} points ({} feasible/green, {} rejected/red)",
        outcome.points.len(),
        feasible,
        outcome.points.len() - feasible
    );
    let mut rows = Vec::new();
    for p in &outcome.points {
        rows.push(format!(
            "{},{},{},{},{}",
            p.feasible,
            p.predicted[&Metric::Energy],
            p.predicted[&Metric::Runtime],
            p.predicted[&Metric::Area],
            p.predicted[&Metric::Power],
        ));
    }
    write_csv(
        &opts.csv_path(name),
        "feasible,energy_j,runtime_s,area_mm2,power_w",
        &rows,
    )?;

    let mut worst = 0.0f64;
    for (rank, errs) in outcome.ground_truth_errors.iter().enumerate() {
        let line: Vec<String> = Metric::ALL
            .iter()
            .map(|m| format!("{}={:.1}%", m.name(), errs[m] * 100.0))
            .collect();
        println!("top-{} prediction vs post-SP&R truth: {}", rank + 1, line.join(" "));
        for m in Metric::ALL {
            worst = worst.max(errs[&m]);
        }
    }
    println!("worst top-k error: {:.1}%", worst * 100.0);
    Ok(worst)
}

/// Fig. 11: DSE of Axiline-SVM (55 features) on NG45; size 10-51,
/// num_cycles 5-21, f_target 0.3-1.3 GHz, util 0.4-0.8; alpha=1,
/// beta=0.001.
pub fn fig11_axiline_svm(opts: &ExpOptions) -> Result<()> {
    fig11_axiline_svm_with(opts, None)
}

/// [`fig11_axiline_svm`] with an optional remote oracle: when `Some`,
/// every full oracle miss is dispatched to the evaluation fleet
/// (ISSUE 10) instead of running in-process. Byte-identical output
/// either way — the fleet ships back bit-exact evaluations.
pub fn fig11_axiline_svm_with(
    opts: &ExpOptions,
    remote: Option<Arc<dyn RemoteOracle>>,
) -> Result<()> {
    let enablement = Enablement::Ng45;
    // `--workload` picks any non-DNN registry entry for the Axiline
    // search; the default stays the paper's SVM-55
    let wl = match &opts.workload {
        None => NonDnnWorkload::standard(crate::workloads::NonDnnAlgo::Svm, 55),
        Some(name) => match workloads::lookup_with_features(name, 55)? {
            WorkloadSpec::NonDnn(wl) => wl,
            WorkloadSpec::Dnn(_) => bail!(
                "fig11 explores Axiline, a non-DNN platform; --workload {name} is a DNN \
                 layer table (pick one of svm, linear_regression, logistic_regression, recsys)"
            ),
        },
    };
    let mut cfg = DatagenConfig::small(Platform::Axiline, enablement);
    cfg.workload = opts.workload.clone();
    cfg.n_arch = 60; // datagen is cheap; dense coverage sharpens the surrogate
    if opts.quick {
        cfg.n_arch = 10;
        cfg.n_backend_train = 12;
        cfg.n_backend_test = 4;
    }
    if let Some(n) = opts.archs {
        cfg.n_arch = n;
    }
    println!("[fig11] generating Axiline/NG45 training data ({} archs)...", cfg.n_arch);
    // one service carries datagen and the DSE ground-truth checks, so
    // the oracle memo is shared; --cache-dir makes both the oracle
    // results and the fitted surrogate warm-startable
    let store = opts.open_cache()?;
    let mstore = opts.open_model_store()?;
    let mut service = EvalService::new(enablement, cfg.seed)
        .with_workers(crate::util::pool::default_workers())
        .with_coalescing(opts.coalesce)
        .with_cache_store_opt(store.clone())
        .with_model_store_opt(mstore.clone())
        .with_remote_oracle_opt(remote);
    let g = datagen::generate_with(&service, &cfg)?;
    let cached = service.fit_surrogate(&g.dataset, &g.backend_split, opts.seed)?;
    println!(
        "[fig11] surrogate: {}",
        if cached {
            "replayed from model store (0 refits, 0 tuning evals)"
        } else {
            "fitted fresh (1 refit)"
        }
    );
    let driver = DseDriver { service };

    // constraints: generous power cap, runtime cap from the dataset's
    // median (forces the search away from the slow tail)
    let mut runtimes: Vec<f64> = g.dataset.rows.iter().map(|r| r.runtime_s).collect();
    runtimes.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let r_max = runtimes[runtimes.len() / 2];
    let p_max = g
        .dataset
        .rows
        .iter()
        .map(|r| r.power_w)
        .fold(0.0f64, f64::max);
    // with no override this is exactly `axiline_svm_problem(p_max, r_max)`
    let problem = axiline_nondnn_problem(p_max, r_max, wl);

    let iters = opts.iters.unwrap_or(if opts.quick { 120 } else { 400 });
    println!(
        "[fig11] {} x {iters} over (dimension, num_cycles, f_target, util)",
        opts.strategy.name()
    );
    // --coalesce: pipelined ask/tell (byte-identical trajectory per
    // strategy; see DseDriver::run_pipelined_with)
    let scfg = MotpeConfig { seed: opts.seed, ..Default::default() };
    let strategy = opts.strategy.build(problem.space(), &scfg);
    let outcome = if opts.coalesce {
        driver.run_pipelined_with(&problem, strategy, iters, 3, 16, opts.inflight)?
    } else {
        driver.run_batched_with(&problem, strategy, iters, 3, 16)?
    };
    println!("[fig11] eval service: {}", driver.stats());
    if let Some(store) = &store {
        store.flush()?;
        println!("[fig11] cache store: {}", store.stats());
    }
    if let Some(ms) = &mstore {
        ms.flush()?;
        println!("[fig11] model store: {}", ms.stats());
    }
    let worst = report(opts, "fig11", &outcome)?;
    println!(
        "paper claim: top-3 within 7% of post-SP&R  |  measured worst: {:.1}%",
        worst * 100.0
    );
    Ok(())
}

/// Fig. 12: backend-only DSE of a fixed VTA design on GF12; f_target
/// 0.3-1.3 GHz, util 0.25-0.55; alpha=beta=1.
pub fn fig12_vta(opts: &ExpOptions) -> Result<()> {
    fig12_vta_with(opts, None)
}

/// [`fig12_vta`] with an optional remote oracle (see
/// [`fig11_axiline_svm_with`]).
pub fn fig12_vta_with(opts: &ExpOptions, remote: Option<Arc<dyn RemoteOracle>>) -> Result<()> {
    let enablement = Enablement::Gf12;
    // `--workload` swaps the layer table the VTA search prices; the
    // default stays the paper's MobileNet-v1 binding
    let wl_override = match &opts.workload {
        None => None,
        Some(name) => match workloads::lookup(name)? {
            spec @ WorkloadSpec::Dnn(_) => Some(spec),
            WorkloadSpec::NonDnn(_) => bail!(
                "fig12 explores VTA, a DNN platform; --workload {name} is a non-DNN \
                 training algorithm (pick one of mobilenet, resnet50, transformer, gcn)"
            ),
        },
    };
    let mut cfg = DatagenConfig::small(Platform::Vta, enablement);
    cfg.workload = opts.workload.clone();
    cfg.n_arch = 24;
    cfg.n_backend_train = 60; // backend-only DSE: densify the knob plane
    if opts.quick {
        cfg.n_arch = 8;
        cfg.n_backend_train = 12;
        cfg.n_backend_test = 4;
    }
    if let Some(n) = opts.archs {
        cfg.n_arch = n;
    }
    println!("[fig12] generating VTA/GF12 training data ({} archs)...", cfg.n_arch);
    let store = opts.open_cache()?;
    let mstore = opts.open_model_store()?;
    let mut service = EvalService::new(enablement, cfg.seed)
        .with_workers(crate::util::pool::default_workers())
        .with_coalescing(opts.coalesce)
        .with_cache_store_opt(store.clone())
        .with_model_store_opt(mstore.clone())
        .with_remote_oracle_opt(remote);
    let g = datagen::generate_with(&service, &cfg)?;
    let cached = service.fit_surrogate(&g.dataset, &g.backend_split, opts.seed)?;
    println!(
        "[fig12] surrogate: {}",
        if cached {
            "replayed from model store (0 refits, 0 tuning evals)"
        } else {
            "fitted fresh (1 refit)"
        }
    );
    let driver = DseDriver { service };

    let mut runtimes: Vec<f64> = g.dataset.rows.iter().map(|r| r.runtime_s).collect();
    runtimes.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let r_max = runtimes[runtimes.len() / 2];
    let p_max = g.dataset.rows.iter().map(|r| r.power_w).fold(0.0f64, f64::max);

    // the fixed VTA architecture under backend DSE: mid-grid
    let base = ArchConfig::new(
        Platform::Vta,
        Platform::Vta
            .param_space()
            .iter()
            .map(|s| s.kind.from_unit(0.5))
            .collect(),
    );
    let mut problem = vta_backend_problem(base, p_max, r_max);
    problem.workload = wl_override; // None keeps the default binding

    let iters = opts.iters.unwrap_or(if opts.quick { 100 } else { 300 });
    println!("[fig12] {} x {iters} over (f_target, util)", opts.strategy.name());
    let scfg = MotpeConfig { seed: opts.seed, ..Default::default() };
    let strategy = opts.strategy.build(problem.space(), &scfg);
    let outcome = if opts.coalesce {
        driver.run_pipelined_with(&problem, strategy, iters, 3, 16, opts.inflight)?
    } else {
        driver.run_batched_with(&problem, strategy, iters, 3, 16)?
    };
    println!("[fig12] eval service: {}", driver.stats());
    if let Some(store) = &store {
        store.flush()?;
        println!("[fig12] cache store: {}", store.stats());
    }
    if let Some(ms) = &mstore {
        ms.flush()?;
        println!("[fig12] model store: {}", ms.stats());
    }
    let worst = report(opts, "fig12", &outcome)?;
    println!(
        "paper claim: top-3 within 6% of post-SP&R  |  measured worst: {:.1}%",
        worst * 100.0
    );
    Ok(())
}
