//! Wire protocol for the serve daemon (ISSUE 9): newline-delimited
//! JSON over TCP. One request per line, one response line per request,
//! in order:
//!
//! ```text
//! -> {"id":1,"op":"eval","body":{"platform":"axiline","arch":[...],"f":0.8,"util":0.5}}
//! <- {"body":{"metrics":{...}},"id":1,"ok":true}
//! -> {"id":2,"op":"nope"}
//! <- {"code":404,"error":"unknown op \"nope\"","id":2,"ok":false}
//! ```
//!
//! Responses serialize through `Json` (`BTreeMap` keys + deterministic
//! float formatting), so a fixed request sequence yields byte-identical
//! response bytes — the socket boundary preserves the repo's
//! determinism contract.
//!
//! Request decode rides the PR 7 streaming tokenizer: the envelope
//! (`id`, `op`) is pulled token-by-token and the `body` span is
//! tree-parsed only after the envelope proves well-formed. A torn,
//! oversized, or non-UTF8 line is a *per-connection* [`ProtoError`]
//! (the client gets a `code`/`error` response and the connection keeps
//! serving) — never a daemon panic.

use crate::util::json::{Json, JsonToken, JsonTokenizer};

/// Hard cap on one request line. Oversized lines are rejected with
/// [`CODE_TOO_LARGE`] and drained, keeping the connection usable.
pub const MAX_LINE: usize = 1 << 20;

pub const CODE_BAD_REQUEST: u16 = 400;
pub const CODE_UNKNOWN_OP: u16 = 404;
pub const CODE_TOO_LARGE: u16 = 413;
pub const CODE_QUOTA: u16 = 429;
pub const CODE_INTERNAL: u16 = 500;
pub const CODE_DRAINING: u16 = 503;

/// One decoded request line.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    /// Client-chosen correlation id, echoed on the response (0 when
    /// the line was too damaged to carry one).
    pub id: u64,
    pub op: String,
    /// The `body` value (`Json::Null` when absent).
    pub body: Json,
}

/// A request-level failure, rendered as an error response line. `code`
/// follows HTTP semantics (400 parse, 404 route, 413 size, 429 quota,
/// 500 handler, 503 draining).
#[derive(Debug, Clone, PartialEq)]
pub struct ProtoError {
    pub code: u16,
    pub msg: String,
}

impl ProtoError {
    pub fn bad_request(msg: impl Into<String>) -> ProtoError {
        ProtoError { code: CODE_BAD_REQUEST, msg: msg.into() }
    }

    pub fn internal(msg: impl Into<String>) -> ProtoError {
        ProtoError { code: CODE_INTERNAL, msg: msg.into() }
    }
}

/// Render a success response line (newline included).
pub fn encode_ok(id: u64, body: Json) -> String {
    let mut line = Json::obj(vec![
        ("body", body),
        ("id", Json::from(id as usize)),
        ("ok", Json::from(true)),
    ])
    .to_string();
    line.push('\n');
    line
}

/// Render an error response line (newline included).
pub fn encode_err(id: u64, e: &ProtoError) -> String {
    let mut line = Json::obj(vec![
        ("code", Json::from(e.code as usize)),
        ("error", Json::from(e.msg.as_str())),
        ("id", Json::from(id as usize)),
        ("ok", Json::from(false)),
    ])
    .to_string();
    line.push('\n');
    line
}

/// Decode one request line. Streaming envelope extraction first (the
/// tokenizer rejects torn docs, trailing garbage, and non-UTF8 string
/// bytes without panicking), then a tree parse of just the `body` span.
pub fn decode_request(line: &[u8]) -> Result<Request, ProtoError> {
    let mut t = JsonTokenizer::new(line);
    let proto = |e: &crate::util::json::JsonError| ProtoError::bad_request(format!("{e}"));
    match t.next().map_err(|e| proto(&e))? {
        Some(JsonToken::ObjBegin) => {}
        _ => return Err(ProtoError::bad_request("request line is not a JSON object")),
    }
    let mut id: u64 = 0;
    let mut op: Option<String> = None;
    let mut body_span: Option<(usize, usize)> = None;
    loop {
        match t.next().map_err(|e| proto(&e))? {
            Some(JsonToken::Key(k)) => match k.as_ref() {
                "id" => match t.next().map_err(|e| proto(&e))? {
                    Some(JsonToken::Num(n)) if n.is_finite() && n >= 0.0 => {
                        id = n as u64;
                    }
                    _ => return Err(ProtoError::bad_request("\"id\" must be a number")),
                },
                "op" => match t.next().map_err(|e| proto(&e))? {
                    Some(JsonToken::Str(s)) => op = Some(s.into_owned()),
                    _ => return Err(ProtoError::bad_request("\"op\" must be a string")),
                },
                "body" => {
                    body_span = Some(t.value_span().map_err(|e| proto(&e))?);
                }
                _ => {
                    // unknown envelope field: validate + skip
                    t.value_span().map_err(|e| proto(&e))?;
                }
            },
            Some(JsonToken::ObjEnd) => break,
            _ => return Err(ProtoError::bad_request("torn request object")),
        }
    }
    // trailing-garbage check: a second document on the line is torn
    if t.next().map_err(|e| proto(&e))?.is_some() {
        return Err(ProtoError::bad_request("trailing bytes after request object"));
    }
    let op = op.ok_or_else(|| ProtoError::bad_request("request is missing \"op\""))?;
    let body = match body_span {
        None => Json::Null,
        Some((s, e)) => {
            // the span was tokenizer-validated, so it is valid UTF-8
            // and a well-formed value; the tree parse cannot fail
            let text = std::str::from_utf8(&line[s..e])
                .map_err(|_| ProtoError::bad_request("body is not UTF-8"))?;
            Json::parse(text).map_err(|e| proto(&e))?
        }
    };
    Ok(Request { id, op, body })
}

/// Salvage a correlation id from a line that failed full decode, so
/// the error response still routes to the right in-flight request on a
/// pipelining client. Best-effort: stops at the first readable `id`
/// (the tail may be torn past it); 0 when the id is unreadable.
pub fn salvage_id(line: &[u8]) -> u64 {
    let mut t = JsonTokenizer::new(line);
    if !matches!(t.next(), Ok(Some(JsonToken::ObjBegin))) {
        return 0;
    }
    loop {
        match t.next() {
            Ok(Some(JsonToken::Key(k))) if k.as_ref() == "id" => {
                return match t.next() {
                    Ok(Some(JsonToken::Num(n))) if n.is_finite() && n >= 0.0 => n as u64,
                    _ => 0,
                };
            }
            Ok(Some(JsonToken::Key(_))) => {
                if t.value_span().is_err() {
                    return 0;
                }
            }
            _ => return 0,
        }
    }
}

/// What one poll of a connection's read buffer yielded.
#[derive(Debug, PartialEq)]
pub enum LineEvent {
    /// One complete request line (newline stripped).
    Line(Vec<u8>),
    /// The read timed out — the caller checks the drain flag and polls
    /// again.
    TimedOut,
    /// Peer closed the connection (any unterminated tail bytes are a
    /// torn final request with nobody left to answer — dropped).
    Eof,
    /// The current line exceeded [`MAX_LINE`]; its bytes are being
    /// drained. Reported once per oversized line.
    Oversized,
}

/// Incremental newline framing over a blocking-with-timeout stream.
/// Tolerates torn reads (partial lines buffer until the newline
/// arrives) and bounds memory via [`MAX_LINE`].
pub struct LineReader {
    buf: Vec<u8>,
    /// Draining an oversized line: discard until the next newline.
    skipping: bool,
}

impl Default for LineReader {
    fn default() -> Self {
        LineReader::new()
    }
}

impl LineReader {
    pub fn new() -> LineReader {
        LineReader { buf: Vec::new(), skipping: false }
    }

    /// Pull the next event, reading from `stream` only when the buffer
    /// holds no complete line.
    pub fn poll_line(&mut self, stream: &mut dyn std::io::Read) -> std::io::Result<LineEvent> {
        loop {
            if let Some(ev) = self.event_from_buffer() {
                return Ok(ev);
            }
            let mut chunk = [0u8; 4096];
            match stream.read(&mut chunk) {
                Ok(0) => return Ok(LineEvent::Eof),
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    return Ok(LineEvent::TimedOut)
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
    }

    /// Like [`LineReader::poll_line`] but never touches the socket:
    /// only lines whose bytes already arrived come out. The drain path
    /// uses this so every *acknowledged* (received) request completes
    /// while nothing new is admitted.
    pub fn poll_buffered(&mut self) -> Option<LineEvent> {
        self.event_from_buffer()
    }

    fn event_from_buffer(&mut self) -> Option<LineEvent> {
        loop {
            let nl = self.buf.iter().position(|&b| b == b'\n');
            if self.skipping {
                // still draining an oversized line
                match nl {
                    Some(i) => {
                        self.buf.drain(..=i);
                        self.skipping = false;
                        continue;
                    }
                    None => {
                        self.buf.clear();
                        return None;
                    }
                }
            }
            return match nl {
                Some(i) => {
                    let mut line: Vec<u8> = self.buf.drain(..=i).collect();
                    line.pop(); // newline
                    if line.last() == Some(&b'\r') {
                        line.pop();
                    }
                    if line.len() > MAX_LINE {
                        Some(LineEvent::Oversized)
                    } else if line.is_empty() {
                        continue; // blank keep-alive line
                    } else {
                        Some(LineEvent::Line(line))
                    }
                }
                None if self.buf.len() > MAX_LINE => {
                    self.buf.clear();
                    self.skipping = true;
                    Some(LineEvent::Oversized)
                }
                None => None,
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn well_formed_requests_decode() {
        let r = decode_request(br#"{"id":3,"op":"health"}"#).unwrap();
        assert_eq!(r, Request { id: 3, op: "health".into(), body: Json::Null });
        let r = decode_request(br#"{"body":{"rows":[[1.5,2]]},"id":9,"op":"predict"}"#).unwrap();
        assert_eq!(r.id, 9);
        assert_eq!(r.op, "predict");
        assert_eq!(r.body.get("rows").idx(0).idx(1).as_f64(), Some(2.0));
        // missing id defaults to 0; unknown envelope fields are skipped
        let r = decode_request(br#"{"op":"stats","x":{"deep":[1,{"k":"}"}]}}"#).unwrap();
        assert_eq!((r.id, r.op.as_str()), (0, "stats"));
    }

    #[test]
    fn torn_oversized_and_non_utf8_lines_are_errors_not_panics() {
        // torn tails at every cut of a valid request: always a 400,
        // never a panic (the crash-injection contract of satellite 3)
        let full = br#"{"body":{"rows":[[1.0,2.0]]},"id":7,"op":"predict"}"#;
        for cut in 1..full.len() - 1 {
            let e = decode_request(&full[..cut]).expect_err("torn line must error");
            assert_eq!(e.code, CODE_BAD_REQUEST, "cut {cut}");
        }
        // non-UTF8 bytes inside a string
        let mut bad = full.to_vec();
        let q = bad.iter().position(|&b| b == b'p').unwrap();
        bad[q] = 0xFF;
        assert_eq!(decode_request(&bad).unwrap_err().code, CODE_BAD_REQUEST);
        // structurally foreign lines
        for junk in [&b"null"[..], b"[1,2]", b"{\"op\":7}", b"{} trailing", b"\xF5\x01\x02"] {
            assert!(decode_request(junk).is_err(), "{junk:?} must not decode");
        }
        // the id is still salvaged from a torn line when readable
        assert_eq!(salvage_id(br#"{"id":42,"op":"eval","body":{"#), 42);
        assert_eq!(salvage_id(b"garbage"), 0);
    }

    #[test]
    fn responses_are_deterministic_lines() {
        let ok = encode_ok(5, Json::obj(vec![("z", Json::from(1usize)), ("a", Json::from(2usize))]));
        // sorted keys at both levels, one trailing newline
        assert_eq!(ok, "{\"body\":{\"a\":2,\"z\":1},\"id\":5,\"ok\":true}\n");
        let err = encode_err(2, &ProtoError { code: CODE_QUOTA, msg: "slow down".into() });
        assert_eq!(err, "{\"code\":429,\"error\":\"slow down\",\"id\":2,\"ok\":false}\n");
    }

    #[test]
    fn line_reader_frames_torn_reads_and_bounds_lines() {
        // feed a line in two torn chunks through a scripted reader
        struct Script(Vec<Vec<u8>>);
        impl std::io::Read for Script {
            fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
                if self.0.is_empty() {
                    return Ok(0);
                }
                let mut chunk = self.0.remove(0);
                if chunk.is_empty() {
                    return Err(std::io::ErrorKind::WouldBlock.into());
                }
                let n = chunk.len().min(out.len());
                out[..n].copy_from_slice(&chunk[..n]);
                if n < chunk.len() {
                    chunk.drain(..n);
                    self.0.insert(0, chunk);
                }
                Ok(n)
            }
        }
        let mut r = LineReader::new();
        let mut s = Script(vec![
            b"{\"op\":\"he".to_vec(),
            Vec::new(), // torn: timeout between the halves
            b"alth\"}\r\n{\"op\":\"stats\"}\n".to_vec(),
        ]);
        assert_eq!(r.poll_line(&mut s).unwrap(), LineEvent::TimedOut);
        assert_eq!(
            r.poll_line(&mut s).unwrap(),
            LineEvent::Line(b"{\"op\":\"health\"}".to_vec())
        );
        assert_eq!(
            r.poll_line(&mut s).unwrap(),
            LineEvent::Line(b"{\"op\":\"stats\"}".to_vec())
        );
        assert_eq!(r.poll_line(&mut s).unwrap(), LineEvent::Eof);

        // an oversized line reports once, drains, and the next line
        // still parses (the connection survives)
        let mut r = LineReader::new();
        let mut big = vec![b'x'; MAX_LINE + 10];
        big.push(b'\n');
        big.extend_from_slice(b"{\"op\":\"health\"}\n");
        let mut s = Script(vec![big]);
        assert_eq!(r.poll_line(&mut s).unwrap(), LineEvent::Oversized);
        assert_eq!(
            r.poll_line(&mut s).unwrap(),
            LineEvent::Line(b"{\"op\":\"health\"}".to_vec())
        );
    }
}
