//! Graceful-drain machinery for the serve daemon (ISSUE 9): one
//! process-global drain flag, set by SIGTERM/SIGINT (installed via the
//! C `signal` shim below — std already links libc, no new dependency)
//! or by the `shutdown` op. The accept loop polls the flag and stops
//! accepting; connection threads finish their in-flight requests, then
//! exit at their next read timeout; the daemon joins them and flushes
//! the stores. Both exit paths (signal and `shutdown` op) run the same
//! drain, so the flushed shard bytes are identical either way — the
//! property `tests/serve_daemon.rs` byte-diffs.

use std::sync::atomic::{AtomicBool, Ordering};

static DRAIN: AtomicBool = AtomicBool::new(false);

/// Request a graceful drain (idempotent; also what SIGTERM does).
pub fn request() {
    DRAIN.store(true, Ordering::SeqCst);
}

/// Has a drain been requested?
pub fn requested() -> bool {
    DRAIN.load(Ordering::SeqCst)
}

/// Clear the flag (test support: in-process daemons in unit tests).
pub fn reset() {
    DRAIN.store(false, Ordering::SeqCst);
}

/// The async-signal-safe handler: set the flag, nothing else. The
/// accept/connection loops poll it from ordinary code.
extern "C" fn on_signal(_sig: i32) {
    DRAIN.store(true, Ordering::SeqCst);
}

/// Route SIGTERM and SIGINT into the drain flag. Uses the historical
/// `signal(2)` entry point directly — std links libc already, and the
/// offline build has no `libc` crate to declare it for us.
#[cfg(unix)]
pub fn install_signal_handlers() {
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    let h = on_signal as extern "C" fn(i32) as usize;
    unsafe {
        signal(SIGTERM, h);
        signal(SIGINT, h);
    }
}

#[cfg(not(unix))]
pub fn install_signal_handlers() {
    // non-unix: the `shutdown` op (or process kill) is the only drain
    // trigger; the daemon still drains identically through it
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drain_flag_round_trips() {
        reset();
        assert!(!requested());
        request();
        assert!(requested());
        request(); // idempotent
        assert!(requested());
        reset();
        assert!(!requested());
    }
}
