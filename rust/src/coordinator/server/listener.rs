//! Accept loop and per-connection serving threads for the evaluation
//! daemon (ISSUE 9 tentpole). One thread per connection over a
//! nonblocking accept poll; each connection frames request lines with
//! [`LineReader`], pays one token per request to its
//! [`TokenBucket`], and dispatches through the shared
//! [`ServerState`]. The drain flag (SIGTERM / `shutdown` op) stops the
//! accept loop, lets every connection finish its already-received
//! lines via [`LineReader::poll_buffered`], joins the threads, and
//! flushes the stores — the identical path for both triggers, so the
//! flushed shard bytes cannot depend on *how* the daemon was stopped.

use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::coalesce::EvalRouter;
use crate::coordinator::eval_service::EvalService;
use crate::coordinator::{CacheStore, ModelStore};

use super::fault::{self, ServeFault};
use super::protocol::{
    decode_request, encode_err, encode_ok, salvage_id, LineEvent, LineReader, ProtoError,
    CODE_QUOTA, CODE_TOO_LARGE, MAX_LINE,
};
use super::quota::TokenBucket;
use super::router::{dispatch, ServerState};
use super::{drain, ServeStats};

/// How often idle loops wake to poll the drain flag.
const POLL_MS: u64 = 15;

/// Daemon configuration, filled in by `fso serve --listen`.
pub struct ServeOptions {
    /// `HOST:PORT` to bind; port 0 picks an ephemeral port (the bound
    /// address is printed to stdout as `listening on ADDR`).
    pub listen: String,
    /// Per-connection admission burst; `None` = unlimited.
    pub quota_burst: Option<usize>,
    /// Token refill rate per second. 0 with a finite burst gives the
    /// deterministic "first B admitted, rest rejected" mode.
    pub quota_rate: f64,
    /// Feature width of the attached surrogate (what `predict` rows
    /// must carry; advertised via `health`).
    pub feat_dim: usize,
    /// `FSO_SERVE_TEST_HOOKS=1`: expose the `hook` op to clients.
    pub test_hooks: bool,
}

/// Run the daemon until drained. Returns after all connection threads
/// have exited and the stores (when attached) have flushed.
pub fn run_daemon(
    service: Arc<EvalService>,
    cache: Option<Arc<CacheStore>>,
    models: Option<Arc<ModelStore>>,
    opts: &ServeOptions,
) -> Result<()> {
    drain::reset();
    drain::install_signal_handlers();
    let listener = TcpListener::bind(opts.listen.as_str())
        .with_context(|| format!("binding serve listener on {}", opts.listen))?;
    let local = listener.local_addr()?;
    // the one stdout line: clients (and tests) parse the bound address
    // from it, which is what makes `--listen 127.0.0.1:0` usable
    println!("listening on {local}");
    std::io::stdout().flush().ok();
    listener.set_nonblocking(true)?;

    let stats = Arc::new(ServeStats::default());
    let state = Arc::new(ServerState {
        service: Arc::clone(&service),
        router: Arc::new(EvalRouter::start(Arc::clone(&service))),
        stats: Arc::clone(&stats),
        feat_dim: opts.feat_dim,
        test_hooks: opts.test_hooks,
        fleet: None,
    });
    eprintln!(
        "[serve] up addr={local} seed={} quota_burst={} quota_rate={}",
        service.seed(),
        opts.quota_burst.map_or_else(|| "unlimited".to_string(), |b| b.to_string()),
        opts.quota_rate,
    );

    serve_loop(listener, Arc::clone(&state), opts.quota_burst, opts.quota_rate)?;
    // the router thread quiesces before the stores flush so late
    // coalesced work cannot race the final render
    drop(state);
    if let Some(c) = &cache {
        let n = c.flush().context("flushing cache store at drain")?;
        eprintln!("[serve] drained: cache store flushed {n} record(s)");
    }
    if let Some(m) = &models {
        let n = m.flush().context("flushing model store at drain")?;
        eprintln!("[serve] drained: model store flushed {n} record(s)");
    }
    eprintln!(
        "[serve] down requests_served={} requests_err={} quota_rejects={}",
        stats.requests_ok.load(Ordering::Relaxed),
        stats.requests_err.load(Ordering::Relaxed),
        stats.quota_rejects.load(Ordering::Relaxed),
    );
    Ok(())
}

/// Accept/serve until the drain flag trips, then join every connection
/// thread. Shared by `run_daemon` and the fleet leader
/// ([`crate::coordinator::fleet::run_leader`]), whose listener must
/// behave byte-for-byte like the plain daemon's.
pub(crate) fn serve_loop(
    listener: TcpListener,
    state: Arc<ServerState>,
    quota_burst: Option<usize>,
    quota_rate: f64,
) -> Result<()> {
    let stats = Arc::clone(&state.stats);
    let mut workers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let mut next_conn: u64 = 0;
    while !drain::requested() {
        match listener.accept() {
            Ok((stream, peer)) => {
                next_conn += 1;
                let cid = next_conn;
                stats.connections.fetch_add(1, Ordering::Relaxed);
                let st = Arc::clone(&state);
                let bucket = match quota_burst {
                    Some(b) => TokenBucket::new(b, quota_rate),
                    None => TokenBucket::unlimited(),
                };
                workers.push(std::thread::spawn(move || {
                    serve_connection(stream, peer, cid, st, bucket)
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                reap_finished(&mut workers, &stats);
                std::thread::sleep(Duration::from_millis(POLL_MS));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e).context("accepting serve connection"),
        }
    }

    // drain: stop accepting, let in-flight requests finish
    drop(listener);
    let inflight = workers.len();
    eprintln!("[serve] draining: joining {inflight} connection thread(s)");
    for h in workers {
        join_counting_panics(h, &stats);
    }
    Ok(())
}

/// Join (never just drop) every finished connection handle, so a
/// connection-thread panic is counted instead of vanishing — and so
/// the drain-time `inflight` log counts only live threads. The old
/// `retain(|h| !h.is_finished())` discarded the `JoinHandle` and with
/// it the panic payload.
fn reap_finished(workers: &mut Vec<std::thread::JoinHandle<()>>, stats: &ServeStats) {
    let mut i = 0;
    while i < workers.len() {
        if workers[i].is_finished() {
            join_counting_panics(workers.swap_remove(i), stats);
        } else {
            i += 1;
        }
    }
}

fn join_counting_panics(h: std::thread::JoinHandle<()>, stats: &ServeStats) {
    if h.join().is_err() {
        stats.connection_panics.fetch_add(1, Ordering::Relaxed);
        eprintln!("[serve] connection thread panicked (counted in connection_panics)");
    }
}

/// One response, plus what the request log line needs to say about it.
struct Outcome {
    text: String,
    id: u64,
    op: String,
    ok: bool,
    code: u16,
}

fn serve_connection(
    mut stream: TcpStream,
    peer: SocketAddr,
    cid: u64,
    state: Arc<ServerState>,
    mut bucket: TokenBucket,
) {
    if stream.set_read_timeout(Some(Duration::from_millis(POLL_MS))).is_err() {
        return;
    }
    let mut reader = LineReader::new();
    loop {
        // once draining, serve only bytes that already arrived: every
        // acknowledged request completes, nothing new is admitted
        let ev = if drain::requested() {
            match reader.poll_buffered() {
                Some(ev) => Ok(ev),
                None => break,
            }
        } else {
            reader.poll_line(&mut stream)
        };
        match ev {
            Ok(LineEvent::Line(mut line)) => {
                if fault::trip(ServeFault::PanicConnection) {
                    panic!("injected connection-thread panic (server::fault test hook)");
                }
                if fault::trip(ServeFault::TornRequest) {
                    fault::tear_line(&mut line);
                }
                let t0 = Instant::now();
                let out = respond(&state, &mut bucket, &line);
                let wrote = stream.write_all(out.text.as_bytes()).is_ok();
                let us = t0.elapsed().as_micros();
                eprintln!(
                    "[serve] conn={cid} id={} op={} ok={} code={} bytes={} us={us}{}",
                    out.id,
                    out.op,
                    out.ok,
                    out.code,
                    out.text.len(),
                    if wrote { "" } else { " write=failed" },
                );
                if !wrote {
                    break;
                }
            }
            Ok(LineEvent::Oversized) => {
                state.stats.oversized_lines.fetch_add(1, Ordering::Relaxed);
                state.stats.requests_err.fetch_add(1, Ordering::Relaxed);
                let e = ProtoError {
                    code: CODE_TOO_LARGE,
                    msg: format!("request line exceeds {MAX_LINE} bytes"),
                };
                eprintln!("[serve] conn={cid} oversized line rejected code={CODE_TOO_LARGE}");
                if stream.write_all(encode_err(0, &e).as_bytes()).is_err() {
                    break;
                }
            }
            Ok(LineEvent::TimedOut) => {
                if drain::requested() {
                    // loop once more through poll_buffered to flush
                    // any complete lines framed before the drain tick
                    continue;
                }
            }
            Ok(LineEvent::Eof) | Err(_) => break,
        }
    }
    eprintln!("[serve] conn={cid} peer={peer} closed");
}

/// Admission, decode, dispatch, encode — the per-request pipeline.
/// Infallible by construction: every failure mode is an error
/// *response*, so a bad request can never take down its connection,
/// let alone the daemon.
fn respond(state: &ServerState, bucket: &mut TokenBucket, line: &[u8]) -> Outcome {
    if !bucket.try_take() {
        state.stats.quota_rejects.fetch_add(1, Ordering::Relaxed);
        state.stats.requests_err.fetch_add(1, Ordering::Relaxed);
        let id = salvage_id(line);
        let e = ProtoError {
            code: CODE_QUOTA,
            msg: "per-connection quota exhausted; retry later".to_string(),
        };
        return Outcome { text: encode_err(id, &e), id, op: "?".to_string(), ok: false, code: e.code };
    }
    match decode_request(line) {
        Ok(req) => match dispatch(state, &req) {
            Ok(body) => {
                state.stats.requests_ok.fetch_add(1, Ordering::Relaxed);
                Outcome {
                    text: encode_ok(req.id, body),
                    id: req.id,
                    op: req.op,
                    ok: true,
                    code: 0,
                }
            }
            Err(e) => {
                state.stats.requests_err.fetch_add(1, Ordering::Relaxed);
                Outcome {
                    text: encode_err(req.id, &e),
                    id: req.id,
                    op: req.op,
                    ok: false,
                    code: e.code,
                }
            }
        },
        Err(e) => {
            state.stats.requests_err.fetch_add(1, Ordering::Relaxed);
            let id = salvage_id(line);
            Outcome { text: encode_err(id, &e), id, op: "?".to_string(), ok: false, code: e.code }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Enablement;
    use crate::util::json::Json;

    fn state() -> ServerState {
        let service = Arc::new(EvalService::new(Enablement::Gf12, 11).with_coalescing(true));
        let router = Arc::new(EvalRouter::start(Arc::clone(&service)));
        ServerState {
            service,
            router,
            stats: Arc::new(ServeStats::default()),
            feat_dim: 4,
            test_hooks: false,
            fleet: None,
        }
    }

    #[test]
    fn reap_finished_joins_and_counts_panicking_connection_threads() {
        let stats = ServeStats::default();
        let mut workers = vec![
            std::thread::spawn(|| {}),
            std::thread::spawn(|| panic!("boom")),
            std::thread::spawn(|| std::thread::sleep(Duration::from_millis(400))),
        ];
        // wait for the first two to finish so the reap sees them
        while !(workers[0].is_finished() && workers[1].is_finished()) {
            std::thread::sleep(Duration::from_millis(5));
        }
        reap_finished(&mut workers, &stats);
        assert_eq!(workers.len(), 1, "only the live thread stays tracked");
        assert_eq!(stats.connection_panics.load(Ordering::Relaxed), 1);
        // drain-time joins run through the same panic accounting
        for h in workers {
            join_counting_panics(h, &stats);
        }
        assert_eq!(stats.connection_panics.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn respond_turns_every_failure_into_an_error_line() {
        let st = state();
        let mut bucket = TokenBucket::unlimited();
        // torn line → 400 response carrying the salvaged id
        let out = respond(&st, &mut bucket, br#"{"id":7,"op":"ev"#);
        assert!(!out.ok);
        assert_eq!(out.id, 7);
        assert!(out.text.ends_with('\n'));
        assert!(out.text.contains("\"code\":400"));
        // non-UTF8 junk → 400, id 0
        let out = respond(&st, &mut bucket, &[0xFF, 0xFE, 0x01]);
        assert!(!out.ok);
        assert_eq!(out.id, 0);
        // a healthy request still round-trips through the same path
        let out = respond(&st, &mut bucket, br#"{"id":1,"op":"health"}"#);
        assert!(out.ok);
        assert_eq!(out.code, 0);
        assert_eq!(st.stats.requests_ok.load(Ordering::Relaxed), 1);
        assert_eq!(st.stats.requests_err.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn quota_rejects_are_429_responses_not_hangs() {
        let st = state();
        let mut bucket = TokenBucket::new(2, 0.0);
        let line = br#"{"id":3,"op":"health"}"#;
        assert!(respond(&st, &mut bucket, line).ok);
        assert!(respond(&st, &mut bucket, line).ok);
        let out = respond(&st, &mut bucket, line);
        assert!(!out.ok);
        assert_eq!(out.code, CODE_QUOTA);
        assert_eq!(out.id, 3, "the reject echoes the salvaged request id");
        assert_eq!(st.stats.quota_rejects.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn stats_handler_merges_serve_counters() {
        let st = state();
        let mut bucket = TokenBucket::unlimited();
        respond(&st, &mut bucket, br#"{"id":1,"op":"health"}"#);
        let out = respond(&st, &mut bucket, br#"{"id":2,"op":"stats"}"#);
        assert!(out.ok);
        let doc = Json::parse(out.text.trim()).unwrap();
        let body = doc.get("body");
        assert_eq!(body.get("requests_served").as_usize(), Some(1));
        assert_eq!(body.get("connections").as_usize(), Some(0));
        assert_eq!(body.get("oracle_runs").as_usize(), Some(0));
    }
}
