//! The multi-tenant evaluation daemon behind `fso serve --listen`
//! (ISSUE 9 tentpole): a long-lived process speaking newline-delimited
//! JSON over plain `std::net::TcpListener` — no async runtime, fully
//! offline — that puts the whole coordinator stack (memoized
//! [`EvalService`](crate::coordinator::EvalService), single-flight
//! oracle dedup, the [`EvalRouter`](crate::coordinator::EvalRouter)
//! mega-batching window, DirLock-guarded sharded stores) behind one
//! socket shared by many client processes.
//!
//! Protocol (one JSON document per line, both directions):
//!
//! ```text
//! request:   {"body":{...},"id":N,"op":"predict"}
//! ok:        {"body":{...},"id":N,"ok":true}
//! error:     {"code":429,"error":"...","id":N,"ok":false}
//! ```
//!
//! Module layout:
//! - [`protocol`]: line framing (torn-read tolerant, `MAX_LINE`
//!   bounded), tokenizer-based request decode, deterministic response
//!   encoding, error codes.
//! - [`router`]: the `routes!` op table and typed handlers
//!   (`health` / `stats` / `predict` / `eval` / `shutdown`, plus the
//!   test-gated `hook`).
//! - [`quota`]: per-connection token-bucket admission (reject, never
//!   hang).
//! - [`drain`]: SIGTERM/`shutdown`-op graceful drain — one shared
//!   path, so flushed store bytes are identical either way.
//! - [`fault`]: one-shot torn-request injection for the lifecycle
//!   tests.
//! - [`listener`]: the accept loop and per-connection serving threads.
//!
//! Determinism contract: with a fixed daemon seed, any interleaving of
//! any number of clients yields byte-identical response lines per
//! request and byte-identical flushed shard files, while the
//! single-flight/coalescing counters prove cross-client dedup
//! (`oracle_runs == unique keys`, `coalesced_hits > 0`).

pub mod drain;
pub mod fault;
pub mod listener;
pub mod protocol;
pub mod quota;
pub mod router;

pub use fault::ServeFault;
pub use listener::{run_daemon, ServeOptions};
pub use router::ServerState;

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::util::json::Json;

/// Daemon-level request counters, merged into the `stats` op's
/// response next to the evaluation-stack counters.
#[derive(Default)]
pub struct ServeStats {
    /// Connections accepted over the daemon's lifetime.
    pub connections: AtomicUsize,
    /// Connection threads that ended in a panic. Finished handles are
    /// *joined* (not just dropped) so a panicking connection is
    /// surfaced here instead of vanishing silently.
    pub connection_panics: AtomicUsize,
    /// Requests answered `ok:true`.
    pub requests_ok: AtomicUsize,
    /// Requests answered `ok:false` (any error code).
    pub requests_err: AtomicUsize,
    /// Requests rejected with code 429 by a connection's token bucket.
    pub quota_rejects: AtomicUsize,
    /// Request lines dropped for exceeding [`protocol::MAX_LINE`].
    pub oversized_lines: AtomicUsize,
}

impl ServeStats {
    /// Stable-keyed entries for the `stats` response (sorted into the
    /// response object's BTreeMap, so byte-deterministic).
    pub fn to_entries(&self) -> Vec<(&'static str, Json)> {
        vec![
            ("connection_panics", Json::from(self.connection_panics.load(Ordering::Relaxed))),
            ("connections", Json::from(self.connections.load(Ordering::Relaxed))),
            ("oversized_lines", Json::from(self.oversized_lines.load(Ordering::Relaxed))),
            ("quota_rejects", Json::from(self.quota_rejects.load(Ordering::Relaxed))),
            ("requests_err", Json::from(self.requests_err.load(Ordering::Relaxed))),
            ("requests_served", Json::from(self.requests_ok.load(Ordering::Relaxed))),
        ]
    }
}
