//! Per-client admission control for the serve daemon (ISSUE 9): a
//! token bucket per connection. Every request costs one token; an
//! empty bucket is an immediate [`super::protocol::CODE_QUOTA`]
//! reject — never a hang or a queued stall, so one greedy client
//! cannot wedge the accept loop or starve its own pipelined peers.
//!
//! Determinism: with `rate_per_sec == 0` the bucket never refills, so
//! "burst B, then send R > B requests" rejects exactly the last
//! `R - B` — the mode the daemon tests pin. A positive rate refills
//! continuously on wall-clock time (throughput shaping, inherently
//! timing-dependent).

use std::time::Instant;

/// Token bucket: starts full at `burst`, refills at `rate_per_sec` up
/// to `burst`.
pub struct TokenBucket {
    burst: f64,
    rate_per_sec: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    pub fn new(burst: usize, rate_per_sec: f64) -> TokenBucket {
        TokenBucket {
            burst: burst as f64,
            rate_per_sec: rate_per_sec.max(0.0),
            tokens: burst as f64,
            last: Instant::now(),
        }
    }

    /// Unlimited admission (the default daemon configuration).
    pub fn unlimited() -> TokenBucket {
        TokenBucket::new(usize::MAX >> 12, 0.0)
    }

    /// Take one token if available. `false` = reject this request now.
    pub fn try_take(&mut self) -> bool {
        if self.rate_per_sec > 0.0 {
            let now = Instant::now();
            let dt = now.duration_since(self.last).as_secs_f64();
            self.last = now;
            self.tokens = (self.tokens + dt * self.rate_per_sec).min(self.burst);
        }
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::TokenBucket;

    #[test]
    fn zero_rate_bucket_rejects_deterministically() {
        let mut b = TokenBucket::new(5, 0.0);
        let admitted: Vec<bool> = (0..8).map(|_| b.try_take()).collect();
        // exactly the first 5 admitted, the last 3 rejected — no
        // timing dependence at rate 0
        assert_eq!(admitted, [true, true, true, true, true, false, false, false]);
    }

    #[test]
    fn zero_burst_with_positive_rate_blackholes_every_request() {
        // degenerate config (ISSUE 10 satellite): refill is capped at
        // `burst`, so `burst = 0` with any positive rate admits
        // *nothing*, ever — the daemon would answer only 429s. The CLI
        // rejects `--quota-burst 0` with a positive rate up front
        // (`fso serve`); this pins the behavior that makes it wrong.
        let mut b = TokenBucket::new(0, 1e9);
        std::thread::sleep(std::time::Duration::from_millis(2));
        for _ in 0..3 {
            assert!(!b.try_take(), "burst 0 blackholes regardless of refill rate");
        }
    }

    #[test]
    fn refill_restores_admission_and_caps_at_burst() {
        let mut b = TokenBucket::new(2, 1e9); // effectively instant refill
        for _ in 0..50 {
            assert!(b.try_take(), "a refilling bucket readmits");
        }
        let mut b = TokenBucket::unlimited();
        for _ in 0..10_000 {
            assert!(b.try_take(), "the unlimited bucket never rejects");
        }
    }
}
