//! Typed request routing for the serve daemon (ISSUE 9): the
//! `routes!` table maps op names onto handler functions (the mik-sdk
//! handler-table pattern), and the extractor helpers pull typed fields
//! out of request bodies with field-named 400s instead of panics or
//! silent defaults.
//!
//! Every handler is a pure function of `(state, body)` → `Json`, so
//! responses inherit the determinism of the underlying service: fixed
//! seed + fixed request ⇒ byte-identical response line, no matter
//! which client or connection issued it.

use std::sync::Arc;

use crate::backend::BackendConfig;
use crate::coordinator::coalesce::{self, EvalRouter};
use crate::coordinator::eval_service::EvalService;
use crate::generators::{ArchConfig, Platform};
use crate::util::json::Json;
use crate::workloads::{self, WorkloadSpec};

use super::fault::{self, ServeFault};
use super::protocol::{ProtoError, Request, CODE_UNKNOWN_OP};
use super::{drain, ServeStats};

/// Shared daemon state, one per process, `Arc`-cloned into every
/// connection thread.
pub struct ServerState {
    pub service: Arc<EvalService>,
    pub router: Arc<EvalRouter>,
    pub stats: Arc<ServeStats>,
    /// Feature width the surrogate was fit on; `predict` rows of any
    /// other length are a 400 (tree inference indexes features by
    /// position and must never see a short row). Advertised by
    /// `health` so clients can size their rows.
    pub feat_dim: usize,
    /// `FSO_SERVE_TEST_HOOKS=1`: expose the `hook` op (barrier/fault
    /// arming for the lifecycle tests). Off in any real deployment.
    pub test_hooks: bool,
}

/// Route table: `(op name, handler)` pairs compile into the dispatch
/// match plus the introspectable [`OPS`] list `health` reports.
macro_rules! routes {
    ($(($op:literal, $handler:path)),* $(,)?) => {
        /// Every routable op name, in route-table order.
        pub const OPS: &[&str] = &[$($op),*];

        /// Dispatch one decoded request to its handler.
        pub fn dispatch(state: &ServerState, req: &Request) -> Result<Json, ProtoError> {
            match req.op.as_str() {
                $($op => $handler(state, &req.body),)*
                other => Err(ProtoError {
                    code: CODE_UNKNOWN_OP,
                    msg: format!("unknown op {other:?} (have: {})", OPS.join(", ")),
                }),
            }
        }
    };
}

routes![
    ("health", h_health),
    ("stats", h_stats),
    ("predict", h_predict),
    ("eval", h_eval),
    ("shutdown", h_shutdown),
    ("hook", h_hook),
];

// ---- typed body extractors -----------------------------------------

fn want_str<'a>(body: &'a Json, key: &str) -> Result<&'a str, ProtoError> {
    body.get(key)
        .as_str()
        .ok_or_else(|| ProtoError::bad_request(format!("\"{key}\" must be a string")))
}

fn want_f64(body: &Json, key: &str) -> Result<f64, ProtoError> {
    body.get(key)
        .as_f64()
        .ok_or_else(|| ProtoError::bad_request(format!("\"{key}\" must be a number")))
}

fn want_f64_arr(body: &Json, key: &str) -> Result<Vec<f64>, ProtoError> {
    let arr = body
        .get(key)
        .as_arr()
        .ok_or_else(|| ProtoError::bad_request(format!("\"{key}\" must be an array")))?;
    arr.iter()
        .map(|v| {
            v.as_f64()
                .ok_or_else(|| ProtoError::bad_request(format!("\"{key}\" must hold only numbers")))
        })
        .collect()
}

fn want_rows(body: &Json, key: &str) -> Result<Vec<Vec<f64>>, ProtoError> {
    let arr = body
        .get(key)
        .as_arr()
        .ok_or_else(|| ProtoError::bad_request(format!("\"{key}\" must be an array of rows")))?;
    arr.iter()
        .map(|row| {
            row.as_arr()
                .ok_or_else(|| {
                    ProtoError::bad_request(format!("\"{key}\" rows must be number arrays"))
                })?
                .iter()
                .map(|v| {
                    v.as_f64().ok_or_else(|| {
                        ProtoError::bad_request(format!("\"{key}\" rows must hold only numbers"))
                    })
                })
                .collect()
        })
        .collect()
}

// ---- handlers ------------------------------------------------------

fn h_health(state: &ServerState, _body: &Json) -> Result<Json, ProtoError> {
    let ops: Vec<String> = OPS.iter().map(|s| s.to_string()).collect();
    Ok(Json::obj(vec![
        ("feat_dim", Json::from(state.feat_dim)),
        ("ops", Json::arr_str(&ops)),
        ("seed", Json::from(state.service.seed() as usize)),
        ("status", Json::from("ok")),
    ]))
}

fn h_stats(state: &ServerState, _body: &Json) -> Result<Json, ProtoError> {
    let mut j = state.service.stats().to_json();
    if let Json::Obj(o) = &mut j {
        for (k, v) in state.stats.to_entries() {
            o.insert(k.to_string(), v);
        }
    }
    Ok(j)
}

/// `{"rows": [[f64; FEAT_DIM], ...]}` → surrogate scores through the
/// shared cross-client mega-batching router.
fn h_predict(state: &ServerState, body: &Json) -> Result<Json, ProtoError> {
    let rows = want_rows(body, "rows")?;
    if let Some(bad) = rows.iter().find(|r| r.len() != state.feat_dim) {
        return Err(ProtoError::bad_request(format!(
            "\"rows\" entries must carry {} features, got {}",
            state.feat_dim,
            bad.len()
        )));
    }
    let points = state
        .router
        .client()
        .predict(rows)
        .map_err(|e| ProtoError::internal(format!("{e:#}")))?;
    let points: Vec<Json> = points
        .into_iter()
        .map(|p| {
            let predicted: Vec<(&str, Json)> =
                p.predicted.iter().map(|(m, v)| (m.name(), Json::from(*v))).collect();
            Json::obj(vec![
                ("in_roi", Json::from(p.in_roi)),
                ("predicted", Json::obj(predicted)),
            ])
        })
        .collect();
    Ok(Json::obj(vec![("points", Json::Arr(points))]))
}

/// `{"platform": "axiline", "arch": [..], "f": GHz, "util": frac,
/// "workload"?: name, "trial"?: n}` → ground-truth evaluation through
/// the full memo/coalesce/store stack.
fn h_eval(state: &ServerState, body: &Json) -> Result<Json, ProtoError> {
    let platform = Platform::from_name(want_str(body, "platform")?)
        .map_err(|e| ProtoError::bad_request(format!("{e:#}")))?;
    let arch = ArchConfig::new(platform, want_f64_arr(body, "arch")?);
    arch.validate().map_err(|e| ProtoError::bad_request(format!("{e:#}")))?;
    let bcfg = BackendConfig::new(want_f64(body, "f")?, want_f64(body, "util")?);
    let wl: Option<WorkloadSpec> = match body.get("workload") {
        Json::Null => None,
        j => {
            let name = j
                .as_str()
                .ok_or_else(|| ProtoError::bad_request("\"workload\" must be a string"))?;
            Some(
                workloads::lookup(name)
                    .map_err(|e| ProtoError::bad_request(format!("{e:#}")))?,
            )
        }
    };
    let trial = match body.get("trial") {
        Json::Null => 0,
        j => j
            .as_f64()
            .filter(|n| n.is_finite() && *n >= 0.0)
            .ok_or_else(|| ProtoError::bad_request("\"trial\" must be a non-negative number"))?
            as u64,
    };
    let ev = state
        .service
        .evaluate_trial(&arch, bcfg, wl.as_ref(), trial)
        .map_err(|e| ProtoError::internal(format!("{e:#}")))?;
    let metrics: Vec<(&str, Json)> =
        ev.metrics().iter().map(|(m, v)| (m.name(), Json::from(*v))).collect();
    Ok(Json::obj(vec![
        ("arch_id", Json::from(crate::coordinator::store::hex_key(arch.id_hash()).as_str())),
        ("metrics", Json::obj(metrics)),
    ]))
}

/// Begin a graceful drain, exactly as SIGTERM does: the response is
/// written, in-flight requests on other connections complete, the
/// listener stops accepting, and the stores flush before exit.
fn h_shutdown(_state: &ServerState, _body: &Json) -> Result<Json, ProtoError> {
    drain::request();
    Ok(Json::obj(vec![("draining", Json::from(true))]))
}

/// Test-only (`FSO_SERVE_TEST_HOOKS=1`): arm the process-global
/// interleaving/fault hooks from a test client, so the lifecycle tests
/// can force exact coalescing windows and torn-request reads inside
/// the daemon process.
fn h_hook(state: &ServerState, body: &Json) -> Result<Json, ProtoError> {
    if !state.test_hooks {
        return Err(ProtoError {
            code: CODE_UNKNOWN_OP,
            msg: "unknown op \"hook\" (test hooks are not enabled)".to_string(),
        });
    }
    let kind = want_str(body, "kind")?;
    match kind {
        "leader_barrier" => {
            let n = want_f64(body, "n")? as usize;
            coalesce::hook::arm_leader_barrier(n);
        }
        "router_barrier" => {
            let n = want_f64(body, "n")? as usize;
            coalesce::hook::arm_router_barrier(n);
        }
        "torn_request" => fault::arm(ServeFault::TornRequest),
        "disarm" => {
            coalesce::hook::disarm();
            fault::disarm();
        }
        other => {
            return Err(ProtoError::bad_request(format!(
                "unknown hook kind {other:?} (leader_barrier|router_barrier|torn_request|disarm)"
            )))
        }
    }
    Ok(Json::obj(vec![("armed", Json::from(kind))]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Enablement;
    use crate::coordinator::server::protocol::{CODE_BAD_REQUEST, CODE_INTERNAL};

    fn state() -> ServerState {
        let service = Arc::new(EvalService::new(Enablement::Gf12, 2023).with_coalescing(true));
        let router = Arc::new(EvalRouter::start(Arc::clone(&service)));
        ServerState {
            service,
            router,
            stats: Arc::new(ServeStats::default()),
            feat_dim: 4,
            test_hooks: false,
        }
    }

    fn req(op: &str, body: Json) -> Request {
        Request { id: 1, op: op.to_string(), body }
    }

    #[test]
    fn health_stats_and_unknown_ops_route() {
        let st = state();
        let h = dispatch(&st, &req("health", Json::Null)).unwrap();
        assert_eq!(h.get("status").as_str(), Some("ok"));
        assert_eq!(h.get("ops").as_arr().unwrap().len(), OPS.len());
        let s = dispatch(&st, &req("stats", Json::Null)).unwrap();
        assert_eq!(s.get("oracle_runs").as_usize(), Some(0));
        assert_eq!(s.get("requests_served").as_usize(), Some(0));
        let e = dispatch(&st, &req("bogus", Json::Null)).unwrap_err();
        assert_eq!(e.code, CODE_UNKNOWN_OP);
        // the hook op is routable only under FSO_SERVE_TEST_HOOKS
        let e = dispatch(&st, &req("hook", Json::obj(vec![("kind", Json::from("disarm"))])))
            .unwrap_err();
        assert_eq!(e.code, CODE_UNKNOWN_OP);
    }

    #[test]
    fn eval_round_trips_and_matches_local_service() {
        let st = state();
        let space = Platform::Axiline.param_space();
        let values: Vec<f64> = space.iter().map(|p| p.kind.from_unit(0.4)).collect();
        let body = Json::obj(vec![
            ("platform", Json::from("axiline")),
            ("arch", Json::arr_f64(&values)),
            ("f", Json::from(0.8)),
            ("util", Json::from(0.5)),
        ]);
        let out = dispatch(&st, &req("eval", body)).unwrap();
        // byte-determinism root: the daemon's numbers are the local
        // service's numbers, bit for bit
        let arch = ArchConfig::new(Platform::Axiline, values);
        let local = st
            .service
            .evaluate(&arch, BackendConfig::new(0.8, 0.5), None)
            .unwrap();
        for (m, v) in local.metrics() {
            assert_eq!(out.get("metrics").get(m.name()).as_f64(), Some(v), "{}", m.name());
        }

        // typed extraction failures are field-named 400s
        for bad in [
            Json::obj(vec![("platform", Json::from("axiline"))]),
            Json::obj(vec![
                ("platform", Json::from("nope")),
                ("arch", Json::arr_f64(&[1.0])),
                ("f", Json::from(0.8)),
                ("util", Json::from(0.5)),
            ]),
            Json::obj(vec![
                ("platform", Json::from("axiline")),
                ("arch", Json::arr_f64(&[1.0])), // wrong length
                ("f", Json::from(0.8)),
                ("util", Json::from(0.5)),
            ]),
        ] {
            let e = dispatch(&st, &req("eval", bad)).unwrap_err();
            assert_eq!(e.code, CODE_BAD_REQUEST);
        }
    }

    #[test]
    fn predict_without_surrogate_is_a_handler_error_not_a_panic() {
        let st = state();
        let body = Json::obj(vec![("rows", Json::Arr(vec![Json::arr_f64(&[0.0; 4])]))]);
        let e = dispatch(&st, &req("predict", body)).unwrap_err();
        assert_eq!(e.code, CODE_INTERNAL);
        let e = dispatch(&st, &req("predict", Json::obj(vec![("rows", Json::from(3.0))])))
            .unwrap_err();
        assert_eq!(e.code, CODE_BAD_REQUEST);
        // wrong feature width is a 400 at the edge, not an index panic
        // deep inside tree inference
        let body = Json::obj(vec![("rows", Json::Arr(vec![Json::arr_f64(&[0.0; 3])]))]);
        let e = dispatch(&st, &req("predict", body)).unwrap_err();
        assert_eq!(e.code, CODE_BAD_REQUEST);
    }
}
