//! Typed request routing for the serve daemon (ISSUE 9): the
//! `routes!` table maps op names onto handler functions (the mik-sdk
//! handler-table pattern), and the extractor helpers pull typed fields
//! out of request bodies with field-named 400s instead of panics or
//! silent defaults.
//!
//! Every handler is a pure function of `(state, body)` → `Json`, so
//! responses inherit the determinism of the underlying service: fixed
//! seed + fixed request ⇒ byte-identical response line, no matter
//! which client or connection issued it.

use std::sync::Arc;

use crate::backend::BackendConfig;
use crate::coordinator::coalesce::{self, EvalRouter};
use crate::coordinator::eval_service::EvalService;
use crate::coordinator::fleet::{self, FleetQueue};
use crate::coordinator::store::parse_hex_key;
use crate::generators::{ArchConfig, Platform};
use crate::util::json::Json;
use crate::workloads::{self, WorkloadSpec};

use super::fault::{self, ServeFault};
use super::protocol::{ProtoError, Request, CODE_UNKNOWN_OP};
use super::{drain, ServeStats};

/// Shared daemon state, one per process, `Arc`-cloned into every
/// connection thread.
pub struct ServerState {
    pub service: Arc<EvalService>,
    pub router: Arc<EvalRouter>,
    pub stats: Arc<ServeStats>,
    /// Feature width the surrogate was fit on; `predict` rows of any
    /// other length are a 400 (tree inference indexes features by
    /// position and must never see a short row). Advertised by
    /// `health` so clients can size their rows.
    pub feat_dim: usize,
    /// `FSO_SERVE_TEST_HOOKS=1`: expose the `hook` op (barrier/fault
    /// arming for the lifecycle tests). Off in any real deployment.
    pub test_hooks: bool,
    /// Present when this daemon is a fleet leader (`fso fleet lead`):
    /// the shared task queue behind the `claim`/`result`/`heartbeat`
    /// ops. `None` in a plain `fso serve` daemon, where those ops
    /// answer 404.
    pub fleet: Option<Arc<FleetQueue>>,
}

/// Route table: `(op name, handler)` pairs compile into the dispatch
/// match plus the introspectable [`OPS`] list `health` reports.
macro_rules! routes {
    ($(($op:literal, $handler:path)),* $(,)?) => {
        /// Every routable op name, in route-table order.
        pub const OPS: &[&str] = &[$($op),*];

        /// Dispatch one decoded request to its handler.
        pub fn dispatch(state: &ServerState, req: &Request) -> Result<Json, ProtoError> {
            match req.op.as_str() {
                $($op => $handler(state, &req.body),)*
                other => Err(ProtoError {
                    code: CODE_UNKNOWN_OP,
                    msg: format!("unknown op {other:?} (have: {})", OPS.join(", ")),
                }),
            }
        }
    };
}

routes![
    ("health", h_health),
    ("stats", h_stats),
    ("predict", h_predict),
    ("eval", h_eval),
    ("claim", h_claim),
    ("result", h_result),
    ("heartbeat", h_heartbeat),
    ("shutdown", h_shutdown),
    ("hook", h_hook),
];

// ---- typed body extractors -----------------------------------------

fn want_str<'a>(body: &'a Json, key: &str) -> Result<&'a str, ProtoError> {
    body.get(key)
        .as_str()
        .ok_or_else(|| ProtoError::bad_request(format!("\"{key}\" must be a string")))
}

fn want_f64(body: &Json, key: &str) -> Result<f64, ProtoError> {
    body.get(key)
        .as_f64()
        .ok_or_else(|| ProtoError::bad_request(format!("\"{key}\" must be a number")))
}

fn want_f64_arr(body: &Json, key: &str) -> Result<Vec<f64>, ProtoError> {
    let arr = body
        .get(key)
        .as_arr()
        .ok_or_else(|| ProtoError::bad_request(format!("\"{key}\" must be an array")))?;
    arr.iter()
        .map(|v| {
            v.as_f64()
                .ok_or_else(|| ProtoError::bad_request(format!("\"{key}\" must hold only numbers")))
        })
        .collect()
}

fn want_rows(body: &Json, key: &str) -> Result<Vec<Vec<f64>>, ProtoError> {
    let arr = body
        .get(key)
        .as_arr()
        .ok_or_else(|| ProtoError::bad_request(format!("\"{key}\" must be an array of rows")))?;
    arr.iter()
        .map(|row| {
            row.as_arr()
                .ok_or_else(|| {
                    ProtoError::bad_request(format!("\"{key}\" rows must be number arrays"))
                })?
                .iter()
                .map(|v| {
                    v.as_f64().ok_or_else(|| {
                        ProtoError::bad_request(format!("\"{key}\" rows must hold only numbers"))
                    })
                })
                .collect()
        })
        .collect()
}

// ---- handlers ------------------------------------------------------

fn h_health(state: &ServerState, _body: &Json) -> Result<Json, ProtoError> {
    let ops: Vec<String> = OPS.iter().map(|s| s.to_string()).collect();
    Ok(Json::obj(vec![
        ("feat_dim", Json::from(state.feat_dim)),
        ("ops", Json::arr_str(&ops)),
        ("seed", Json::from(state.service.seed() as usize)),
        ("status", Json::from("ok")),
    ]))
}

fn h_stats(state: &ServerState, _body: &Json) -> Result<Json, ProtoError> {
    let mut j = state.service.stats().to_json();
    if let Json::Obj(o) = &mut j {
        for (k, v) in state.stats.to_entries() {
            o.insert(k.to_string(), v);
        }
    }
    Ok(j)
}

/// `{"rows": [[f64; FEAT_DIM], ...]}` → surrogate scores through the
/// shared cross-client mega-batching router.
fn h_predict(state: &ServerState, body: &Json) -> Result<Json, ProtoError> {
    let rows = want_rows(body, "rows")?;
    if let Some(bad) = rows.iter().find(|r| r.len() != state.feat_dim) {
        return Err(ProtoError::bad_request(format!(
            "\"rows\" entries must carry {} features, got {}",
            state.feat_dim,
            bad.len()
        )));
    }
    let points = state
        .router
        .client()
        .predict(rows)
        .map_err(|e| ProtoError::internal(format!("{e:#}")))?;
    let points: Vec<Json> = points
        .into_iter()
        .map(|p| {
            let predicted: Vec<(&str, Json)> =
                p.predicted.iter().map(|(m, v)| (m.name(), Json::from(*v))).collect();
            Json::obj(vec![
                ("in_roi", Json::from(p.in_roi)),
                ("predicted", Json::obj(predicted)),
            ])
        })
        .collect();
    Ok(Json::obj(vec![("points", Json::Arr(points))]))
}

/// `{"platform": "axiline", "arch": [..], "f": GHz, "util": frac,
/// "workload"?: name, "trial"?: n}` → ground-truth evaluation through
/// the full memo/coalesce/store stack.
fn h_eval(state: &ServerState, body: &Json) -> Result<Json, ProtoError> {
    let platform = Platform::from_name(want_str(body, "platform")?)
        .map_err(|e| ProtoError::bad_request(format!("{e:#}")))?;
    let arch = ArchConfig::new(platform, want_f64_arr(body, "arch")?);
    arch.validate().map_err(|e| ProtoError::bad_request(format!("{e:#}")))?;
    let bcfg = BackendConfig::new(want_f64(body, "f")?, want_f64(body, "util")?);
    let wl: Option<WorkloadSpec> = match body.get("workload") {
        Json::Null => None,
        j => {
            let name = j
                .as_str()
                .ok_or_else(|| ProtoError::bad_request("\"workload\" must be a string"))?;
            Some(
                workloads::lookup(name)
                    .map_err(|e| ProtoError::bad_request(format!("{e:#}")))?,
            )
        }
    };
    let trial = match body.get("trial") {
        Json::Null => 0,
        j => j
            .as_f64()
            .filter(|n| n.is_finite() && *n >= 0.0)
            .ok_or_else(|| ProtoError::bad_request("\"trial\" must be a non-negative number"))?
            as u64,
    };
    let ev = state
        .service
        .evaluate_trial(&arch, bcfg, wl.as_ref(), trial)
        .map_err(|e| ProtoError::internal(format!("{e:#}")))?;
    let metrics: Vec<(&str, Json)> =
        ev.metrics().iter().map(|(m, v)| (m.name(), Json::from(*v))).collect();
    Ok(Json::obj(vec![
        ("arch_id", Json::from(crate::coordinator::store::hex_key(arch.id_hash()).as_str())),
        ("metrics", Json::obj(metrics)),
    ]))
}

// ---- fleet ops (ISSUE 10): leader side of the claim/lease protocol --

fn want_fleet(state: &ServerState) -> Result<&Arc<FleetQueue>, ProtoError> {
    state.fleet.as_ref().ok_or_else(|| ProtoError {
        code: CODE_UNKNOWN_OP,
        msg: "this daemon is not a fleet leader (start one with `fso fleet lead`)".to_string(),
    })
}

fn want_worker_id(body: &Json) -> Result<u64, ProtoError> {
    Ok(want_f64(body, "worker")?.max(0.0) as u64)
}

/// `{"worker": id}` → `{"drain": bool, "lease_ms": n, "task": spec|null}`.
/// A dry queue answers `task: null` (the worker sleeps and re-polls);
/// `drain: true` tells the worker to exit cleanly.
fn h_claim(state: &ServerState, body: &Json) -> Result<Json, ProtoError> {
    let q = want_fleet(state)?;
    let worker = want_worker_id(body)?;
    let draining = q.draining();
    let task = if draining { None } else { q.claim(worker) };
    Ok(Json::obj(vec![
        ("drain", Json::from(draining)),
        ("lease_ms", Json::from(q.lease_ms() as usize)),
        ("task", task.map_or(Json::Null, |t| t.to_json())),
    ]))
}

/// `{"key": hex, "eval": {...}}` on success, `{"key": hex, "error":
/// msg}` on worker-side failure. First result per key wins; a late
/// duplicate answers `recorded: false`.
fn h_result(state: &ServerState, body: &Json) -> Result<Json, ProtoError> {
    let q = want_fleet(state)?;
    let key = want_str(body, "key")?;
    let key = parse_hex_key(key)
        .ok_or_else(|| ProtoError::bad_request("\"key\" must be a hex task key"))?;
    let result = match body.get("error") {
        Json::Null => Ok(fleet::eval_from_wire(body.get("eval"))
            .map_err(|e| ProtoError::bad_request(format!("{e:#}")))?),
        e => Err(e.as_str().unwrap_or("unknown worker error").to_string()),
    };
    Ok(Json::obj(vec![("recorded", Json::from(q.complete(key, result)))]))
}

/// `{"worker": id}` → `{"renewed": n}`: push every lease the worker
/// holds out by one lease window.
fn h_heartbeat(state: &ServerState, body: &Json) -> Result<Json, ProtoError> {
    let q = want_fleet(state)?;
    let worker = want_worker_id(body)?;
    Ok(Json::obj(vec![("renewed", Json::from(q.heartbeat(worker)))]))
}

/// Begin a graceful drain, exactly as SIGTERM does: the response is
/// written, in-flight requests on other connections complete, the
/// listener stops accepting, and the stores flush before exit.
fn h_shutdown(_state: &ServerState, _body: &Json) -> Result<Json, ProtoError> {
    drain::request();
    Ok(Json::obj(vec![("draining", Json::from(true))]))
}

/// Test-only (`FSO_SERVE_TEST_HOOKS=1`): arm the process-global
/// interleaving/fault hooks from a test client, so the lifecycle tests
/// can force exact coalescing windows and torn-request reads inside
/// the daemon process.
fn h_hook(state: &ServerState, body: &Json) -> Result<Json, ProtoError> {
    if !state.test_hooks {
        return Err(ProtoError {
            code: CODE_UNKNOWN_OP,
            msg: "unknown op \"hook\" (test hooks are not enabled)".to_string(),
        });
    }
    let kind = want_str(body, "kind")?;
    match kind {
        "leader_barrier" => {
            let n = want_f64(body, "n")? as usize;
            coalesce::hook::arm_leader_barrier(n);
        }
        "router_barrier" => {
            let n = want_f64(body, "n")? as usize;
            coalesce::hook::arm_router_barrier(n);
        }
        "torn_request" => fault::arm(ServeFault::TornRequest),
        "panic_connection" => fault::arm(ServeFault::PanicConnection),
        "disarm" => {
            coalesce::hook::disarm();
            fault::disarm();
        }
        other => {
            return Err(ProtoError::bad_request(format!(
                "unknown hook kind {other:?} \
                 (leader_barrier|router_barrier|torn_request|panic_connection|disarm)"
            )))
        }
    }
    Ok(Json::obj(vec![("armed", Json::from(kind))]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Enablement;
    use crate::coordinator::server::protocol::{CODE_BAD_REQUEST, CODE_INTERNAL};

    fn state() -> ServerState {
        let service = Arc::new(EvalService::new(Enablement::Gf12, 2023).with_coalescing(true));
        let router = Arc::new(EvalRouter::start(Arc::clone(&service)));
        ServerState {
            service,
            router,
            stats: Arc::new(ServeStats::default()),
            feat_dim: 4,
            test_hooks: false,
            fleet: None,
        }
    }

    fn req(op: &str, body: Json) -> Request {
        Request { id: 1, op: op.to_string(), body }
    }

    #[test]
    fn health_stats_and_unknown_ops_route() {
        let st = state();
        let h = dispatch(&st, &req("health", Json::Null)).unwrap();
        assert_eq!(h.get("status").as_str(), Some("ok"));
        assert_eq!(h.get("ops").as_arr().unwrap().len(), OPS.len());
        let s = dispatch(&st, &req("stats", Json::Null)).unwrap();
        assert_eq!(s.get("oracle_runs").as_usize(), Some(0));
        assert_eq!(s.get("requests_served").as_usize(), Some(0));
        let e = dispatch(&st, &req("bogus", Json::Null)).unwrap_err();
        assert_eq!(e.code, CODE_UNKNOWN_OP);
        // the hook op is routable only under FSO_SERVE_TEST_HOOKS
        let e = dispatch(&st, &req("hook", Json::obj(vec![("kind", Json::from("disarm"))])))
            .unwrap_err();
        assert_eq!(e.code, CODE_UNKNOWN_OP);
    }

    #[test]
    fn eval_round_trips_and_matches_local_service() {
        let st = state();
        let space = Platform::Axiline.param_space();
        let values: Vec<f64> = space.iter().map(|p| p.kind.from_unit(0.4)).collect();
        let body = Json::obj(vec![
            ("platform", Json::from("axiline")),
            ("arch", Json::arr_f64(&values)),
            ("f", Json::from(0.8)),
            ("util", Json::from(0.5)),
        ]);
        let out = dispatch(&st, &req("eval", body)).unwrap();
        // byte-determinism root: the daemon's numbers are the local
        // service's numbers, bit for bit
        let arch = ArchConfig::new(Platform::Axiline, values);
        let local = st
            .service
            .evaluate(&arch, BackendConfig::new(0.8, 0.5), None)
            .unwrap();
        for (m, v) in local.metrics() {
            assert_eq!(out.get("metrics").get(m.name()).as_f64(), Some(v), "{}", m.name());
        }

        // typed extraction failures are field-named 400s
        for bad in [
            Json::obj(vec![("platform", Json::from("axiline"))]),
            Json::obj(vec![
                ("platform", Json::from("nope")),
                ("arch", Json::arr_f64(&[1.0])),
                ("f", Json::from(0.8)),
                ("util", Json::from(0.5)),
            ]),
            Json::obj(vec![
                ("platform", Json::from("axiline")),
                ("arch", Json::arr_f64(&[1.0])), // wrong length
                ("f", Json::from(0.8)),
                ("util", Json::from(0.5)),
            ]),
        ] {
            let e = dispatch(&st, &req("eval", bad)).unwrap_err();
            assert_eq!(e.code, CODE_BAD_REQUEST);
        }
    }

    #[test]
    fn fleet_ops_route_only_on_a_leader_and_round_trip_a_task() {
        // plain daemon: fleet ops are 404s, like any unknown op
        let st = state();
        for op in ["claim", "result", "heartbeat"] {
            let e = dispatch(&st, &req(op, Json::obj(vec![("worker", Json::from(1.0))])))
                .unwrap_err();
            assert_eq!(e.code, CODE_UNKNOWN_OP, "{op} without a fleet queue");
        }

        // leader: claim hands out the queued task under a lease, the
        // result op records it exactly once
        let mut st = state();
        let queue = Arc::new(FleetQueue::new(60_000));
        st.fleet = Some(Arc::clone(&queue));
        let space = Platform::Axiline.param_space();
        let values: Vec<f64> = space.iter().map(|p| p.kind.from_unit(0.3)).collect();
        queue.enqueue(crate::coordinator::fleet::TaskSpec {
            key: 0xfff7_0000_0000_0001, // > 2^53: exercises the hex path
            flow_key: 9,
            arch: ArchConfig::new(Platform::Axiline, values),
            f_target_ghz: 0.8,
            util: 0.5,
            workload: None,
            trial: 0,
            enablement: Enablement::Gf12,
            seed: 11,
        });
        let worker = Json::obj(vec![("worker", Json::from(7.0))]);
        let out = dispatch(&st, &req("claim", worker.clone())).unwrap();
        assert_eq!(out.get("drain").as_bool(), Some(false));
        let task = out.get("task");
        assert_eq!(task.get("key").as_str(), Some("fff7000000000001"));
        assert_eq!(dispatch(&st, &req("heartbeat", worker.clone())).unwrap()
            .get("renewed").as_usize(), Some(1));
        // dry queue: task null, still not draining
        let out = dispatch(&st, &req("claim", worker)).unwrap();
        assert!(matches!(out.get("task"), Json::Null));

        let spec = crate::coordinator::fleet::TaskSpec::from_json(task).unwrap();
        let ev = st.service
            .evaluate_trial(&spec.arch, BackendConfig::new(spec.f_target_ghz, spec.util),
                spec.workload.as_ref(), spec.trial)
            .unwrap();
        let body = Json::obj(vec![
            ("eval", crate::coordinator::fleet::eval_to_json(&ev)),
            ("key", Json::from("fff7000000000001")),
        ]);
        let out = dispatch(&st, &req("result", body.clone())).unwrap();
        assert_eq!(out.get("recorded").as_bool(), Some(true));
        let out = dispatch(&st, &req("result", body)).unwrap();
        assert_eq!(out.get("recorded").as_bool(), Some(false), "late duplicate is dropped");
        assert_eq!(queue.await_result(0xfff7_0000_0000_0001).unwrap(), ev);

        // malformed payloads are 400s, not panics
        let e = dispatch(&st, &req("result", Json::obj(vec![("key", Json::from("zz"))])))
            .unwrap_err();
        assert_eq!(e.code, CODE_BAD_REQUEST);
        let e = dispatch(&st, &req("result",
            Json::obj(vec![("key", Json::from("0f")), ("eval", Json::from(1.0))])))
            .unwrap_err();
        assert_eq!(e.code, CODE_BAD_REQUEST);
    }

    #[test]
    fn predict_without_surrogate_is_a_handler_error_not_a_panic() {
        let st = state();
        let body = Json::obj(vec![("rows", Json::Arr(vec![Json::arr_f64(&[0.0; 4])]))]);
        let e = dispatch(&st, &req("predict", body)).unwrap_err();
        assert_eq!(e.code, CODE_INTERNAL);
        let e = dispatch(&st, &req("predict", Json::obj(vec![("rows", Json::from(3.0))])))
            .unwrap_err();
        assert_eq!(e.code, CODE_BAD_REQUEST);
        // wrong feature width is a 400 at the edge, not an index panic
        // deep inside tree inference
        let body = Json::obj(vec![("rows", Json::Arr(vec![Json::arr_f64(&[0.0; 3])]))]);
        let e = dispatch(&st, &req("predict", body)).unwrap_err();
        assert_eq!(e.code, CODE_BAD_REQUEST);
    }
}
