//! Fault injection for the daemon's request path (ISSUE 9 satellite,
//! mirroring `store::fault`): tests arm a one-shot fault and the next
//! request line the daemon reads is damaged *after* framing but
//! *before* decode — emulating a client torn mid-line by a crash or a
//! proxy truncation. The contract under test: the damaged request gets
//! a per-connection error response and the daemon keeps serving; it
//! never panics and never wedges the connection.
//!
//! The hook is process-global and one-shot, armed either in-process
//! (unit tests) or over the wire through the test-gated `hook` op
//! (`FSO_SERVE_TEST_HOOKS=1` child daemons in `tests/serve_daemon.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};

/// How the next framed request line is damaged before decode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeFault {
    /// Truncate the line midway and append a non-UTF8 byte: a torn,
    /// invalid request that must yield a 400 response, not a panic.
    TornRequest,
    /// Panic the connection thread that reads the next request line
    /// (ISSUE 10 satellite): the accept loop must *join* the dead
    /// handle and count it in `ServeStats::connection_panics` instead
    /// of silently dropping it, and the daemon must keep serving.
    PanicConnection,
}

// 0 = disarmed, 1 = TornRequest, 2 = PanicConnection
static ARMED: AtomicUsize = AtomicUsize::new(0);

fn code(fault: ServeFault) -> usize {
    match fault {
        ServeFault::TornRequest => 1,
        ServeFault::PanicConnection => 2,
    }
}

/// Arm a one-shot request fault; the next request line consumes it.
pub fn arm(fault: ServeFault) {
    ARMED.store(code(fault), Ordering::SeqCst);
}

/// Cancel a pending fault (test cleanup).
pub fn disarm() {
    ARMED.store(0, Ordering::SeqCst);
}

/// True exactly once after `arm(point)` — the connection loop calls
/// this per framed line and damages the line when it fires.
pub(crate) fn trip(point: ServeFault) -> bool {
    ARMED
        .compare_exchange(code(point), 0, Ordering::SeqCst, Ordering::SeqCst)
        .is_ok()
}

/// The injected damage: keep the first half of the line and append a
/// byte that is valid in no UTF-8 sequence, so the decode *must* take
/// its torn-line path.
pub(crate) fn tear_line(line: &mut Vec<u8>) {
    line.truncate(line.len() / 2);
    line.push(0xFF);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::protocol::{decode_request, CODE_BAD_REQUEST};

    #[test]
    fn torn_fault_is_one_shot_and_decode_survives_the_damage() {
        disarm();
        assert!(!trip(ServeFault::TornRequest), "disarmed hook never fires");
        arm(ServeFault::TornRequest);
        assert!(trip(ServeFault::TornRequest), "armed hook fires once");
        assert!(!trip(ServeFault::TornRequest), "and only once");

        let mut line = br#"{"body":{"rows":[[1.0]]},"id":5,"op":"predict"}"#.to_vec();
        tear_line(&mut line);
        let e = decode_request(&line).expect_err("torn line must fail decode");
        assert_eq!(e.code, CODE_BAD_REQUEST);
    }
}
