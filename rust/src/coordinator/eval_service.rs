//! EvalService — the single entry point for scoring an (architecture,
//! backend) point (ROADMAP "scale the search" seam; paper §7.1/§8.4).
//!
//! Both expensive oracles — the SP&R flow and the system simulators —
//! sit behind this service:
//!
//! - **Memoization**: ground-truth results are cached behind a seeded
//!   content-hash key (platform + arch values + backend knobs +
//!   enablement + seed + workload + trial), so repeated evaluations of
//!   the same point (MOTPE revisits, datagen/DSE overlap, benchmark
//!   sweeps) cost one oracle call. The workload-independent SP&R flow
//!   result is additionally cached under a workload-free key, so the
//!   expensive flow is shared across workloads (datagen's default
//!   binding vs. a DSE problem's explicit one). Design aggregates are
//!   cached per architecture the same way.
//! - **Parallel fan-out**: `evaluate_many` spreads ground-truth
//!   evaluations over `util::pool::par_map` with a configurable worker
//!   count. Order is preserved and every evaluation is deterministic
//!   given the service seed, so the worker count never changes results
//!   — serial and parallel runs are byte-identical.
//! - **Per-trial RNG streams**: `evaluate_trial` derives independent
//!   flow-noise seeds per trial through `util::rng::Rng::fork`, stable
//!   under call reordering. Trial 0 is the base seed (compatible with
//!   the historical single-flow path).
//! - **Batched surrogate scoring**: `predict_batch` scores candidate
//!   batches metric-major through the two-stage `SurrogateBundle`
//!   (one regressor pass per metric instead of per-row `predict_one`
//!   calls), and `predict_ann_batch` routes feature rows through the
//!   dynamic-batching `PredictServer` when a client is attached.
//! - **Stats**: `ServerStats`-style counters (cache hit rates, batch
//!   occupancy) surfaced via [`EvalService::stats`] for benches,
//!   examples, and tests.
//! - **Persistence**: an optional [`CacheStore`]
//!   (`with_cache_store`) adds a disk-backed second cache level:
//!   lookups read through to sharded JSONL records from previous runs
//!   (warm start), oracle results are written behind and flushed via
//!   `flush_cache`. Several services — across enablements, workloads,
//!   and processes — can share one store; results never change, only
//!   wall-clock (see `coordinator::cache_store`).
//! - **Single-flight coalescing** (`with_coalescing`, ISSUE 5):
//!   concurrent misses on the same content-hash key share one
//!   in-flight oracle run instead of racing to recompute it — all
//!   waiters receive the bit-identical result, the memo and store are
//!   written once per key, and `oracle_runs` is pinned at one per
//!   unique key under any thread schedule (see
//!   `coordinator::coalesce`).

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::backend::{BackendConfig, Enablement, FlowResult, SpnrFlow};
use crate::coordinator::cache_store::CacheStore;
use crate::coordinator::coalesce::{Joined, SingleFlight};
use crate::coordinator::dse_driver::SurrogateBundle;
use crate::coordinator::model_store::ModelStore;
use crate::coordinator::predict_server::PredictClient;
use crate::data::{Dataset, Metric, Split};
use crate::generators::{unified_features, ArchConfig, DesignAggregates, FEAT_DIM};
use crate::simulators::{simulate, simulate_spec, SystemMetrics};
use crate::util::json::Json;
use crate::util::pool::par_map;
use crate::util::rng::{hash_bytes, Rng};
use crate::workloads::{NonDnnAlgo, WorkloadSpec};

/// One fully ground-truthed point: SP&R flow output + system metrics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Evaluation {
    pub flow: FlowResult,
    pub system: SystemMetrics,
}

impl Evaluation {
    /// The five paper metrics as a map (ground-truth side of the DSE
    /// "within 6-7% of post-SP&R" check).
    pub fn metrics(&self) -> BTreeMap<Metric, f64> {
        BTreeMap::from([
            (Metric::Power, self.flow.backend.total_power_w()),
            (Metric::Performance, self.flow.backend.f_effective_ghz),
            (Metric::Area, self.flow.backend.chip_area_mm2),
            (Metric::Energy, self.system.energy_j),
            (Metric::Runtime, self.system.runtime_s),
        ])
    }
}

/// One surrogate-scored point (two-stage: ROI gate + per-metric value).
#[derive(Debug, Clone, PartialEq)]
pub struct SurrogatePoint {
    pub in_roi: bool,
    pub predicted: BTreeMap<Metric, f64>,
}

/// Everything a remote worker needs to ground-truth one point without
/// sharing any state with the leader: the pre-computed content-hash
/// keys (so the fleet queue can dedup) plus the full evaluation spec
/// (so the worker recomputes the bit-identical result from scratch).
pub struct RemoteTask<'a> {
    pub key: u64,
    pub flow_key: u64,
    pub arch: &'a ArchConfig,
    pub bcfg: BackendConfig,
    pub wl: Option<&'a WorkloadSpec>,
    pub trial: u64,
    pub enablement: Enablement,
    pub seed: u64,
}

/// Fleet dispatch seam (ISSUE 10): when attached via
/// [`EvalService::with_remote_oracle`], full oracle misses — memo and
/// store both cold — are shipped to worker processes instead of
/// running the SP&R flow + simulator locally. Implementations must be
/// deterministic: the same task always yields the bit-identical
/// [`Evaluation`] a local run would produce (workers run the same
/// seeded flow), so attaching a remote oracle never changes results,
/// record sets, or shard bytes — only where the CPU time is spent.
pub trait RemoteOracle: Send + Sync {
    fn evaluate_remote(&self, task: &RemoteTask<'_>) -> Result<Evaluation>;
}

/// Snapshot of the service counters (`ServerStats` analogue).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EvalStats {
    /// Ground-truth oracle calls answered without running the flow +
    /// simulator (in-memory memo or persistent store).
    pub oracle_hits: usize,
    /// Ground-truth oracle calls that ran the flow + simulator.
    pub oracle_misses: usize,
    /// Design-aggregate lookups answered from the per-arch cache.
    pub agg_hits: usize,
    /// Design-aggregate lookups that generated the module tree.
    pub agg_misses: usize,
    /// Feature rows scored through `predict_batch`.
    pub surrogate_rows: usize,
    /// `predict_batch` invocations (batching efficiency denominator).
    pub surrogate_batches: usize,
    /// Feature rows routed through the attached `PredictServer`.
    pub ann_rows: usize,
    /// `predict_ann_batch` invocations.
    pub ann_batches: usize,
    /// Oracle/flow lookups this service answered from the persistent
    /// `CacheStore` (loaded from a previous run's shards, or written by
    /// another service sharing the store).
    pub disk_hits: usize,
    /// Shard files the attached store has parsed (store-level: shared
    /// by every service attached to the same store).
    pub shard_loads: usize,
    /// Flushes the attached store has performed (store-level).
    pub flushes: usize,
    /// Surrogate-model artifacts served from the attached `ModelStore`
    /// (store-level counters, shared by everything attached to it).
    pub model_hits: usize,
    /// Model-store lookups that fell back to a fresh fit.
    pub model_misses: usize,
    /// Records evicted by the attached stores' lifecycle policies
    /// (oracle + model store, store-level).
    pub store_evictions: usize,
    /// Compaction passes the attached stores have run (explicit +
    /// automatic, store-level).
    pub store_compactions: usize,
    /// Records scanned but *not* decoded by the attached stores'
    /// shard loads (storage engine v2 streaming scan; oracle + model
    /// store, store-level).
    pub lazy_skips: usize,
    /// Point lookups the attached stores answered from `.idx`
    /// sidecars without loading a shard (oracle + model store).
    pub sidecar_hits: usize,
    /// Sidecars the attached stores rebuilt after finding them
    /// missing, torn, or stale (oracle + model store).
    pub sidecar_rebuilds: usize,
    /// Records the attached stores transcoded between codecs at
    /// flush/compact (mixed-codec directories; oracle + model store).
    pub transcoded_records: usize,
    /// Full ground-truth computations actually executed (the
    /// simulator pass after every cache level missed). Unlike
    /// `oracle_misses` — which is pinned at one per unique key by the
    /// double-checked memo insert — this counts *work*: racing
    /// uncoalesced workers may run the same key several times, while a
    /// coalesced service pins it at exactly one per unique key.
    pub oracle_runs: usize,
    /// SP&R flow executions actually performed (`flow_runs <=
    /// oracle_runs`; the flow is shared across workloads and trials
    /// reuse nothing).
    pub flow_runs: usize,
    /// Oracle calls served by waiting on another caller's in-flight
    /// single-flight computation (ISSUE 5; also counted in
    /// `oracle_hits` — the call never ran the oracle).
    pub coalesced_hits: usize,
    /// Highest number of concurrently in-flight oracle leaders
    /// observed (single-flight occupancy).
    pub inflight_peak: usize,
    /// Predict requests routed through an attached `EvalRouter`.
    pub router_requests: usize,
    /// Feature rows routed through an attached `EvalRouter`.
    pub router_rows: usize,
    /// Mega-batches the router issued (cross-client coalescing
    /// efficiency denominator).
    pub router_batches: usize,
    /// Queued evaluations pulled and run by parked single-flight
    /// waiters (work-stealing mode, ISSUE 10); stays 0 unless
    /// `with_work_stealing` is enabled.
    pub steals: usize,
}

impl EvalStats {
    /// Fraction of ground-truth oracle calls served from cache.
    pub fn oracle_hit_rate(&self) -> f64 {
        let total = self.oracle_hits + self.oracle_misses;
        if total == 0 {
            0.0
        } else {
            self.oracle_hits as f64 / total as f64
        }
    }

    /// Fraction of all cached-oracle lookups (flow results + design
    /// aggregates) served from cache.
    pub fn cache_hit_rate(&self) -> f64 {
        let hits = self.oracle_hits + self.agg_hits;
        let total = hits + self.oracle_misses + self.agg_misses;
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }

    /// Mean rows per surrogate batch (batching efficiency).
    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.surrogate_batches == 0 {
            0.0
        } else {
            self.surrogate_rows as f64 / self.surrogate_batches as f64
        }
    }

    /// Mean rows per router mega-batch (cross-client coalescing
    /// efficiency).
    pub fn router_occupancy(&self) -> f64 {
        if self.router_batches == 0 {
            0.0
        } else {
            self.router_rows as f64 / self.router_batches as f64
        }
    }

    /// The full counter set as a JSON object — what the serve daemon's
    /// `stats` endpoint returns. `Json::obj` sorts the keys, so the
    /// serialization is deterministic for byte-diffing clients.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("oracle_hits", Json::from(self.oracle_hits)),
            ("oracle_misses", Json::from(self.oracle_misses)),
            ("agg_hits", Json::from(self.agg_hits)),
            ("agg_misses", Json::from(self.agg_misses)),
            ("surrogate_rows", Json::from(self.surrogate_rows)),
            ("surrogate_batches", Json::from(self.surrogate_batches)),
            ("ann_rows", Json::from(self.ann_rows)),
            ("ann_batches", Json::from(self.ann_batches)),
            ("disk_hits", Json::from(self.disk_hits)),
            ("shard_loads", Json::from(self.shard_loads)),
            ("flushes", Json::from(self.flushes)),
            ("model_hits", Json::from(self.model_hits)),
            ("model_misses", Json::from(self.model_misses)),
            ("store_evictions", Json::from(self.store_evictions)),
            ("store_compactions", Json::from(self.store_compactions)),
            ("lazy_skips", Json::from(self.lazy_skips)),
            ("sidecar_hits", Json::from(self.sidecar_hits)),
            ("sidecar_rebuilds", Json::from(self.sidecar_rebuilds)),
            ("transcoded_records", Json::from(self.transcoded_records)),
            ("oracle_runs", Json::from(self.oracle_runs)),
            ("flow_runs", Json::from(self.flow_runs)),
            ("coalesced_hits", Json::from(self.coalesced_hits)),
            ("inflight_peak", Json::from(self.inflight_peak)),
            ("router_requests", Json::from(self.router_requests)),
            ("router_rows", Json::from(self.router_rows)),
            ("router_batches", Json::from(self.router_batches)),
            ("steals", Json::from(self.steals)),
        ])
    }
}

impl std::fmt::Display for EvalStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "oracle {} calls ({:.1}% cached) | aggregates {} lookups ({:.1}% cached) | \
             surrogate {} rows / {} batches ({:.1}/batch)",
            self.oracle_hits + self.oracle_misses,
            self.oracle_hit_rate() * 100.0,
            self.agg_hits + self.agg_misses,
            {
                let t = self.agg_hits + self.agg_misses;
                if t == 0 { 0.0 } else { self.agg_hits as f64 / t as f64 * 100.0 }
            },
            self.surrogate_rows,
            self.surrogate_batches,
            self.mean_batch_occupancy(),
        )?;
        write!(
            f,
            " | persistent {} disk hits ({} shard loads, {} flushes)",
            self.disk_hits, self.shard_loads, self.flushes
        )?;
        write!(
            f,
            " | model store {} hits / {} misses",
            self.model_hits, self.model_misses
        )?;
        write!(
            f,
            " | lifecycle {} evictions / {} compactions",
            self.store_evictions, self.store_compactions
        )?;
        write!(
            f,
            " | engine {} lazy skips / {} sidecar hits / {} rebuilds / {} transcoded",
            self.lazy_skips, self.sidecar_hits, self.sidecar_rebuilds, self.transcoded_records
        )?;
        write!(
            f,
            " | coalesce {} waits ({} oracle runs, {} steals, peak {} in flight)",
            self.coalesced_hits, self.oracle_runs, self.steals, self.inflight_peak
        )?;
        write!(
            f,
            " | router {} reqs / {} rows / {} batches ({:.1}/batch)",
            self.router_requests,
            self.router_rows,
            self.router_batches,
            self.router_occupancy()
        )
    }
}

#[derive(Default)]
struct Counters {
    oracle_hits: AtomicUsize,
    oracle_misses: AtomicUsize,
    agg_hits: AtomicUsize,
    agg_misses: AtomicUsize,
    surrogate_rows: AtomicUsize,
    surrogate_batches: AtomicUsize,
    ann_rows: AtomicUsize,
    ann_batches: AtomicUsize,
    disk_hits: AtomicUsize,
    oracle_runs: AtomicUsize,
    flow_runs: AtomicUsize,
    coalesced_hits: AtomicUsize,
    router_requests: AtomicUsize,
    router_rows: AtomicUsize,
    router_batches: AtomicUsize,
    steals: AtomicUsize,
}

/// Optional PJRT path: a `PredictServer` client plus the (variant,
/// theta) identity its batches are keyed by.
#[derive(Clone)]
struct AnnClient {
    client: PredictClient,
    variant: String,
    theta: Vec<f32>,
}

/// The parallel, cached evaluation service (see module docs).
pub struct EvalService {
    enablement: Enablement,
    seed: u64,
    flow: SpnrFlow,
    workers: usize,
    surrogate: Option<SurrogateBundle>,
    ann: Mutex<Option<AnnClient>>,
    oracle_cache: Mutex<HashMap<u64, Evaluation>>,
    /// SP&R results keyed without the workload: the flow depends only
    /// on (design, knobs, enablement, seed, trial), so datagen rows
    /// (default workload) and DSE ground truth (explicit workload)
    /// share one flow computation per point.
    flow_cache: Mutex<HashMap<u64, FlowResult>>,
    agg_cache: Mutex<HashMap<u64, DesignAggregates>>,
    /// Optional persistent second-level cache (read-through on memo
    /// misses, write-behind on oracle runs); shared across services
    /// and across runs via `Arc<CacheStore>`.
    store: Option<Arc<CacheStore>>,
    /// Optional persistent surrogate-model store (ISSUE 3):
    /// `fit_surrogate` reads through it and writes fresh fits behind.
    model_store: Option<Arc<ModelStore>>,
    /// Single-flight request coalescing (ISSUE 5, `with_coalescing`):
    /// when enabled, concurrent misses on the same oracle/flow key
    /// share one in-flight computation instead of racing to recompute
    /// identical results.
    coalesce: bool,
    oracle_flights: SingleFlight<Evaluation>,
    flow_flights: SingleFlight<FlowResult>,
    /// Work-stealing single flight (ISSUE 10, `with_work_stealing`):
    /// when enabled, `evaluate_many` waiters that lose a flight
    /// election pull other queued jobs off the shared batch instead of
    /// idling until their leader publishes.
    steal: bool,
    /// Fleet dispatch seam (ISSUE 10, `with_remote_oracle`): full
    /// oracle misses are shipped to worker processes when attached.
    remote: Option<Arc<dyn RemoteOracle>>,
    counters: Counters,
}

impl EvalService {
    /// A serial service. Chain `with_workers` / `with_surrogate` to
    /// configure; `seed` keys the SP&R flow's deterministic tool noise.
    pub fn new(enablement: Enablement, seed: u64) -> EvalService {
        EvalService {
            enablement,
            seed,
            flow: SpnrFlow::new(enablement, seed),
            workers: 1,
            surrogate: None,
            ann: Mutex::new(None),
            oracle_cache: Mutex::new(HashMap::new()),
            flow_cache: Mutex::new(HashMap::new()),
            agg_cache: Mutex::new(HashMap::new()),
            store: None,
            model_store: None,
            coalesce: false,
            oracle_flights: SingleFlight::new(),
            flow_flights: SingleFlight::new(),
            steal: false,
            remote: None,
            counters: Counters::default(),
        }
    }

    /// Enable single-flight request coalescing (ISSUE 5): concurrent
    /// `evaluate*` calls that miss every cache level on the same
    /// content-hash key elect one leader to run the SP&R oracle +
    /// simulator; every other caller waits and receives the leader's
    /// bit-identical result, and the memo/store are written once per
    /// key. Never changes results — only wall-clock and CPU time —
    /// and pins `oracle_runs` at exactly one per unique key under any
    /// thread schedule.
    pub fn with_coalescing(mut self, on: bool) -> EvalService {
        self.coalesce = on;
        self
    }

    /// Whether single-flight coalescing is enabled.
    pub fn coalescing(&self) -> bool {
        self.coalesce
    }

    /// Enable the work-stealing flavor of single-flight (ISSUE 10):
    /// an `evaluate_many` worker that loses a flight election pulls
    /// other queued jobs off the shared batch and runs them instead of
    /// idling until its leader publishes, lifting the wall-clock floor
    /// on grouped-duplicate workloads. Requires `with_coalescing(true)`
    /// to have any effect. Never changes results or counter totals
    /// other than `steals` — values are schedule-independent and
    /// `oracle_runs` stays at one per unique key.
    pub fn with_work_stealing(mut self, on: bool) -> EvalService {
        self.steal = on;
        self
    }

    /// Whether work-stealing single-flight is enabled.
    pub fn work_stealing(&self) -> bool {
        self.steal
    }

    /// Attach a fleet dispatch seam (ISSUE 10): full oracle misses —
    /// in-memory memo and persistent store both cold — are shipped
    /// through `remote` (normally a `fleet::FleetOracle` fronting
    /// worker processes) instead of running the SP&R flow + simulator
    /// on this thread. The returned evaluation is recorded through the
    /// same double-checked memo insert and write-behind puts as a
    /// local run, so record sets and flushed shard bytes stay
    /// byte-identical to a single-process run.
    pub fn with_remote_oracle(mut self, remote: Arc<dyn RemoteOracle>) -> EvalService {
        self.remote = Some(remote);
        self
    }

    /// `with_remote_oracle` for plumbing that may not have a fleet:
    /// attaches when given, no-op otherwise.
    pub fn with_remote_oracle_opt(self, remote: Option<Arc<dyn RemoteOracle>>) -> EvalService {
        match remote {
            Some(r) => self.with_remote_oracle(r),
            None => self,
        }
    }

    /// Worker threads for `evaluate_many` / `predict_batch` fan-out;
    /// 0 = auto (`util::pool::default_workers`, the convention
    /// `DatagenConfig` and `TrainOptions` share). Never changes
    /// results — only wall-clock.
    pub fn with_workers(mut self, workers: usize) -> EvalService {
        self.workers = if workers == 0 {
            crate::util::pool::default_workers()
        } else {
            workers
        };
        self
    }

    /// Attach the two-stage surrogate used by `predict_batch`.
    pub fn with_surrogate(mut self, surrogate: SurrogateBundle) -> EvalService {
        self.surrogate = Some(surrogate);
        self
    }

    /// Attach a persistent cache store. Lookups fall through the
    /// in-memory memo to the store (read-through); oracle runs are
    /// recorded back (write-behind — call [`EvalService::flush_cache`]
    /// or drop the last `Arc` to make them durable). Several services —
    /// across enablements, workloads, or processes — can share one
    /// store: the content-hash keys encode everything that
    /// distinguishes them. Never changes results, only wall-clock.
    pub fn with_cache_store(mut self, store: Arc<CacheStore>) -> EvalService {
        self.store = Some(store);
        self
    }

    /// `with_cache_store` for CLI plumbing that may or may not have a
    /// `--cache-dir`: attaches when given, no-op otherwise.
    pub fn with_cache_store_opt(self, store: Option<Arc<CacheStore>>) -> EvalService {
        match store {
            Some(s) => self.with_cache_store(s),
            None => self,
        }
    }

    /// The attached persistent store, if any.
    pub fn cache_store(&self) -> Option<&Arc<CacheStore>> {
        self.store.as_ref()
    }

    /// Attach a persistent surrogate-model store (ISSUE 3):
    /// [`EvalService::fit_surrogate`] reads fitted bundles through it
    /// and writes fresh fits behind. Cohabits with the oracle store
    /// under one `--cache-dir` (see `coordinator::model_store`). Never
    /// changes results — stored models replay bit-identical
    /// predictions — only wall-clock.
    pub fn with_model_store(mut self, store: Arc<ModelStore>) -> EvalService {
        self.model_store = Some(store);
        self
    }

    /// `with_model_store` for CLI plumbing: attaches when given.
    pub fn with_model_store_opt(self, store: Option<Arc<ModelStore>>) -> EvalService {
        match store {
            Some(s) => self.with_model_store(s),
            None => self,
        }
    }

    /// The attached model store, if any.
    pub fn model_store(&self) -> Option<&Arc<ModelStore>> {
        self.model_store.as_ref()
    }

    /// Fit-or-load the two-stage DSE surrogate through the attached
    /// model store and attach it for `predict_batch` (read-through on
    /// the fit request, write-behind after fitting; a plain fit
    /// without a store attached). Returns whether the bundle was
    /// served from the store — a warm start reports `true` and runs
    /// zero refits.
    pub fn fit_surrogate(&mut self, ds: &Dataset, split: &Split, seed: u64) -> Result<bool> {
        let (bundle, cached) =
            SurrogateBundle::fit_cached(ds, split, seed, self.model_store.as_deref())?;
        self.surrogate = Some(bundle);
        Ok(cached)
    }

    /// Flush both attached stores' pending records to disk (no-op for
    /// absent stores). Returns the number of shard files written.
    pub fn flush_cache(&self) -> Result<usize> {
        let mut written = 0;
        if let Some(s) = &self.store {
            written += s.flush()?;
        }
        if let Some(m) = &self.model_store {
            written += m.flush()?;
        }
        Ok(written)
    }

    pub fn enablement(&self) -> Enablement {
        self.enablement
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn surrogate(&self) -> Option<&SurrogateBundle> {
        self.surrogate.as_ref()
    }

    /// Snapshot the counters.
    pub fn stats(&self) -> EvalStats {
        EvalStats {
            oracle_hits: self.counters.oracle_hits.load(Ordering::Relaxed),
            oracle_misses: self.counters.oracle_misses.load(Ordering::Relaxed),
            agg_hits: self.counters.agg_hits.load(Ordering::Relaxed),
            agg_misses: self.counters.agg_misses.load(Ordering::Relaxed),
            surrogate_rows: self.counters.surrogate_rows.load(Ordering::Relaxed),
            surrogate_batches: self.counters.surrogate_batches.load(Ordering::Relaxed),
            ann_rows: self.counters.ann_rows.load(Ordering::Relaxed),
            ann_batches: self.counters.ann_batches.load(Ordering::Relaxed),
            disk_hits: self.counters.disk_hits.load(Ordering::Relaxed),
            shard_loads: self.store.as_ref().map_or(0, |s| s.shard_loads()),
            flushes: self.store.as_ref().map_or(0, |s| s.flush_count()),
            model_hits: self.model_store.as_ref().map_or(0, |m| m.hits()),
            model_misses: self.model_store.as_ref().map_or(0, |m| m.misses()),
            store_evictions: self.store.as_ref().map_or(0, |s| s.evictions())
                + self.model_store.as_ref().map_or(0, |m| m.evictions()),
            store_compactions: self.store.as_ref().map_or(0, |s| s.compactions())
                + self.model_store.as_ref().map_or(0, |m| m.compactions()),
            lazy_skips: self.store.as_ref().map_or(0, |s| s.lazy_skips())
                + self.model_store.as_ref().map_or(0, |m| m.lazy_skips()),
            sidecar_hits: self.store.as_ref().map_or(0, |s| s.sidecar_hits())
                + self.model_store.as_ref().map_or(0, |m| m.sidecar_hits()),
            sidecar_rebuilds: self.store.as_ref().map_or(0, |s| s.sidecar_rebuilds())
                + self.model_store.as_ref().map_or(0, |m| m.sidecar_rebuilds()),
            transcoded_records: self.store.as_ref().map_or(0, |s| s.transcoded_records())
                + self.model_store.as_ref().map_or(0, |m| m.transcoded_records()),
            oracle_runs: self.counters.oracle_runs.load(Ordering::Relaxed),
            flow_runs: self.counters.flow_runs.load(Ordering::Relaxed),
            coalesced_hits: self.counters.coalesced_hits.load(Ordering::Relaxed),
            inflight_peak: self.oracle_flights.inflight_peak(),
            router_requests: self.counters.router_requests.load(Ordering::Relaxed),
            router_rows: self.counters.router_rows.load(Ordering::Relaxed),
            router_batches: self.counters.router_batches.load(Ordering::Relaxed),
            steals: self.counters.steals.load(Ordering::Relaxed),
        }
    }

    /// Router accounting (called by `coordinator::coalesce` when an
    /// `EvalRouter` drains a coalescing window into this service).
    pub(crate) fn note_router_requests(&self, requests: usize, rows: usize) {
        self.counters.router_requests.fetch_add(requests, Ordering::Relaxed);
        self.counters.router_rows.fetch_add(rows, Ordering::Relaxed);
    }

    /// One router mega-batch issued against this service.
    pub(crate) fn note_router_batch(&self) {
        self.counters.router_batches.fetch_add(1, Ordering::Relaxed);
    }

    /// Content-hash key for the workload-independent SP&R flow result:
    /// design identity, backend knobs, enablement, seed, trial stream.
    fn flow_key(&self, arch: &ArchConfig, bcfg: BackendConfig, trial: u64) -> u64 {
        let mut bytes = Vec::with_capacity(48);
        bytes.extend_from_slice(&arch.id_hash().to_le_bytes());
        bytes.extend_from_slice(&bcfg.f_target_ghz.to_bits().to_le_bytes());
        bytes.extend_from_slice(&bcfg.util.to_bits().to_le_bytes());
        bytes.push(match self.enablement {
            Enablement::Gf12 => 0,
            Enablement::Ng45 => 1,
        });
        bytes.extend_from_slice(&self.seed.to_le_bytes());
        bytes.extend_from_slice(&trial.to_le_bytes());
        hash_bytes(&bytes)
    }

    /// Content-hash key for a full ground-truth evaluation: the flow
    /// key extended with the workload the simulator ran. The `None`
    /// (platform default binding) and non-DNN encodings are frozen —
    /// warm caches from earlier releases stay byte-compatible; DNN
    /// layer-table overrides extend the keyspace under a new tag.
    fn oracle_key(&self, flow_key: u64, wl: Option<&WorkloadSpec>) -> u64 {
        let mut bytes = Vec::with_capacity(48);
        bytes.extend_from_slice(&flow_key.to_le_bytes());
        match wl {
            None => bytes.push(0),
            Some(WorkloadSpec::NonDnn(w)) => {
                bytes.push(match w.algo {
                    NonDnnAlgo::Svm => 1,
                    NonDnnAlgo::LinearRegression => 2,
                    NonDnnAlgo::LogisticRegression => 3,
                    NonDnnAlgo::Recsys => 4,
                    NonDnnAlgo::Backprop => 5,
                });
                bytes.extend_from_slice(&(w.features as u64).to_le_bytes());
                bytes.extend_from_slice(&(w.samples as u64).to_le_bytes());
                bytes.extend_from_slice(&(w.epochs as u64).to_le_bytes());
            }
            Some(WorkloadSpec::Dnn(net)) => {
                bytes.push(6);
                // name + op/weight totals + layer count: a cached result
                // never survives an edit to the layer table it priced
                bytes.extend_from_slice(&hash_bytes(net.name.as_bytes()).to_le_bytes());
                bytes.extend_from_slice(&net.total_macs().to_le_bytes());
                bytes.extend_from_slice(&net.total_vector_ops().to_le_bytes());
                bytes.extend_from_slice(&net.total_weights().to_le_bytes());
                bytes.extend_from_slice(&(net.layers.len() as u64).to_le_bytes());
            }
        }
        hash_bytes(&bytes)
    }

    /// Design aggregates for an architecture, cached by identity hash.
    /// The miss path generates outside the lock (concurrent first
    /// touches of the same arch may generate twice and one result is
    /// discarded — generation is deterministic, so values never
    /// differ); the double-checked insert keeps hit/miss totals
    /// deterministic: exactly one miss per unique key.
    pub fn aggregates(&self, arch: &ArchConfig) -> Result<DesignAggregates> {
        let key = arch.id_hash();
        if let Some(agg) = self.agg_cache.lock().unwrap().get(&key) {
            self.counters.agg_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(*agg);
        }
        // generate outside the lock (first touches of distinct archs
        // proceed in parallel), double-check on insert so exactly one
        // miss is recorded per unique key
        let tree = arch.platform.generate(arch)?;
        let agg = tree.aggregates();
        let mut cache = self.agg_cache.lock().unwrap();
        if cache.contains_key(&key) {
            self.counters.agg_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.counters.agg_misses.fetch_add(1, Ordering::Relaxed);
            cache.insert(key, agg);
        }
        Ok(agg)
    }

    /// Seed the aggregate cache with a value computed elsewhere
    /// (datagen builds each arch's module tree for its LHG anyway —
    /// priming avoids regenerating it on the first evaluation).
    /// Counted as neither hit nor miss.
    pub fn prime_aggregates(&self, arch: &ArchConfig, agg: DesignAggregates) {
        self.agg_cache.lock().unwrap().entry(arch.id_hash()).or_insert(agg);
    }

    /// Unified Eq. 1/2 feature vector for an (arch, backend) point.
    pub fn features(&self, arch: &ArchConfig, bcfg: BackendConfig) -> Result<[f64; FEAT_DIM]> {
        let agg = self.aggregates(arch)?;
        Ok(unified_features(
            arch,
            bcfg.f_target_ghz,
            bcfg.util,
            agg.comb_cells,
            agg.macro_bits,
        ))
    }

    /// Ground-truth one point (SP&R flow + system simulator), memoized.
    /// `wl = None` uses the platform's default workload binding; any
    /// registry workload (DNN layer table or non-DNN spec) overrides it.
    pub fn evaluate(
        &self,
        arch: &ArchConfig,
        bcfg: BackendConfig,
        wl: Option<&WorkloadSpec>,
    ) -> Result<Evaluation> {
        self.evaluate_trial(arch, bcfg, wl, 0)
    }

    /// Ground-truth one point under an independent per-trial noise
    /// stream. Trial 0 runs the base-seed flow; trial t > 0 forks a
    /// deterministic seed via `Rng::fork(t)`, stable under reordering
    /// of calls (repeated-trial studies of the oracle's tool noise).
    pub fn evaluate_trial(
        &self,
        arch: &ArchConfig,
        bcfg: BackendConfig,
        wl: Option<&WorkloadSpec>,
        trial: u64,
    ) -> Result<Evaluation> {
        self.evaluate_trial_with_steal(arch, bcfg, wl, trial, None)
    }

    /// `evaluate_trial` with an optional work-stealing hook: when this
    /// call loses the flight election, `steal` pulls one queued job
    /// off the shared batch per invocation (see `evaluate_many`'s
    /// stealing fan-out). Values are identical either way.
    fn evaluate_trial_with_steal(
        &self,
        arch: &ArchConfig,
        bcfg: BackendConfig,
        wl: Option<&WorkloadSpec>,
        trial: u64,
        steal: Option<&dyn Fn() -> bool>,
    ) -> Result<Evaluation> {
        let flow_key = self.flow_key(arch, bcfg, trial);
        let key = self.oracle_key(flow_key, wl);
        if !self.coalesce {
            return self.evaluate_keyed(arch, bcfg, wl, trial, flow_key, key);
        }
        // fast path: a memo hit needs no flight bookkeeping
        if let Some(ev) = self.oracle_cache.lock().unwrap().get(&key) {
            self.counters.oracle_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(*ev);
        }
        // single flight (ISSUE 5): one leader per in-flight key runs
        // the miss path; everyone else waits on its result. A caller
        // that leads *after* a previous flight published simply hits
        // the memo inside `evaluate_keyed`, so `oracle_runs` stays at
        // exactly one per unique key under any schedule.
        match self.oracle_flights.run_with_steal(
            key,
            || self.evaluate_keyed(arch, bcfg, wl, trial, flow_key, key),
            steal,
        )? {
            Joined::Led(ev) => Ok(ev),
            Joined::Coalesced(ev) => {
                self.counters.oracle_hits.fetch_add(1, Ordering::Relaxed);
                self.counters.coalesced_hits.fetch_add(1, Ordering::Relaxed);
                Ok(ev)
            }
        }
    }

    /// The full lookup-or-compute path for pre-computed keys (memo →
    /// store → flow reuse → compute). Safe to run concurrently for the
    /// same key — double-checked inserts keep counter totals
    /// deterministic — but `with_coalescing` routes duplicates through
    /// a single flight so the work itself is never repeated.
    fn evaluate_keyed(
        &self,
        arch: &ArchConfig,
        bcfg: BackendConfig,
        wl: Option<&WorkloadSpec>,
        trial: u64,
        flow_key: u64,
        key: u64,
    ) -> Result<Evaluation> {
        if let Some(ev) = self.oracle_cache.lock().unwrap().get(&key) {
            self.counters.oracle_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(*ev);
        }
        // read-through: a previous run — or another service sharing the
        // store — may hold the full evaluation. The double-checked memo
        // insert keeps counter totals deterministic under worker races:
        // exactly one disk hit per unique key served from the store.
        if let Some(store) = &self.store {
            if let Some(ev) = store.get_eval(key) {
                let mut cache = self.oracle_cache.lock().unwrap();
                self.counters.oracle_hits.fetch_add(1, Ordering::Relaxed);
                if !cache.contains_key(&key) {
                    self.counters.disk_hits.fetch_add(1, Ordering::Relaxed);
                    cache.insert(key, ev);
                }
                return Ok(ev);
            }
        }
        // fleet mode (ISSUE 10): a leader process ships full misses to
        // worker processes instead of computing locally. The worker
        // recomputes the bit-identical evaluation from the task spec;
        // the result is recorded through the same double-checked memo
        // inserts and write-behind puts as a local run, so record sets
        // and flushed shard bytes match the single-process run.
        if let Some(remote) = &self.remote {
            let ev = remote.evaluate_remote(&RemoteTask {
                key,
                flow_key,
                arch,
                bcfg,
                wl,
                trial,
                enablement: self.enablement,
                seed: self.seed,
            })?;
            self.counters.oracle_runs.fetch_add(1, Ordering::Relaxed);
            {
                let mut flows = self.flow_cache.lock().unwrap();
                if !flows.contains_key(&flow_key) {
                    flows.insert(flow_key, ev.flow);
                    if let Some(store) = &self.store {
                        store.put_flow(flow_key, ev.flow); // write-behind
                    }
                }
            }
            let mut cache = self.oracle_cache.lock().unwrap();
            if cache.contains_key(&key) {
                self.counters.oracle_hits.fetch_add(1, Ordering::Relaxed);
            } else {
                self.counters.oracle_misses.fetch_add(1, Ordering::Relaxed);
                cache.insert(key, ev);
                if let Some(store) = &self.store {
                    store.put_eval(key, ev); // write-behind
                }
            }
            return Ok(ev);
        }
        // the flow is workload-independent: reuse it across workloads
        // (datagen's default binding vs. a DSE problem's explicit one)
        // and, through the store, across runs
        let cached_flow = self.flow_cache.lock().unwrap().get(&flow_key).copied();
        let fr = match cached_flow {
            Some(f) => f,
            // distinct workloads over the same design race on one flow:
            // coalesce them onto a single SP&R run too
            None if self.coalesce => {
                match self
                    .flow_flights
                    .run(flow_key, || self.compute_flow(arch, bcfg, trial, flow_key))?
                {
                    Joined::Led(f) | Joined::Coalesced(f) => f,
                }
            }
            None => self.compute_flow(arch, bcfg, trial, flow_key)?,
        };
        self.counters.oracle_runs.fetch_add(1, Ordering::Relaxed);
        let system = match wl {
            Some(spec) => simulate_spec(arch, &fr.backend, self.enablement, spec)?,
            None => simulate(arch, &fr.backend, self.enablement)?,
        };
        let ev = Evaluation { flow: fr, system };
        // double-check under the lock: when two workers race on the same
        // fresh key, exactly one records the miss and inserts — totals
        // stay deterministic (the recomputed value is identical anyway)
        let mut cache = self.oracle_cache.lock().unwrap();
        if cache.contains_key(&key) {
            self.counters.oracle_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.counters.oracle_misses.fetch_add(1, Ordering::Relaxed);
            cache.insert(key, ev);
            if let Some(store) = &self.store {
                store.put_eval(key, ev); // write-behind
            }
        }
        Ok(ev)
    }

    /// Fetch-or-run the workload-independent SP&R flow for `flow_key`
    /// (memo re-check → store → execute), inserting the winner into
    /// the flow memo and write-behind store exactly once per key.
    fn compute_flow(
        &self,
        arch: &ArchConfig,
        bcfg: BackendConfig,
        trial: u64,
        flow_key: u64,
    ) -> Result<FlowResult> {
        // re-check the memo: a single-flight leader can arrive after a
        // previous leader already published this flow
        if let Some(f) = self.flow_cache.lock().unwrap().get(&flow_key) {
            return Ok(*f);
        }
        let disk_flow = self.store.as_ref().and_then(|s| s.get_flow(flow_key));
        let from_disk = disk_flow.is_some();
        let f = match disk_flow {
            Some(f) => f,
            None => {
                let agg = self.aggregates(arch)?;
                self.counters.flow_runs.fetch_add(1, Ordering::Relaxed);
                if trial == 0 {
                    self.flow.run_on_aggregates(
                        &agg,
                        arch.id_hash(),
                        arch.platform.macro_heavy(),
                        bcfg,
                    )
                } else {
                    let trial_seed = Rng::new(self.seed).fork(trial).next_u64();
                    let flow = SpnrFlow::new(self.enablement, trial_seed);
                    flow.run_on_aggregates(
                        &agg,
                        arch.id_hash(),
                        arch.platform.macro_heavy(),
                        bcfg,
                    )
                }
            }
        };
        // double-check so a racing worker's duplicate disk fetch
        // (or identical recomputation) counts at most once. The
        // write-behind put happens only in the winner branch and
        // under this lock, *after* the memo insert: a racing
        // worker that finds the store entry also finds the memo
        // entry, so a cold run can never report a disk hit for
        // work it did itself.
        let mut cache = self.flow_cache.lock().unwrap();
        if !cache.contains_key(&flow_key) {
            cache.insert(flow_key, f);
            if from_disk {
                self.counters.disk_hits.fetch_add(1, Ordering::Relaxed);
            } else if let Some(store) = &self.store {
                store.put_flow(flow_key, f); // write-behind
            }
        }
        Ok(f)
    }

    /// Ground-truth a batch of points across the worker pool. Output
    /// order matches input order, and results are independent of the
    /// worker count (each evaluation is deterministic given the seed).
    pub fn evaluate_many(
        &self,
        jobs: &[(ArchConfig, BackendConfig)],
        wl: Option<&WorkloadSpec>,
    ) -> Result<Vec<Evaluation>> {
        if self.steal && self.coalesce && self.workers > 1 && jobs.len() > 1 {
            return self.evaluate_many_stealing(jobs, wl);
        }
        let results: Vec<Result<Evaluation>> = par_map(jobs.len(), self.workers, |i| {
            let (arch, bcfg) = &jobs[i];
            self.evaluate(arch, *bcfg, wl)
        });
        results.into_iter().collect()
    }

    /// Work-stealing fan-out (ISSUE 10): jobs are claimed off a shared
    /// atomic cursor exactly once each; a worker whose claim loses a
    /// flight election steals further jobs through the same cursor
    /// while it waits, so grouped duplicates no longer serialize the
    /// pool. Output order matches input order and every value is
    /// bit-identical to the parked path — only idle time moves.
    fn evaluate_many_stealing(
        &self,
        jobs: &[(ArchConfig, BackendConfig)],
        wl: Option<&WorkloadSpec>,
    ) -> Result<Vec<Evaluation>> {
        struct StealCtx<'a> {
            svc: &'a EvalService,
            jobs: &'a [(ArchConfig, BackendConfig)],
            wl: Option<&'a WorkloadSpec>,
            next: AtomicUsize,
            slots: Vec<Mutex<Option<Result<Evaluation>>>>,
        }
        /// Claim one job off the cursor and run it to completion
        /// (recursively stealing while parked); false once the batch
        /// is exhausted.
        fn claim_and_run(ctx: &StealCtx<'_>, stolen: bool) -> bool {
            let i = ctx.next.fetch_add(1, Ordering::SeqCst);
            if i >= ctx.jobs.len() {
                return false;
            }
            if stolen {
                ctx.svc.counters.steals.fetch_add(1, Ordering::Relaxed);
            }
            let (arch, bcfg) = &ctx.jobs[i];
            let steal = || claim_and_run(ctx, true);
            let r = ctx.svc.evaluate_trial_with_steal(arch, *bcfg, ctx.wl, 0, Some(&steal));
            *ctx.slots[i].lock().unwrap() = Some(r);
            true
        }
        let ctx = StealCtx {
            svc: self,
            jobs,
            wl,
            next: AtomicUsize::new(0),
            slots: (0..jobs.len()).map(|_| Mutex::new(None)).collect(),
        };
        let threads = self.workers.min(jobs.len()).max(1);
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| while claim_and_run(&ctx, false) {});
            }
        });
        ctx.slots
            .into_iter()
            .map(|slot| {
                slot.into_inner()
                    .unwrap_or_else(|p| p.into_inner())
                    .expect("every claimed job fills its slot")
            })
            .collect()
    }

    /// Score a batch of feature rows through the two-stage surrogate:
    /// one flat-SoA classifier pass for the ROI gate, then one batched
    /// regressor pass per metric — bit-identical to per-row
    /// `prob`/`predict_one() + exp` reference walks.
    pub fn predict_batch(&self, feats: &[Vec<f64>]) -> Result<Vec<SurrogatePoint>> {
        let bundle = self
            .surrogate
            .as_ref()
            .context("EvalService has no surrogate attached (with_surrogate)")?;
        let n = feats.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        self.counters.surrogate_rows.fetch_add(n, Ordering::Relaxed);
        self.counters.surrogate_batches.fetch_add(1, Ordering::Relaxed);
        Ok(bundle
            .predict_batch(feats, self.workers)
            .into_iter()
            .map(|(in_roi, predicted)| SurrogatePoint { in_roi, predicted })
            .collect())
    }

    /// Route ANN surrogate traffic through the dynamic-batching
    /// `PredictServer` (one coalesced request per batch instead of
    /// per-row calls). Requires `attach_predict_client`.
    pub fn attach_predict_client(
        &mut self,
        client: PredictClient,
        variant: &str,
        theta: Vec<f32>,
    ) {
        *self.ann.lock().unwrap() =
            Some(AnnClient { client, variant: variant.to_string(), theta });
    }

    /// Batched ANN prediction via the attached `PredictServer` client.
    pub fn predict_ann_batch(&self, rows: &[Vec<f64>]) -> Result<Vec<f32>> {
        let ann = self
            .ann
            .lock()
            .unwrap()
            .clone()
            .context("no PredictServer client attached (attach_predict_client)")?;
        let rows32: Vec<Vec<f32>> = rows
            .iter()
            .map(|r| r.iter().map(|&v| v as f32).collect())
            .collect();
        self.counters.ann_rows.fetch_add(rows.len(), Ordering::Relaxed);
        self.counters.ann_batches.fetch_add(1, Ordering::Relaxed);
        ann.client.predict(&ann.variant, &ann.theta, rows32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators::Platform;
    use crate::workloads::NonDnnWorkload;

    fn mid_arch(p: Platform) -> ArchConfig {
        ArchConfig::new(
            p,
            p.param_space().iter().map(|s| s.kind.from_unit(0.5)).collect(),
        )
    }

    #[test]
    fn evaluate_matches_direct_flow_plus_simulator() {
        let arch = mid_arch(Platform::Axiline);
        let bcfg = BackendConfig::new(0.8, 0.5);
        let svc = EvalService::new(Enablement::Gf12, 7);
        let ev = svc.evaluate(&arch, bcfg, None).unwrap();

        let flow = SpnrFlow::new(Enablement::Gf12, 7);
        let fr = flow.run(&arch, bcfg).unwrap();
        let sys = simulate(&arch, &fr.backend, Enablement::Gf12).unwrap();
        assert_eq!(ev.flow.backend, fr.backend);
        assert_eq!(ev.flow.synth, fr.synth);
        assert_eq!(ev.system, sys);
    }

    #[test]
    fn cache_hits_on_repeat_and_results_are_identical() {
        let arch = mid_arch(Platform::Vta);
        let bcfg = BackendConfig::new(1.0, 0.4);
        let svc = EvalService::new(Enablement::Gf12, 1);
        let a = svc.evaluate(&arch, bcfg, None).unwrap();
        let b = svc.evaluate(&arch, bcfg, None).unwrap();
        assert_eq!(a.flow.backend, b.flow.backend);
        assert_eq!(a.system, b.system);
        let s = svc.stats();
        assert_eq!(s.oracle_misses, 1);
        assert_eq!(s.oracle_hits, 1);
        assert!(s.oracle_hit_rate() > 0.0);
        assert!(s.cache_hit_rate() > 0.0);
    }

    #[test]
    fn distinct_knobs_and_workloads_do_not_collide() {
        let arch = mid_arch(Platform::Axiline);
        let svc = EvalService::new(Enablement::Gf12, 1);
        let a = svc.evaluate(&arch, BackendConfig::new(0.8, 0.5), None).unwrap();
        let b = svc.evaluate(&arch, BackendConfig::new(0.9, 0.5), None).unwrap();
        assert_ne!(a.flow.backend.f_effective_ghz, b.flow.backend.f_effective_ghz);
        let wl = WorkloadSpec::NonDnn(NonDnnWorkload::standard(NonDnnAlgo::Svm, 55));
        let c = svc.evaluate(&arch, BackendConfig::new(0.8, 0.5), Some(&wl)).unwrap();
        // same flow result, workload-specific system metrics allowed to
        // differ; the cache must treat them as distinct entries
        assert_eq!(svc.stats().oracle_misses, 3);
        assert_eq!(a.flow.backend, c.flow.backend);
    }

    #[test]
    fn dnn_workload_overrides_are_distinct_cache_entries() {
        let arch = mid_arch(Platform::Vta);
        let svc = EvalService::new(Enablement::Gf12, 1);
        let bcfg = BackendConfig::new(0.9, 0.4);
        let a = svc.evaluate(&arch, bcfg, None).unwrap(); // default: mobilenet
        let tf = crate::workloads::lookup("transformer").unwrap();
        let b = svc.evaluate(&arch, bcfg, Some(&tf)).unwrap();
        let gc = crate::workloads::lookup("gcn").unwrap();
        let c = svc.evaluate(&arch, bcfg, Some(&gc)).unwrap();
        let s = svc.stats();
        assert_eq!(s.oracle_misses, 3, "each workload is its own oracle entry");
        assert_eq!(s.flow_runs, 1, "the SP&R flow is workload-independent");
        assert_eq!(a.flow.backend, b.flow.backend);
        // an 11-GMAC encoder and a 63-MMAC GCN cannot price the same
        assert_ne!(b.system, c.system);
        // an explicit mobilenet override is a distinct key from the
        // default binding but simulates identically
        let mb = crate::workloads::lookup("mobilenet").unwrap();
        let d = svc.evaluate(&arch, bcfg, Some(&mb)).unwrap();
        assert_eq!(d.system, a.system);
        assert_eq!(svc.stats().oracle_misses, 4);
    }

    #[test]
    fn evaluate_many_preserves_order_any_worker_count() {
        let archs: Vec<ArchConfig> = [0.2, 0.5, 0.8]
            .iter()
            .map(|&u| {
                ArchConfig::new(
                    Platform::Axiline,
                    Platform::Axiline
                        .param_space()
                        .iter()
                        .map(|s| s.kind.from_unit(u))
                        .collect(),
                )
            })
            .collect();
        let mut jobs = Vec::new();
        for a in &archs {
            for f in [0.5, 0.9, 1.3] {
                jobs.push((a.clone(), BackendConfig::new(f, 0.5)));
            }
        }
        let serial = EvalService::new(Enablement::Gf12, 3);
        let parallel = EvalService::new(Enablement::Gf12, 3).with_workers(4);
        let a = serial.evaluate_many(&jobs, None).unwrap();
        let b = parallel.evaluate_many(&jobs, None).unwrap();
        assert_eq!(a.len(), jobs.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.flow.backend, y.flow.backend);
            assert_eq!(x.system, y.system);
        }
    }

    #[test]
    fn stats_ratios_are_zero_not_nan_before_any_request() {
        // ISSUE 2 satellite: zero-denominator ratio helpers must report
        // 0.0 (a NaN here poisons every downstream aggregate/format)
        let s = EvalStats::default();
        assert_eq!(s.oracle_hit_rate(), 0.0);
        assert_eq!(s.cache_hit_rate(), 0.0);
        assert_eq!(s.mean_batch_occupancy(), 0.0);
        assert!(s.oracle_hit_rate().is_finite());
        assert!(s.cache_hit_rate().is_finite());
        assert!(s.mean_batch_occupancy().is_finite());
        let line = format!("{s}");
        assert!(!line.contains("NaN"), "stats line must not print NaN: {line}");
        // a fresh service reports the same zeroed, finite stats
        let svc = EvalService::new(Enablement::Gf12, 1);
        assert_eq!(svc.stats(), s);
    }

    #[test]
    fn cache_store_round_trips_through_service() {
        use crate::coordinator::cache_store::CacheStore;
        use std::sync::Arc;

        let dir = std::env::temp_dir()
            .join(format!("fso-eval-store-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let arch = mid_arch(Platform::Axiline);
        let bcfg = BackendConfig::new(0.8, 0.5);

        let cold_ev = {
            let store = Arc::new(CacheStore::open(&dir).unwrap());
            let svc = EvalService::new(Enablement::Gf12, 7).with_cache_store(store);
            let ev = svc.evaluate(&arch, bcfg, None).unwrap();
            let s = svc.stats();
            assert_eq!(s.oracle_misses, 1);
            assert_eq!(s.disk_hits, 0, "cold run must not report disk hits");
            assert!(svc.flush_cache().unwrap() > 0, "one shard should flush");
            ev
        };

        // fresh service + reopened store: served from disk, no oracle run
        let store = Arc::new(CacheStore::open(&dir).unwrap());
        let svc = EvalService::new(Enablement::Gf12, 7).with_cache_store(store);
        let warm_ev = svc.evaluate(&arch, bcfg, None).unwrap();
        assert_eq!(warm_ev.flow.backend, cold_ev.flow.backend);
        assert_eq!(warm_ev.flow.synth, cold_ev.flow.synth);
        assert_eq!(warm_ev.system, cold_ev.system);
        let s = svc.stats();
        assert_eq!(s.oracle_misses, 0, "warm run must not re-run the oracle");
        assert_eq!(s.disk_hits, 1);
        // storage engine v2: the point lookup is answered by the shard's
        // `.idx` sidecar — one frame fetch, zero shard scans
        assert!(s.sidecar_hits > 0, "warm lookup must go through the sidecar: {s}");
        assert_eq!(s.shard_loads, 0, "sidecar lookup must not scan a shard: {s}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn coalescing_is_invisible_to_results_and_counter_totals() {
        // ISSUE 5: a coalesced service must report the same hit/miss
        // totals and values as the uncoalesced one — on a serial
        // workload the single-flight layer is pure pass-through
        let arch = mid_arch(Platform::Vta);
        let bcfg = BackendConfig::new(1.0, 0.4);
        let plain = EvalService::new(Enablement::Gf12, 1);
        let coal = EvalService::new(Enablement::Gf12, 1).with_coalescing(true);
        assert!(coal.coalescing() && !plain.coalescing());
        for svc in [&plain, &coal] {
            let a = svc.evaluate(&arch, bcfg, None).unwrap();
            let b = svc.evaluate(&arch, bcfg, None).unwrap();
            assert_eq!(a.flow.backend, b.flow.backend);
            assert_eq!(a.system, b.system);
        }
        let (p, c) = (plain.stats(), coal.stats());
        assert_eq!(
            plain.evaluate(&arch, bcfg, None).unwrap().flow.backend,
            coal.evaluate(&arch, bcfg, None).unwrap().flow.backend
        );
        assert_eq!(p.oracle_hits, c.oracle_hits);
        assert_eq!(p.oracle_misses, c.oracle_misses);
        assert_eq!(p.oracle_runs, c.oracle_runs);
        assert_eq!(c.oracle_runs, 1);
        assert_eq!(c.flow_runs, 1);
        assert_eq!(c.coalesced_hits, 0, "serial calls never wait on a flight");
        assert_eq!(c.inflight_peak, 1);
    }

    #[test]
    fn oracle_runs_counter_tracks_actual_work() {
        // distinct points, serial service: runs == misses == points
        let svc = EvalService::new(Enablement::Gf12, 3);
        let arch = mid_arch(Platform::Axiline);
        for f in [0.6, 0.9, 1.2] {
            svc.evaluate(&arch, BackendConfig::new(f, 0.5), None).unwrap();
        }
        let s = svc.stats();
        assert_eq!(s.oracle_runs, 3);
        assert_eq!(s.flow_runs, 3);
        assert_eq!(s.oracle_misses, 3);
        // a workload revisit reuses the flow: one more oracle run (the
        // cheap simulator pass) but no new flow run
        let wl = WorkloadSpec::NonDnn(NonDnnWorkload::standard(NonDnnAlgo::Svm, 55));
        svc.evaluate(&arch, BackendConfig::new(0.6, 0.5), Some(&wl)).unwrap();
        let s = svc.stats();
        assert_eq!(s.oracle_runs, 4);
        assert_eq!(s.flow_runs, 3, "the SP&R flow is shared across workloads");
    }

    #[test]
    fn work_stealing_matches_parked_values_and_counters() {
        // grouped duplicates so waiters actually park on flights
        let arch = mid_arch(Platform::Axiline);
        let mut jobs = Vec::new();
        for f in [0.6, 0.9, 1.2] {
            for _ in 0..4 {
                jobs.push((arch.clone(), BackendConfig::new(f, 0.5)));
            }
        }
        let parked = EvalService::new(Enablement::Gf12, 5).with_workers(4).with_coalescing(true);
        let stealing = EvalService::new(Enablement::Gf12, 5)
            .with_workers(4)
            .with_coalescing(true)
            .with_work_stealing(true);
        assert!(stealing.work_stealing() && !parked.work_stealing());
        let a = parked.evaluate_many(&jobs, None).unwrap();
        let b = stealing.evaluate_many(&jobs, None).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.flow.backend, y.flow.backend);
            assert_eq!(x.system, y.system);
        }
        let (p, s) = (parked.stats(), stealing.stats());
        assert_eq!(p.oracle_runs, 3, "one run per unique key");
        assert_eq!(s.oracle_runs, 3, "stealing keeps one run per unique key");
        assert_eq!(p.steals, 0, "parked mode never steals");
        // `s.steals` is schedule-dependent (waiters only steal while a
        // flight is actually open) — any value is valid here; the
        // bench suite pins the wall-clock benefit
    }

    #[test]
    fn remote_oracle_seam_matches_local_run_and_counters() {
        struct LocalRemote {
            inner: EvalService,
            calls: AtomicUsize,
        }
        impl RemoteOracle for LocalRemote {
            fn evaluate_remote(&self, t: &RemoteTask<'_>) -> Result<Evaluation> {
                self.calls.fetch_add(1, Ordering::SeqCst);
                self.inner.evaluate_trial(t.arch, t.bcfg, t.wl, t.trial)
            }
        }
        let arch = mid_arch(Platform::Vta);
        let bcfg = BackendConfig::new(1.0, 0.4);
        let local = EvalService::new(Enablement::Gf12, 9);
        let want = local.evaluate(&arch, bcfg, None).unwrap();
        let remote = Arc::new(LocalRemote {
            inner: EvalService::new(Enablement::Gf12, 9),
            calls: AtomicUsize::new(0),
        });
        let svc = EvalService::new(Enablement::Gf12, 9).with_remote_oracle(remote.clone());
        let got = svc.evaluate(&arch, bcfg, None).unwrap();
        assert_eq!(got.flow.backend, want.flow.backend);
        assert_eq!(got.flow.synth, want.flow.synth);
        assert_eq!(got.system, want.system);
        // memo hit on repeat: no second dispatch
        svc.evaluate(&arch, bcfg, None).unwrap();
        assert_eq!(remote.calls.load(Ordering::SeqCst), 1);
        let s = svc.stats();
        assert_eq!(s.oracle_misses, 1);
        assert_eq!(s.oracle_hits, 1);
        assert_eq!(s.oracle_runs, 1);
        assert_eq!(s.flow_runs, 0, "the flow ran on the remote side");
    }

    #[test]
    fn trial_streams_are_deterministic_and_distinct() {
        let arch = mid_arch(Platform::GeneSys);
        let bcfg = BackendConfig::new(0.9, 0.4);
        let s1 = EvalService::new(Enablement::Gf12, 11);
        let s2 = EvalService::new(Enablement::Gf12, 11);
        let a = s1.evaluate_trial(&arch, bcfg, None, 1).unwrap();
        let b = s2.evaluate_trial(&arch, bcfg, None, 1).unwrap();
        assert_eq!(a.flow.backend, b.flow.backend);
        let base = s1.evaluate_trial(&arch, bcfg, None, 0).unwrap();
        assert_ne!(a.flow.backend.f_effective_ghz, base.flow.backend.f_effective_ghz);
    }
}
