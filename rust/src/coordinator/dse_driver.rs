//! DSE driver (paper §8.4): MOTPE proposes (architecture, backend)
//! knobs; trained two-stage models predict the five metrics; ROI +
//! power/runtime constraints gate feasibility; the Pareto front of
//! (energy, area) accumulates; the Eq. 3 cost picks the winners; and
//! the ground-truth oracle (full flow + simulator) scores the top-k —
//! the paper's "within 6-7% of post-SP&R" check.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::backend::{roi_epsilon, BackendConfig, Enablement, SpnrFlow};
use crate::data::{Dataset, Metric, Split};
use crate::dse::{select_best, Candidate, CostSpec, Motpe, MotpeConfig};
use crate::generators::{unified_features, ArchConfig, ParamKind, ParamSpec, Platform};
use crate::models::{Gbdt, GbdtParams, RoiClassifier};
use crate::simulators::{simulate, simulate_nondnn};
use crate::workloads::{NonDnnAlgo, NonDnnWorkload};

/// The trained predictor bundle the DSE consults (two-stage: ROI
/// classifier + per-metric GBDT regressors — the fastest family at
/// equal accuracy on our data, exactly the surrogate role MOTPE needs).
pub struct SurrogateBundle {
    pub classifier: RoiClassifier,
    pub regressors: BTreeMap<Metric, Gbdt>,
}

impl SurrogateBundle {
    /// Fit on a generated dataset's training rows.
    pub fn fit(ds: &Dataset, split: &Split, seed: u64) -> Result<SurrogateBundle> {
        let x_all = ds.features(&split.train);
        let roi = ds.roi_labels(&split.train);
        let classifier = RoiClassifier::fit(&x_all, &roi, seed);
        let train_roi = ds.roi_subset(&split.train);
        anyhow::ensure!(!train_roi.is_empty(), "no ROI rows to fit on");
        let x = ds.features(&train_roi);
        let mut regressors = BTreeMap::new();
        for m in Metric::ALL {
            // all five metrics are positive with wide dynamic range across
            // the design space: fit in log space so small designs are not
            // swamped by large ones (relative accuracy is what the DSE
            // ground-truth check measures)
            let y: Vec<f64> = ds
                .targets(&train_roi, m)
                .iter()
                .map(|v| v.max(1e-30).ln())
                .collect();
            let model = Gbdt::fit(&x, &y, GbdtParams::default(), seed ^ m.name().len() as u64);
            regressors.insert(m, model);
        }
        Ok(SurrogateBundle { classifier, regressors })
    }

    pub fn predict(&self, feats: &[f64]) -> (bool, BTreeMap<Metric, f64>) {
        let in_roi = self.classifier.prob(feats) >= 0.5;
        let mut out = BTreeMap::new();
        for (m, model) in &self.regressors {
            out.insert(*m, model.predict_one(feats).exp());
        }
        (in_roi, out)
    }
}

/// What the DSE explores: a subset of architectural knobs (the rest
/// frozen at `base_arch`) plus the two backend knobs.
pub struct DseProblem {
    pub base_arch: ArchConfig,
    /// Names of architectural parameters to expose to MOTPE (with
    /// optional narrowed ranges); empty = backend-only DSE (Fig. 12).
    pub arch_knobs: Vec<ParamSpec>,
    pub f_target_range: (f64, f64),
    pub util_range: (f64, f64),
    pub cost: CostSpec,
    /// Explicit workload override for non-DNN platforms (e.g. the
    /// paper's SVM-55 for Axiline).
    pub workload: Option<NonDnnWorkload>,
}

impl DseProblem {
    fn space(&self) -> Vec<ParamSpec> {
        let mut space = self.arch_knobs.clone();
        space.push(ParamSpec {
            name: "f_target",
            kind: ParamKind::Float { lo: self.f_target_range.0, hi: self.f_target_range.1 },
        });
        space.push(ParamSpec {
            name: "util",
            kind: ParamKind::Float { lo: self.util_range.0, hi: self.util_range.1 },
        });
        space
    }

    /// Materialize a proposal into (arch config, backend config).
    fn decode(&self, x: &[f64]) -> (ArchConfig, BackendConfig) {
        let mut arch = self.base_arch.clone();
        let arch_space = arch.platform.param_space();
        for (k, spec) in self.arch_knobs.iter().enumerate() {
            let idx = arch_space
                .iter()
                .position(|s| s.name == spec.name)
                .unwrap_or_else(|| panic!("unknown arch knob {}", spec.name));
            arch.values[idx] = x[k];
        }
        let n = self.arch_knobs.len();
        (arch, BackendConfig::new(x[n], x[n + 1]))
    }
}

/// One explored DSE point, predicted and (optionally) ground-truthed.
#[derive(Debug, Clone)]
pub struct DsePoint {
    pub x: Vec<f64>,
    pub predicted: BTreeMap<Metric, f64>,
    pub feasible: bool,
}

pub struct DseOutcome {
    pub points: Vec<DsePoint>,
    /// Indices of the Eq.-3 winners (into `points`).
    pub best: Vec<usize>,
    /// Per-winner, per-metric relative error |pred - truth| / truth.
    pub ground_truth_errors: Vec<BTreeMap<Metric, f64>>,
}

pub struct DseDriver {
    pub enablement: Enablement,
    pub surrogate: SurrogateBundle,
    pub flow_seed: u64,
}

impl DseDriver {
    /// Run MOTPE for `iterations`, then ground-truth the top-k winners.
    pub fn run(
        &self,
        problem: &DseProblem,
        iterations: usize,
        top_k: usize,
        motpe_cfg: MotpeConfig,
    ) -> Result<DseOutcome> {
        let mut motpe = Motpe::new(problem.space(), motpe_cfg);
        let mut points = Vec::with_capacity(iterations);

        for _ in 0..iterations {
            let x = motpe.ask();
            let (arch, bcfg) = problem.decode(&x);
            let tree = arch.platform.generate(&arch)?;
            let agg = tree.aggregates();
            let feats = unified_features(
                &arch,
                bcfg.f_target_ghz,
                bcfg.util,
                agg.comb_cells,
                agg.macro_bits,
            );
            let (in_roi, pred) = self.surrogate.predict(&feats);
            let feasible = in_roi
                && problem.cost.feasible(pred[&Metric::Power], pred[&Metric::Runtime]);
            let objectives = vec![pred[&Metric::Energy], pred[&Metric::Area]];
            motpe.tell(x.clone(), objectives, feasible);
            points.push(DsePoint { x, predicted: pred, feasible });
        }

        // Eq. 3 selection over the feasible Pareto set. MOTPE converges
        // onto good configurations and proposes them repeatedly — dedup
        // by knob vector so top-k names k *distinct* designs.
        let mut seen = std::collections::BTreeSet::new();
        let mut candidates: Vec<Candidate> = Vec::new();
        let mut cand_to_point = Vec::new();
        for (i, p) in points.iter().enumerate() {
            let key: Vec<u64> = p.x.iter().map(|v| v.to_bits()).collect();
            if !seen.insert(key) {
                continue;
            }
            candidates.push(Candidate {
                x: p.x.clone(),
                energy_j: p.predicted[&Metric::Energy],
                runtime_s: p.predicted[&Metric::Runtime],
                power_w: p.predicted[&Metric::Power],
                area_mm2: p.predicted[&Metric::Area],
                in_roi: p.feasible,
            });
            cand_to_point.push(i);
        }
        let best: Vec<usize> = select_best(&candidates, &problem.cost, top_k)
            .into_iter()
            .map(|c| cand_to_point[c])
            .collect();

        // ground truth: full SP&R oracle + simulator on the winners
        let flow = SpnrFlow::new(self.enablement, self.flow_seed);
        let mut ground_truth_errors = Vec::new();
        for &bi in &best {
            let (arch, bcfg) = problem.decode(&points[bi].x);
            let fr = flow.run(&arch, bcfg)?;
            let sys = match problem.workload {
                Some(wl) => simulate_nondnn(&arch, &fr.backend, self.enablement, &wl)?,
                None => simulate(&arch, &fr.backend, self.enablement)?,
            };
            let truth: BTreeMap<Metric, f64> = BTreeMap::from([
                (Metric::Power, fr.backend.total_power_w()),
                (Metric::Performance, fr.backend.f_effective_ghz),
                (Metric::Area, fr.backend.chip_area_mm2),
                (Metric::Energy, sys.energy_j),
                (Metric::Runtime, sys.runtime_s),
            ]);
            let mut errs = BTreeMap::new();
            for m in Metric::ALL {
                let p = points[bi].predicted[&m];
                errs.insert(m, (p - truth[&m]).abs() / truth[&m].abs().max(1e-12));
            }
            ground_truth_errors.push(errs);
        }

        Ok(DseOutcome { points, best, ground_truth_errors })
    }
}

/// The paper's Axiline-SVM-55 DSE problem (§8.4): size 10-51, cycles
/// 5-21, f_target 0.3-1.3 GHz, util 0.4-0.8, alpha=1, beta=0.001.
pub fn axiline_svm_problem(p_max: f64, r_max: f64) -> DseProblem {
    let platform = Platform::Axiline;
    let space = platform.param_space();
    let mut base = ArchConfig::new(
        platform,
        space.iter().map(|s| s.kind.from_unit(0.5)).collect(),
    );
    // benchmark = svm
    let bidx = space.iter().position(|s| s.name == "benchmark").unwrap();
    base.values[bidx] = 0.0;
    DseProblem {
        base_arch: base,
        arch_knobs: vec![
            ParamSpec { name: "dimension", kind: ParamKind::Int { lo: 10, hi: 51 } },
            ParamSpec { name: "num_cycles", kind: ParamKind::Int { lo: 5, hi: 21 } },
        ],
        f_target_range: (0.3, 1.3),
        util_range: (0.4, 0.8),
        cost: CostSpec { alpha: 1.0, beta: 0.001, p_max, r_max },
        workload: Some(NonDnnWorkload::standard(NonDnnAlgo::Svm, 55)),
    }
}

/// The paper's VTA backend-only DSE (§8.4): f_target 0.3-1.3 GHz, util
/// 0.25-0.55, alpha=beta=1.
pub fn vta_backend_problem(base: ArchConfig, p_max: f64, r_max: f64) -> DseProblem {
    DseProblem {
        base_arch: base,
        arch_knobs: vec![],
        f_target_range: (0.3, 1.3),
        util_range: (0.25, 0.55),
        cost: CostSpec { alpha: 1.0, beta: 1.0, p_max, r_max },
        workload: None,
    }
}
