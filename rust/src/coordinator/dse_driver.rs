//! DSE driver (paper §8.4): a [`DseStrategy`] (MOTPE by default — see
//! `dse/strategy.rs` for the zoo) proposes (architecture, backend)
//! knobs in batches; the trained two-stage models predict the five
//! metrics through the `EvalService`'s batched surrogate path; ROI +
//! power/runtime constraints gate feasibility; the Pareto front of
//! (energy, area) accumulates; the Eq. 3 cost picks the winners; and
//! the ground-truth oracle (full flow + simulator) scores the top-k
//! through the same service — memoized and fanned out over the worker
//! pool — the paper's "within 6-7% of post-SP&R" check.
//!
//! Determinism contract: the proposal trajectory depends only on the
//! strategy (and its seed) and the batch size (`run_batched`'s
//! `batch`), never on the worker count. `run` uses batch 1, which
//! reproduces the historical serial ask/tell loop exactly. The
//! `MotpeConfig`-taking entry points (`run`/`run_batched`/
//! `run_pipelined`) are thin wrappers that build a fresh MOTPE and
//! delegate to the strategy-generic `*_with` flavors, so the default
//! cell is byte-identical to the pre-seam driver.

use std::collections::BTreeMap;
use std::sync::{mpsc, Mutex};

use anyhow::{Context, Result};

use crate::backend::{BackendConfig, Enablement};
use crate::data::{Dataset, Metric, Split};
use crate::dse::{select_best, Candidate, CostSpec, DseStrategy, MotpeConfig, StrategyKind};
use crate::generators::{ArchConfig, ParamKind, ParamSpec, Platform};
use crate::models::{Gbdt, GbdtParams, RoiClassifier};
use crate::util::json::Json;
use crate::util::pool::{default_workers, par_map};
use crate::workloads::{NonDnnAlgo, NonDnnWorkload, WorkloadSpec};

use super::coalesce;
use super::eval_service::{EvalService, EvalStats, SurrogatePoint};
use super::model_store::{ModelKey, ModelStore};

/// The trained predictor bundle the DSE consults (two-stage: ROI
/// classifier + per-metric GBDT regressors — the fastest family at
/// equal accuracy on our data, exactly the surrogate role MOTPE needs).
pub struct SurrogateBundle {
    pub classifier: RoiClassifier,
    pub regressors: BTreeMap<Metric, Gbdt>,
}

impl SurrogateBundle {
    /// Fit on a generated dataset's training rows. The five per-metric
    /// regressors are independent, so they fit across the worker pool;
    /// each keeps its historical seed, so the models are byte-identical
    /// to a serial fit.
    pub fn fit(ds: &Dataset, split: &Split, seed: u64) -> Result<SurrogateBundle> {
        let x_all = ds.features(&split.train);
        let roi = ds.roi_labels(&split.train);
        let classifier = RoiClassifier::fit(&x_all, &roi, seed);
        let train_roi = ds.roi_subset(&split.train);
        anyhow::ensure!(!train_roi.is_empty(), "no ROI rows to fit on");
        let x = ds.features(&train_roi);
        // all five metrics are positive with wide dynamic range across
        // the design space: fit in log space so small designs are not
        // swamped by large ones (relative accuracy is what the DSE
        // ground-truth check measures)
        let models: Vec<Gbdt> = par_map(Metric::ALL.len(), default_workers(), |k| {
            let m = Metric::ALL[k];
            let y: Vec<f64> = ds
                .targets(&train_roi, m)
                .iter()
                .map(|v| v.max(1e-30).ln())
                .collect();
            Gbdt::fit(&x, &y, GbdtParams::default(), seed ^ m.name().len() as u64)
        });
        let mut regressors = BTreeMap::new();
        for (m, model) in Metric::ALL.into_iter().zip(models) {
            regressors.insert(m, model);
        }
        Ok(SurrogateBundle { classifier, regressors })
    }

    /// Batched two-stage scoring — the single home of the 0.5 ROI
    /// threshold and the log-space `.exp()` inverse. One flat-forest
    /// batch for the classifier probabilities (row-chunked across the
    /// workers) and one flat-forest batch per metric regressor
    /// (metric-parallel): exactly `1 + Metric::ALL.len()` batch-major
    /// passes per call, no per-row fallback anywhere — the call-count
    /// regression test in `tests/flat_tree.rs` pins that. Parallelism
    /// never changes values (chunking and `par_map` preserve order).
    pub fn predict_batch(
        &self,
        feats: &[Vec<f64>],
        workers: usize,
    ) -> Vec<(bool, BTreeMap<Metric, f64>)> {
        let n = feats.len();
        if n == 0 {
            return Vec::new();
        }
        let probs: Vec<f64> = self.classifier.probs_with(feats, workers);
        let metric_preds: Vec<Vec<f64>> = par_map(Metric::ALL.len(), workers, |k| {
            let m = Metric::ALL[k];
            self.regressors[&m]
                .predict(feats)
                .into_iter()
                .map(|v| v.exp())
                .collect()
        });
        (0..n)
            .map(|i| {
                let mut out = BTreeMap::new();
                for (k, m) in Metric::ALL.into_iter().enumerate() {
                    out.insert(m, metric_preds[k][i]);
                }
                (probs[i] >= 0.5, out)
            })
            .collect()
    }

    pub fn predict(&self, feats: &[f64]) -> (bool, BTreeMap<Metric, f64>) {
        self.predict_batch(&[feats.to_vec()], 1)
            .pop()
            .expect("one row in, one prediction out")
    }

    /// Aggregated (flat batch invocations, rows scored) across the
    /// classifier and every metric regressor. A `predict_batch` of `n`
    /// rows adds exactly `1 + Metric::ALL.len()` batches and
    /// `(1 + Metric::ALL.len()) * n` rows — the call-count regression
    /// test's probe that no caller degrades to per-row scoring.
    pub fn flat_stats(&self) -> (usize, usize) {
        let (mut batches, mut rows) = self.classifier.flat_stats();
        for reg in self.regressors.values() {
            let (b, r) = reg.flat_stats();
            batches += b;
            rows += r;
        }
        (batches, rows)
    }

    /// Model-store family tag for persisted bundles.
    pub const STORE_KIND: &'static str = "surrogate-bundle";

    /// Content-hash key for the fitted bundle: everything `fit` is a
    /// pure function of — the training features, ROI labels, every
    /// per-metric target vector, and the seed.
    pub fn store_key(ds: &Dataset, split: &Split, seed: u64) -> u64 {
        let mut key = ModelKey::new(Self::STORE_KIND)
            .rows(&ds.features(&split.train))
            .bools(&ds.roi_labels(&split.train))
            .u64(seed);
        for m in Metric::ALL {
            key = key.f64s(&ds.targets(&split.train, m));
        }
        key.finish()
    }

    /// Model-store serialization (bit-exact prediction replay — the
    /// warm DSE trajectory and Pareto front are byte-identical).
    pub fn to_json(&self) -> Json {
        let regs: Vec<(&str, Json)> = Metric::ALL
            .iter()
            .map(|m| (m.name(), self.regressors[m].to_json()))
            .collect();
        Json::obj(vec![
            ("classifier", self.classifier.to_json()),
            ("regressors", Json::obj(regs)),
        ])
    }

    /// Strict inverse of `to_json`: `None` on any defect (missing
    /// metric, corrupt tree), so callers fall back to refitting.
    pub fn from_json(j: &Json) -> Option<SurrogateBundle> {
        let classifier = RoiClassifier::from_json(j.get("classifier"))?;
        let mut regressors = BTreeMap::new();
        for m in Metric::ALL {
            regressors.insert(m, Gbdt::from_json(j.get("regressors").get(m.name()))?);
        }
        Some(SurrogateBundle { classifier, regressors })
    }

    /// Read-through `fit` (ISSUE 3): serve the bundle from the model
    /// store when an artifact for these exact inputs exists —
    /// bit-identical predictions, zero refits — and fit + write-behind
    /// otherwise (durable at the caller's flush). A corrupt artifact
    /// reads as a miss: the fallback refit repairs it. Returns the
    /// bundle and whether it was served from the store.
    pub fn fit_cached(
        ds: &Dataset,
        split: &Split,
        seed: u64,
        store: Option<&ModelStore>,
    ) -> Result<(SurrogateBundle, bool)> {
        let Some(store) = store else {
            return Ok((SurrogateBundle::fit(ds, split, seed)?, false));
        };
        let key = Self::store_key(ds, split, seed);
        if let Some(payload) = store.get(Self::STORE_KIND, key) {
            if let Some(bundle) = SurrogateBundle::from_json(&payload) {
                return Ok((bundle, true));
            }
        }
        let bundle = SurrogateBundle::fit(ds, split, seed)?;
        store.put(Self::STORE_KIND, key, bundle.to_json());
        Ok((bundle, false))
    }
}

/// What the DSE explores: a subset of architectural knobs (the rest
/// frozen at `base_arch`) plus the two backend knobs.
pub struct DseProblem {
    pub base_arch: ArchConfig,
    /// Names of architectural parameters to expose to MOTPE (with
    /// optional narrowed ranges); empty = backend-only DSE (Fig. 12).
    pub arch_knobs: Vec<ParamSpec>,
    pub f_target_range: (f64, f64),
    pub util_range: (f64, f64),
    pub cost: CostSpec,
    /// Explicit workload override routed into the oracle simulators:
    /// a registry non-DNN spec (e.g. the paper's SVM-55 for Axiline)
    /// or a DNN layer table (e.g. `transformer` on VTA). `None` keeps
    /// the platform's default binding.
    pub workload: Option<WorkloadSpec>,
}

impl DseProblem {
    /// The proposal space a strategy optimizes over: the exposed arch
    /// knobs plus the two backend knobs (public so callers can build
    /// `DseStrategy` instances for the `*_with` run flavors).
    pub fn space(&self) -> Vec<ParamSpec> {
        let mut space = self.arch_knobs.clone();
        space.push(ParamSpec {
            name: "f_target",
            kind: ParamKind::Float { lo: self.f_target_range.0, hi: self.f_target_range.1 },
        });
        space.push(ParamSpec {
            name: "util",
            kind: ParamKind::Float { lo: self.util_range.0, hi: self.util_range.1 },
        });
        space
    }

    /// Materialize a proposal into (arch config, backend config).
    fn decode(&self, x: &[f64]) -> (ArchConfig, BackendConfig) {
        let mut arch = self.base_arch.clone();
        let arch_space = arch.platform.param_space();
        for (k, spec) in self.arch_knobs.iter().enumerate() {
            let idx = arch_space
                .iter()
                .position(|s| s.name == spec.name)
                .unwrap_or_else(|| panic!("unknown arch knob {}", spec.name));
            arch.values[idx] = x[k];
        }
        let n = self.arch_knobs.len();
        (arch, BackendConfig::new(x[n], x[n + 1]))
    }
}

/// One explored DSE point, predicted and (optionally) ground-truthed.
#[derive(Debug, Clone, PartialEq)]
pub struct DsePoint {
    pub x: Vec<f64>,
    pub predicted: BTreeMap<Metric, f64>,
    pub feasible: bool,
}

pub struct DseOutcome {
    pub points: Vec<DsePoint>,
    /// Indices of the Eq.-3 winners (into `points`).
    pub best: Vec<usize>,
    /// Per-winner, per-metric relative error |pred - truth| / truth.
    pub ground_truth_errors: Vec<BTreeMap<Metric, f64>>,
}

impl DseOutcome {
    /// Indices (into `points`) of the feasible predicted-(energy, area)
    /// Pareto front — the determinism regression target.
    pub fn pareto_front(&self) -> Vec<usize> {
        let feasible: Vec<usize> =
            (0..self.points.len()).filter(|&i| self.points[i].feasible).collect();
        let objs: Vec<Vec<f64>> = feasible
            .iter()
            .map(|&i| {
                vec![
                    self.points[i].predicted[&Metric::Energy],
                    self.points[i].predicted[&Metric::Area],
                ]
            })
            .collect();
        crate::dse::pareto_front(&objs)
            .into_iter()
            .map(|k| feasible[k])
            .collect()
    }
}

/// Strategy + surrogate + oracle, glued together by the `EvalService`.
pub struct DseDriver {
    pub service: EvalService,
}

/// Apply one scored proposal in ask order: the Eq. 3 feasibility gate,
/// the (energy, area) objectives, the strategy tell, and the recorded
/// point. One home, shared by the strict-alternation and pipelined run
/// flavors, so the two cadences can never diverge.
fn tell_scored(
    problem: &DseProblem,
    strategy: &mut dyn DseStrategy,
    points: &mut Vec<DsePoint>,
    x: Vec<f64>,
    sp: SurrogatePoint,
) {
    let feasible = sp.in_roi
        && problem
            .cost
            .feasible(sp.predicted[&Metric::Power], sp.predicted[&Metric::Runtime]);
    let objectives = vec![sp.predicted[&Metric::Energy], sp.predicted[&Metric::Area]];
    strategy.tell(x.clone(), objectives, feasible);
    points.push(DsePoint { x, predicted: sp.predicted, feasible });
}

impl DseDriver {
    /// Build a driver whose service owns the surrogate and a flow
    /// seeded with `flow_seed` (serial until `with_workers`).
    pub fn new(enablement: Enablement, surrogate: SurrogateBundle, flow_seed: u64) -> DseDriver {
        DseDriver {
            service: EvalService::new(enablement, flow_seed).with_surrogate(surrogate),
        }
    }

    /// Parallel ground-truth / surrogate fan-out (results unchanged).
    pub fn with_workers(mut self, workers: usize) -> DseDriver {
        self.service = self.service.with_workers(workers);
        self
    }

    pub fn stats(&self) -> EvalStats {
        self.service.stats()
    }

    /// Run MOTPE for `iterations` with the historical serial ask/tell
    /// cadence (batch 1), then ground-truth the top-k winners.
    pub fn run(
        &self,
        problem: &DseProblem,
        iterations: usize,
        top_k: usize,
        motpe_cfg: MotpeConfig,
    ) -> Result<DseOutcome> {
        self.run_batched(problem, iterations, top_k, motpe_cfg, 1)
    }

    /// Any-strategy `run`: serial ask/tell cadence (batch 1).
    pub fn run_with(
        &self,
        problem: &DseProblem,
        strategy: Box<dyn DseStrategy>,
        iterations: usize,
        top_k: usize,
    ) -> Result<DseOutcome> {
        self.run_batched_with(problem, strategy, iterations, top_k, 1)
    }

    /// Run MOTPE for `iterations`, requesting suggestions in batches of
    /// `batch` and scoring each batch through the service's batched
    /// surrogate path, then ground-truth the top-k winners through the
    /// memoized parallel oracle.
    pub fn run_batched(
        &self,
        problem: &DseProblem,
        iterations: usize,
        top_k: usize,
        motpe_cfg: MotpeConfig,
        batch: usize,
    ) -> Result<DseOutcome> {
        let strategy = StrategyKind::Motpe.build(problem.space(), &motpe_cfg);
        self.run_batched_with(problem, strategy, iterations, top_k, batch)
    }

    /// Strategy-generic `run_batched`: the strategy asks in batches of
    /// `batch`, each batch is scored through the service's batched
    /// surrogate path, and every tell lands in ask order.
    pub fn run_batched_with(
        &self,
        problem: &DseProblem,
        mut strategy: Box<dyn DseStrategy>,
        iterations: usize,
        top_k: usize,
        batch: usize,
    ) -> Result<DseOutcome> {
        let batch = batch.max(1);
        let mut points = Vec::with_capacity(iterations);

        let mut remaining = iterations;
        while remaining > 0 {
            let b = batch.min(remaining);
            let xs = strategy.ask_batch(b);
            let mut feats = Vec::with_capacity(b);
            for x in &xs {
                let (arch, bcfg) = problem.decode(x);
                feats.push(self.service.features(&arch, bcfg)?.to_vec());
            }
            let scored = self.service.predict_batch(&feats)?;
            for (x, sp) in xs.into_iter().zip(scored) {
                tell_scored(problem, strategy.as_mut(), &mut points, x, sp);
            }
            remaining -= b;
        }

        self.select_and_ground_truth(problem, points, top_k)
    }

    /// `run_batched` with the proposal and scoring stages overlapped
    /// (ISSUE 5): the calling thread keeps generating the current
    /// batch's MOTPE proposals while up to `inflight` scoring workers
    /// decode, featurize, and score already-asked proposals through a
    /// scoped [`coalesce::serve_scoped`] router — so concurrent
    /// workers' rows coalesce into metric-major mega-batches.
    ///
    /// Byte-identical to `run_batched` at the same seed and batch
    /// size: `ask_batch(n)` is exactly `n` sequential `ask` calls with
    /// no intermediate observations, proposals are scored row-
    /// independently, and every `tell` is applied in ask order after
    /// the whole batch is scored — only wall-clock changes.
    pub fn run_pipelined(
        &self,
        problem: &DseProblem,
        iterations: usize,
        top_k: usize,
        motpe_cfg: MotpeConfig,
        batch: usize,
        inflight: usize,
    ) -> Result<DseOutcome> {
        let strategy = StrategyKind::Motpe.build(problem.space(), &motpe_cfg);
        self.run_pipelined_with(problem, strategy, iterations, top_k, batch, inflight)
    }

    /// Strategy-generic `run_pipelined`. The same byte-identity
    /// argument as above holds for every strategy in the zoo: `ask`
    /// consumes only the strategy's private RNG stream and its tell
    /// log, and tells land in ask order after the batch is scored.
    pub fn run_pipelined_with(
        &self,
        problem: &DseProblem,
        mut strategy: Box<dyn DseStrategy>,
        iterations: usize,
        top_k: usize,
        batch: usize,
        inflight: usize,
    ) -> Result<DseOutcome> {
        let batch = batch.max(1);
        let inflight = inflight.max(1);
        let service = &self.service;
        let mut points: Vec<DsePoint> = Vec::with_capacity(iterations);

        let mut remaining = iterations;
        while remaining > 0 {
            let b = batch.min(remaining);
            let mut xs: Vec<Vec<f64>> = Vec::with_capacity(b);
            let slots: Vec<Mutex<Option<Result<SurrogatePoint>>>> =
                (0..b).map(|_| Mutex::new(None)).collect();
            let (jtx, jrx) = mpsc::channel::<(usize, ArchConfig, BackendConfig)>();
            let jrx = Mutex::new(jrx);
            std::thread::scope(|scope| {
                let router = coalesce::serve_scoped(scope, service);
                for _ in 0..inflight {
                    let client = router.clone();
                    let jrx = &jrx;
                    let slots = &slots;
                    scope.spawn(move || loop {
                        // take one job at a time: whichever worker is
                        // free scores the next asked proposal
                        let job = jrx.lock().unwrap().recv();
                        let (i, arch, bcfg) = match job {
                            Ok(j) => j,
                            Err(_) => break, // batch fully asked + dispatched
                        };
                        let scored = (|| {
                            let feats = service.features(&arch, bcfg)?;
                            let mut out = client.predict(vec![feats.to_vec()])?;
                            out.pop().context("router returned an empty batch for one row")
                        })();
                        *slots[i].lock().unwrap() = Some(scored);
                    });
                }
                // the pipeline: proposal i+1 is generated here while
                // workers score proposals <= i through the router
                for i in 0..b {
                    let x = strategy.ask();
                    let (arch, bcfg) = problem.decode(&x);
                    xs.push(x);
                    let _ = jtx.send((i, arch, bcfg));
                }
                drop(jtx);
                drop(router);
            });
            // collect in ask order, tell in ask order: the trajectory
            // is exactly the strict-alternation one
            for (x, slot) in xs.into_iter().zip(slots) {
                let sp = slot
                    .into_inner()
                    .unwrap()
                    .context("scoring worker dropped a proposal")??;
                tell_scored(problem, strategy.as_mut(), &mut points, x, sp);
            }
            remaining -= b;
        }

        self.select_and_ground_truth(problem, points, top_k)
    }

    /// Eq. 3 selection + top-k ground-truth check shared by every run
    /// flavor (strict alternation and pipelined).
    fn select_and_ground_truth(
        &self,
        problem: &DseProblem,
        points: Vec<DsePoint>,
        top_k: usize,
    ) -> Result<DseOutcome> {
        // Eq. 3 selection over the feasible Pareto set. MOTPE converges
        // onto good configurations and proposes them repeatedly — dedup
        // by knob vector so top-k names k *distinct* designs.
        let mut seen = std::collections::BTreeSet::new();
        let mut candidates: Vec<Candidate> = Vec::new();
        let mut cand_to_point = Vec::new();
        for (i, p) in points.iter().enumerate() {
            let key: Vec<u64> = p.x.iter().map(|v| v.to_bits()).collect();
            if !seen.insert(key) {
                continue;
            }
            candidates.push(Candidate {
                x: p.x.clone(),
                energy_j: p.predicted[&Metric::Energy],
                runtime_s: p.predicted[&Metric::Runtime],
                power_w: p.predicted[&Metric::Power],
                area_mm2: p.predicted[&Metric::Area],
                in_roi: p.feasible,
            });
            cand_to_point.push(i);
        }
        let best: Vec<usize> = select_best(&candidates, &problem.cost, top_k)
            .into_iter()
            .map(|c| cand_to_point[c])
            .collect();

        // ground truth: memoized SP&R oracle + simulator on the winners,
        // fanned out across the service's worker pool
        let gt_jobs: Vec<(ArchConfig, BackendConfig)> =
            best.iter().map(|&bi| problem.decode(&points[bi].x)).collect();
        let evals = self.service.evaluate_many(&gt_jobs, problem.workload.as_ref())?;
        let mut ground_truth_errors = Vec::new();
        for (ev, &bi) in evals.iter().zip(&best) {
            let truth = ev.metrics();
            let mut errs = BTreeMap::new();
            for m in Metric::ALL {
                let p = points[bi].predicted[&m];
                errs.insert(m, (p - truth[&m]).abs() / truth[&m].abs().max(1e-12));
            }
            ground_truth_errors.push(errs);
        }

        Ok(DseOutcome { points, best, ground_truth_errors })
    }
}

/// The Axiline DSE problem shape (§8.4) for any registry non-DNN
/// workload: size 10-51, cycles 5-21, f_target 0.3-1.3 GHz, util
/// 0.4-0.8, alpha=1, beta=0.001. The base arch's `benchmark`
/// categorical is pinned to the workload's algorithm so the SP&R flow
/// and the oracle simulator agree on what runs.
pub fn axiline_nondnn_problem(p_max: f64, r_max: f64, wl: NonDnnWorkload) -> DseProblem {
    let platform = Platform::Axiline;
    let space = platform.param_space();
    let mut base = ArchConfig::new(
        platform,
        space.iter().map(|s| s.kind.from_unit(0.5)).collect(),
    );
    let bidx = space.iter().position(|s| s.name == "benchmark").unwrap();
    if let ParamKind::Cat(names) = &space[bidx].kind {
        if let Some(pos) = names.iter().position(|n| *n == wl.algo.name()) {
            base.values[bidx] = pos as f64;
        }
    }
    DseProblem {
        base_arch: base,
        arch_knobs: vec![
            ParamSpec { name: "dimension", kind: ParamKind::Int { lo: 10, hi: 51 } },
            ParamSpec { name: "num_cycles", kind: ParamKind::Int { lo: 5, hi: 21 } },
        ],
        f_target_range: (0.3, 1.3),
        util_range: (0.4, 0.8),
        cost: CostSpec { alpha: 1.0, beta: 0.001, p_max, r_max },
        workload: Some(WorkloadSpec::NonDnn(wl)),
    }
}

/// The paper's Axiline-SVM-55 DSE problem (§8.4) — the default cell of
/// the workload axis on the `axiline-svm` target.
pub fn axiline_svm_problem(p_max: f64, r_max: f64) -> DseProblem {
    axiline_nondnn_problem(p_max, r_max, NonDnnWorkload::standard(NonDnnAlgo::Svm, 55))
}

/// The paper's VTA backend-only DSE (§8.4): f_target 0.3-1.3 GHz, util
/// 0.25-0.55, alpha=beta=1.
pub fn vta_backend_problem(base: ArchConfig, p_max: f64, r_max: f64) -> DseProblem {
    DseProblem {
        base_arch: base,
        arch_knobs: vec![],
        f_target_range: (0.3, 1.3),
        util_range: (0.25, 0.55),
        cost: CostSpec { alpha: 1.0, beta: 1.0, p_max, r_max },
        workload: None,
    }
}
