//! Dataset generation (paper §7.1): sample architectural configurations
//! per platform strategy, sample backend configurations with LHS over
//! the platform's (f_target, util) window (Fig. 6), run every
//! (architecture x backend) point through the `EvalService` — which
//! memoizes the SP&R oracle + system simulator and fans the sweep out
//! over the worker pool — and label ROI membership (Eq. 4).

use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::backend::{roi_epsilon, BackendConfig, Enablement};
use crate::data::{Dataset, Row, Split};
use crate::generators::{unified_features, ArchConfig, Lhg, Platform};
use crate::sampling::{quantize, Sampler, SamplerKind};

use super::cache_store::CacheStore;
use super::eval_service::{EvalService, EvalStats};

#[derive(Debug, Clone)]
pub struct DatagenConfig {
    pub platform: Platform,
    pub enablement: Enablement,
    /// Architectural configurations to sample.
    pub n_arch: usize,
    /// Backend points for the training pool and the held-out test pool
    /// (sampled separately — paper §7.2/Fig. 6).
    pub n_backend_train: usize,
    pub n_backend_test: usize,
    pub arch_sampler: SamplerKind,
    pub seed: u64,
    /// Ground-truth fan-out width; 0 = one per available core. Never
    /// changes the generated rows, only wall-clock.
    pub workers: usize,
    /// Single-flight request coalescing (`--coalesce`, ISSUE 5):
    /// concurrent duplicate oracle keys share one in-flight run.
    /// Never changes the generated rows, only wall-clock/CPU.
    pub coalesce: bool,
    /// Explicit workload name (`--workload`), resolved through the
    /// `workloads::lookup*` registry at row-build time: every row's
    /// system metrics price this workload instead of the platform's
    /// default binding. Unknown names error with the registry listing.
    /// `None` keeps the default binding (byte-identical to pre-matrix
    /// datasets).
    pub workload: Option<String>,
}

impl DatagenConfig {
    pub fn small(platform: Platform, enablement: Enablement) -> DatagenConfig {
        DatagenConfig {
            platform,
            enablement,
            n_arch: match platform {
                Platform::Axiline => 24,
                Platform::Tabla => 12,
                _ => 14,
            },
            n_backend_train: 30,
            n_backend_test: 10,
            arch_sampler: SamplerKind::Lhs,
            seed: 2023,
            workers: 0,
            coalesce: false,
            workload: None,
        }
    }
}

/// Backend sampling windows (paper Fig. 6): std-cell Axiline gets the
/// wide window; macro-heavy platforms the conservative one. The
/// frequency window scales with the enablement's speed (the paper's
/// NG45 runs target proportionally lower clocks than GF12).
pub fn backend_window(
    platform: Platform,
    enablement: Enablement,
) -> ((f64, f64), (f64, f64)) {
    let f_scale = enablement.coeffs().f_ceiling_ghz
        / Enablement::Gf12.coeffs().f_ceiling_ghz;
    let ((f_lo, f_hi), util) = if platform.macro_heavy() {
        ((0.2, 1.5), (0.2, 0.6)) // (f_target GHz range, util range)
    } else {
        ((0.4, 2.2), (0.4, 0.9))
    };
    ((f_lo * f_scale, f_hi * f_scale), util)
}

/// Sample `n` backend configurations with LHS.
pub fn sample_backend(
    platform: Platform,
    enablement: Enablement,
    n: usize,
    seed: u64,
) -> Vec<BackendConfig> {
    let ((f_lo, f_hi), (u_lo, u_hi)) = backend_window(platform, enablement);
    let mut sampler = Sampler::new(SamplerKind::Lhs, 2, seed);
    sampler
        .sample(n)
        .into_iter()
        .map(|p| BackendConfig::new(f_lo + p[0] * (f_hi - f_lo), u_lo + p[1] * (u_hi - u_lo)))
        .collect()
}

/// Sample architectural configurations (paper §7.1 strategies, unified
/// through the configured sampler + per-platform quantization grids).
pub fn sample_archs(
    platform: Platform,
    n: usize,
    kind: SamplerKind,
    seed: u64,
) -> Vec<ArchConfig> {
    let space = platform.param_space();
    let mut sampler = Sampler::new(kind, space.len(), seed);
    let mut out = Vec::with_capacity(n);
    let mut seen = std::collections::BTreeSet::new();
    // oversample: quantization can collide on coarse grids
    let points = sampler.sample(n * 8);
    for vals in quantize(&points, &space) {
        let cfg = ArchConfig::new(platform, vals);
        if seen.insert(cfg.id_hash()) {
            out.push(cfg);
            if out.len() == n {
                break;
            }
        }
    }
    out
}

pub struct GeneratedData {
    pub dataset: Dataset,
    /// Row split induced by the separately-sampled backend pools
    /// (unseen-backend protocol).
    pub backend_split: Split,
    /// Evaluation-service counters for the run (cache hit rates).
    pub stats: EvalStats,
}

/// Run the full datagen pipeline on a fresh service.
pub fn generate(cfg: &DatagenConfig) -> Result<GeneratedData> {
    let service = EvalService::new(cfg.enablement, cfg.seed)
        .with_workers(cfg.workers)
        .with_coalescing(cfg.coalesce);
    generate_with(&service, cfg)
}

/// Multi-enablement (or multi-platform) sweep: run datagen for each
/// configuration through its own `EvalService`, all sharing one
/// persistent cache store. Content-hash keys encode the enablement and
/// seed, so entries never collide across services; the workload-free
/// flow key additionally lets any config that revisits a (design,
/// knobs, enablement, seed) point reuse the SP&R result — across the
/// sweep and, once flushed, across runs. Rows are byte-identical to
/// running each config standalone. The store is *not* flushed here;
/// callers flush once after the sweep (or let the last `Arc` drop).
pub fn generate_sweep(
    cfgs: &[DatagenConfig],
    store: Option<Arc<CacheStore>>,
) -> Result<Vec<GeneratedData>> {
    let mut out = Vec::with_capacity(cfgs.len());
    for cfg in cfgs {
        let service = EvalService::new(cfg.enablement, cfg.seed)
            .with_workers(cfg.workers)
            .with_coalescing(cfg.coalesce)
            .with_cache_store_opt(store.clone());
        out.push(generate_with(&service, cfg)?);
    }
    Ok(out)
}

/// Run the full datagen pipeline through an existing service (shares
/// its oracle/aggregate caches with other phases, e.g. a DSE run).
pub fn generate_with(service: &EvalService, cfg: &DatagenConfig) -> Result<GeneratedData> {
    let archs = sample_archs(cfg.platform, cfg.n_arch, cfg.arch_sampler, cfg.seed);
    let backends_train =
        sample_backend(cfg.platform, cfg.enablement, cfg.n_backend_train, cfg.seed ^ 0xB1);
    let backends_test =
        sample_backend(cfg.platform, cfg.enablement, cfg.n_backend_test, cfg.seed ^ 0xB2);
    build_rows_with(service, cfg, archs, &backends_train, &backends_test)
}

/// Core row construction over explicit arch/backend sets (experiments
/// that control sampling — Table 3, Fig. 10 — call this directly).
pub fn build_rows(
    cfg: &DatagenConfig,
    archs: Vec<ArchConfig>,
    backends_train: &[BackendConfig],
    backends_test: &[BackendConfig],
) -> Result<GeneratedData> {
    let service = EvalService::new(cfg.enablement, cfg.seed)
        .with_workers(cfg.workers)
        .with_coalescing(cfg.coalesce);
    build_rows_with(&service, cfg, archs, backends_train, backends_test)
}

/// Row construction through an explicit service.
pub fn build_rows_with(
    service: &EvalService,
    cfg: &DatagenConfig,
    archs: Vec<ArchConfig>,
    backends_train: &[BackendConfig],
    backends_test: &[BackendConfig],
) -> Result<GeneratedData> {
    ensure!(
        service.enablement() == cfg.enablement && service.seed() == cfg.seed,
        "eval service (enablement, seed) must match the datagen config"
    );
    let eps = roi_epsilon(cfg.platform);

    // precompute trees/aggregates once per arch (the LHG is part of the
    // dataset; the aggregates feed the feature vectors) and prime the
    // service's aggregate cache so the sweep never regenerates trees
    let prep: Vec<_> = archs
        .iter()
        .map(|a| {
            let tree = a.platform.generate(a)?;
            let agg = tree.aggregates();
            let lhg = Lhg::from_tree(&tree);
            service.prime_aggregates(a, agg);
            Ok((agg, lhg))
        })
        .collect::<Result<Vec<_>>>()?;

    let mut jobs = Vec::new();
    for (ai, _) in archs.iter().enumerate() {
        for (bi, b) in backends_train.iter().enumerate() {
            jobs.push((ai, *b, true, bi));
        }
        for (bi, b) in backends_test.iter().enumerate() {
            jobs.push((ai, *b, false, bi));
        }
    }

    // the whole cartesian sweep goes through the service: memoized SP&R
    // oracle + simulator, fanned out over the worker pool, order kept
    let pairs: Vec<(ArchConfig, BackendConfig)> =
        jobs.iter().map(|&(ai, b, _, _)| (archs[ai].clone(), b)).collect();
    let workload = match &cfg.workload {
        None => None,
        Some(name) => Some(crate::workloads::lookup_with_features(
            name,
            crate::simulators::default_workload_features(cfg.platform),
        )?),
    };
    let evals = service.evaluate_many(&pairs, workload.as_ref())?;

    let rows: Vec<Row> = jobs
        .iter()
        .zip(&evals)
        .map(|(&(ai, bcfg, _, _), ev)| {
            let arch = &archs[ai];
            let (agg, _) = &prep[ai];
            let feats = unified_features(
                arch,
                bcfg.f_target_ghz,
                bcfg.util,
                agg.comb_cells,
                agg.macro_bits,
            );
            Row {
                arch_idx: ai,
                features: feats,
                f_target_ghz: bcfg.f_target_ghz,
                util: bcfg.util,
                power_w: ev.flow.backend.total_power_w(),
                f_effective_ghz: ev.flow.backend.f_effective_ghz,
                area_mm2: ev.flow.backend.chip_area_mm2,
                energy_j: ev.system.energy_j,
                runtime_s: ev.system.runtime_s,
                in_roi: ev.flow.backend.in_roi(bcfg.f_target_ghz, eps),
            }
        })
        .collect();

    let mut split = Split::default();
    for (i, (_, _, is_train, _)) in jobs.iter().enumerate() {
        if *is_train {
            split.train.push(i);
        } else {
            split.test.push(i);
        }
    }

    let lhgs = prep.into_iter().map(|(_, l)| l).collect();
    Ok(GeneratedData {
        dataset: Dataset {
            platform: cfg.platform,
            enablement: cfg.enablement,
            archs,
            lhgs,
            rows,
        },
        backend_split: split,
        stats: service.stats(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_full_cartesian_with_split() {
        let mut cfg = DatagenConfig::small(Platform::Axiline, Enablement::Gf12);
        cfg.n_arch = 4;
        cfg.n_backend_train = 5;
        cfg.n_backend_test = 2;
        let g = generate(&cfg).unwrap();
        assert_eq!(g.dataset.len(), 4 * 7);
        assert_eq!(g.backend_split.train.len(), 4 * 5);
        assert_eq!(g.backend_split.test.len(), 4 * 2);
        g.backend_split.validate(g.dataset.len()).unwrap();
        assert_eq!(g.dataset.archs.len(), 4);
        assert_eq!(g.dataset.lhgs.len(), 4);
        // every (arch, backend) point is distinct, so the oracle ran
        // once per row; the per-arch aggregate cache must have hit
        assert_eq!(g.stats.oracle_misses, 4 * 7);
        assert_eq!(g.stats.oracle_hits, 0);
        assert!(g.stats.agg_hits > 0);
        assert!(g.stats.cache_hit_rate() > 0.0);
    }

    #[test]
    fn sampled_archs_are_unique_and_legal() {
        for p in Platform::ALL {
            let archs = sample_archs(p, 10, SamplerKind::Sobol, 3);
            assert!(archs.len() >= 8, "{p}: only {} unique", archs.len());
            let mut ids = std::collections::BTreeSet::new();
            for a in &archs {
                a.validate().unwrap();
                assert!(ids.insert(a.id_hash()));
            }
        }
    }

    #[test]
    fn backend_windows_respected() {
        for p in Platform::ALL {
            let ((f_lo, f_hi), (u_lo, u_hi)) = backend_window(p, Enablement::Gf12);
            for b in sample_backend(p, Enablement::Gf12, 20, 1) {
                assert!((f_lo..=f_hi).contains(&b.f_target_ghz), "{p}");
                assert!((u_lo..=u_hi).contains(&b.util), "{p}");
            }
        }
    }

    #[test]
    fn some_rows_in_roi_some_out() {
        let mut cfg = DatagenConfig::small(Platform::Axiline, Enablement::Gf12);
        cfg.n_arch = 6;
        cfg.n_backend_train = 12;
        cfg.n_backend_test = 4;
        let g = generate(&cfg).unwrap();
        let in_roi = g.dataset.rows.iter().filter(|r| r.in_roi).count();
        assert!(in_roi > 0, "no ROI rows at all");
        assert!(in_roi < g.dataset.len(), "everything in ROI — Eq. 4 gate inert");
    }

    #[test]
    fn workload_override_changes_rows_but_not_flow_columns() {
        let base = DatagenConfig {
            n_arch: 3,
            n_backend_train: 4,
            n_backend_test: 2,
            ..DatagenConfig::small(Platform::Vta, Enablement::Gf12)
        };
        let default = generate(&base).unwrap();
        let explicit = generate(&DatagenConfig {
            workload: Some("mobilenet".into()),
            ..base.clone()
        })
        .unwrap();
        // naming the platform's default binding explicitly is a no-op
        assert_eq!(default.dataset.rows, explicit.dataset.rows);

        let tf = generate(&DatagenConfig {
            workload: Some("transformer".into()),
            ..base.clone()
        })
        .unwrap();
        assert_ne!(default.dataset.rows, tf.dataset.rows);
        for (a, b) in default.dataset.rows.iter().zip(&tf.dataset.rows) {
            // the SP&R flow is workload-independent; only system metrics move
            assert_eq!(a.power_w, b.power_w);
            assert_eq!(a.area_mm2, b.area_mm2);
            assert_eq!(a.f_effective_ghz, b.f_effective_ghz);
            assert_ne!(a.energy_j, b.energy_j);
        }
    }

    #[test]
    fn unknown_workload_name_fails_with_registry_listing() {
        let cfg = DatagenConfig {
            n_arch: 2,
            n_backend_train: 2,
            n_backend_test: 1,
            workload: Some("lenet".into()),
            ..DatagenConfig::small(Platform::Vta, Enablement::Gf12)
        };
        let err = generate(&cfg).unwrap_err().to_string();
        assert!(err.contains("unknown workload"), "{err}");
        assert!(err.contains("transformer") && err.contains("gcn"), "{err}");
    }

    #[test]
    fn sweep_through_shared_store_matches_standalone_runs() {
        let mk = |e: Enablement| DatagenConfig {
            n_arch: 3,
            n_backend_train: 4,
            n_backend_test: 2,
            ..DatagenConfig::small(Platform::Axiline, e)
        };
        let cfgs = [mk(Enablement::Gf12), mk(Enablement::Ng45)];
        let dir = std::env::temp_dir()
            .join(format!("fso-datagen-sweep-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = Arc::new(CacheStore::open(&dir).unwrap());
        let swept = generate_sweep(&cfgs, Some(store)).unwrap();
        assert_eq!(swept.len(), 2);
        // sharing a store never changes rows vs. standalone runs
        for (cfg, g) in cfgs.iter().zip(&swept) {
            let solo = generate(cfg).unwrap();
            assert_eq!(g.dataset.rows, solo.dataset.rows, "{}", cfg.enablement.name());
        }
        // the two enablements really explored different PPA spaces
        assert_ne!(swept[0].dataset.rows, swept[1].dataset.rows);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = DatagenConfig {
            n_arch: 3,
            n_backend_train: 4,
            n_backend_test: 2,
            ..DatagenConfig::small(Platform::Vta, Enablement::Gf12)
        };
        let a = generate(&cfg).unwrap();
        let b = generate(&cfg).unwrap();
        assert_eq!(a.dataset.rows, b.dataset.rows);
    }
}
