//! Persistent sharded oracle cache (ISSUE 2 tentpole; ROADMAP "persist
//! the oracle cache to disk between runs").
//!
//! The `EvalService` (PR 1) memoizes SP&R-flow and full-evaluation
//! results in process memory, so every new datagen or DSE run re-pays
//! the oracle cost from zero. This store makes that cache durable and
//! shareable:
//!
//! - **Sharding by content-hash prefix**: the u64 content-hash keys the
//!   service already computes (`flow_key` / `oracle_key`) are routed to
//!   one of N shard files by their top byte, so a warm lookup touches
//!   one small file instead of one monolithic dump, and independent
//!   runs mostly rewrite disjoint shards.
//! - **Append-only JSONL records** (via `util::json`): one record per
//!   line, each carrying a schema tag (`"v"`). Records with an unknown
//!   schema version are skipped on load, so an old cache directory
//!   never poisons a newer binary.
//! - **Lazy per-shard loading**: a shard file is parsed the first time
//!   a key routed to it is requested; runs that touch a small slice of
//!   the key space never read the rest.
//! - **Atomic flushes**: a flush rewrites each dirty shard to a temp
//!   file in the same directory and renames it over the shard, so a
//!   crash mid-flush leaves the previous shard intact. Entries are
//!   written in sorted key order, so shard files are byte-deterministic
//!   for a given entry set.
//! - **Cross-run / cross-enablement sharing**: keys already encode the
//!   enablement, seed, and trial stream (and, for full evaluations, the
//!   workload), so several `EvalService` instances — different
//!   enablements, different workloads, different processes — can share
//!   one directory without collisions. The workload-free flow key from
//!   PR 1 means the expensive SP&R flow result is shared across every
//!   workload that touches the same (design, knobs, enablement, seed).
//!
//! Determinism contract: evaluations are pure functions of their key
//! inputs, and `util::json` round-trips every finite f64 exactly
//! (Rust's shortest-round-trip `Display` + exact `str::parse`), so a
//! warm-start run returns byte-identical results to the cold run that
//! populated the store. `tests/warm_start.rs` pins this end to end.
//!
//! Cross-process safety (ISSUE 3): trainer and DSE processes may share
//! one cache directory concurrently. Flushes are serialized through a
//! directory lock file (`.store.lock`, stolen after a staleness
//! timeout so a crashed holder never wedges the store) and each dirty
//! shard is **merged on flush**: the disk shard is re-parsed right
//! before the rewrite, so entries another process flushed since our
//! last read are folded in instead of silently dropped (in-memory
//! entries win; values are identical by the determinism contract).
//!
//! NB: `model_store.rs` mirrors this shard/lock/flush protocol line
//! for line. Until the two grow a shared generic core (ROADMAP), any
//! change to the lazy-load / merge-on-flush / DirLock-ordering logic
//! must be applied to BOTH files.
//!
//! Design aggregates are *not* persisted: regenerating a module tree is
//! cheap relative to a flow run, and keeping the record schema to the
//! two oracle kinds keeps shard files small.

use std::collections::HashMap;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::backend::{BackendResult, FlowResult, PowerBreakdown, SynthResult};
use crate::simulators::SystemMetrics;
use crate::util::json::Json;

use super::eval_service::Evaluation;

/// Record schema version. Bump on any layout change to the per-record
/// JSON; loaders skip records whose tag does not match.
pub const SCHEMA_VERSION: u64 = 1;

/// Default shard-file count (keys are routed by their top byte).
pub const DEFAULT_SHARDS: usize = 16;

/// Counters for the store (surfaced through `EvalStats` when a service
/// is attached, and printable on their own for CLI summaries).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CacheStoreStats {
    /// Lookups answered by the store (loaded from disk or written by
    /// another service sharing the store this run).
    pub hits: usize,
    /// Shard files parsed so far (lazy loading).
    pub shard_loads: usize,
    /// `flush` calls that wrote at least one shard.
    pub flushes: usize,
    /// Entries currently held (flow + eval records).
    pub entries: usize,
    /// Entries residing in shards with unflushed changes (an upper
    /// bound on the write-behind backlog: a dirty shard's disk-loaded
    /// entries count too, since the whole shard rewrites at flush).
    pub pending: usize,
}

impl std::fmt::Display for CacheStoreStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} entries ({} pending) | {} disk hits | {} shard loads | {} flushes",
            self.entries, self.pending, self.hits, self.shard_loads, self.flushes
        )
    }
}

#[derive(Clone, Copy)]
struct ShardState {
    loaded: bool,
    dirty: bool,
}

struct Inner {
    flows: HashMap<u64, FlowResult>,
    evals: HashMap<u64, Evaluation>,
    shards: Vec<ShardState>,
}

/// Disk-backed, sharded, read-through/write-behind cache for oracle
/// results. Thread-safe; share one instance across services via `Arc`.
pub struct CacheStore {
    dir: PathBuf,
    n_shards: usize,
    inner: Mutex<Inner>,
    hits: AtomicUsize,
    shard_loads: AtomicUsize,
    flushes: AtomicUsize,
}

impl CacheStore {
    /// Open (creating if needed) a cache directory with the default
    /// shard count. An existing directory keeps the shard count it was
    /// created with (recorded in `meta.json`), so reopening with a
    /// different default never mis-routes keys.
    pub fn open(dir: impl Into<PathBuf>) -> Result<CacheStore> {
        CacheStore::open_sharded(dir, DEFAULT_SHARDS)
    }

    /// Open with an explicit shard count (ignored when the directory
    /// already records one).
    pub fn open_sharded(dir: impl Into<PathBuf>, n_shards: usize) -> Result<CacheStore> {
        let dir = dir.into();
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating cache dir {}", dir.display()))?;
        let meta_path = dir.join("meta.json");
        let n_shards = match fs::read_to_string(&meta_path) {
            Ok(text) => {
                let meta = Json::parse(&text)
                    .with_context(|| format!("parsing {}", meta_path.display()))?;
                let v = meta.get("v").as_usize().unwrap_or(0) as u64;
                anyhow::ensure!(
                    v == SCHEMA_VERSION,
                    "cache dir {} has schema v{v}, this binary expects v{SCHEMA_VERSION}",
                    dir.display()
                );
                meta.get("shards")
                    .as_usize()
                    .filter(|&s| s > 0)
                    .with_context(|| format!("{}: bad shard count", meta_path.display()))?
            }
            // only a genuinely absent meta.json means "fresh directory";
            // any other read error (permissions, transient IO) must not
            // silently re-shard an existing store under a new layout
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                let n = n_shards.max(1);
                let meta = Json::obj(vec![
                    ("v", Json::from(SCHEMA_VERSION as usize)),
                    ("shards", Json::from(n)),
                ]);
                write_atomic(&meta_path, format!("{meta}\n").as_bytes())?;
                n
            }
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("reading {}", meta_path.display()))
            }
        };
        Ok(CacheStore {
            dir,
            n_shards,
            inner: Mutex::new(Inner {
                flows: HashMap::new(),
                evals: HashMap::new(),
                shards: vec![ShardState { loaded: false, dirty: false }; n_shards],
            }),
            hits: AtomicUsize::new(0),
            shard_loads: AtomicUsize::new(0),
            flushes: AtomicUsize::new(0),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn shard_count(&self) -> usize {
        self.n_shards
    }

    fn shard_of(&self, key: u64) -> usize {
        // content-hash prefix routing: the top byte spreads uniformly
        // because keys come out of splitmix-finalized hashes
        ((key >> 56) as usize) % self.n_shards
    }

    fn shard_path(&self, shard: usize) -> PathBuf {
        self.dir.join(format!("shard-{shard:03}.jsonl"))
    }

    /// Parse a shard file into the maps. Unknown schema versions,
    /// unknown kinds, and corrupt lines are skipped (a half-written or
    /// foreign record must never sink a run); in-memory entries win
    /// over disk (values are identical by the determinism contract).
    fn load_shard(&self, inner: &mut Inner, shard: usize) {
        if inner.shards[shard].loaded {
            return;
        }
        inner.shards[shard].loaded = true;
        self.shard_loads.fetch_add(1, Ordering::Relaxed);
        self.parse_shard_lines(inner, shard);
    }

    /// The raw disk-to-map merge under `load_shard` and the flush-time
    /// re-read. Does not touch the `loaded` flag or the lazy-load
    /// counter — callers decide what the read means.
    fn parse_shard_lines(&self, inner: &mut Inner, shard: usize) {
        let text = match fs::read_to_string(self.shard_path(shard)) {
            Ok(t) => t,
            Err(_) => return, // never flushed, or unreadable: treat as empty
        };
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let rec = match Json::parse(line) {
                Ok(r) => r,
                Err(_) => continue,
            };
            if rec.get("v").as_usize().map(|v| v as u64) != Some(SCHEMA_VERSION) {
                continue;
            }
            let key = match rec.get("key").as_str().and_then(parse_hex_key) {
                Some(k) => k,
                None => continue,
            };
            match rec.get("kind").as_str() {
                Some("flow") => {
                    if let Some(fr) = flow_from_json(&rec) {
                        inner.flows.entry(key).or_insert(fr);
                    }
                }
                Some("eval") => {
                    if let Some(ev) = eval_from_json(&rec) {
                        inner.evals.entry(key).or_insert(ev);
                    }
                }
                _ => continue,
            }
        }
    }

    /// Workload-free SP&R flow result for a flow key, if known.
    pub fn get_flow(&self, key: u64) -> Option<FlowResult> {
        let mut inner = self.inner.lock().unwrap();
        self.load_shard(&mut inner, self.shard_of(key));
        let hit = inner.flows.get(&key).copied();
        if hit.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Record a flow result (write-behind: durable at the next flush).
    pub fn put_flow(&self, key: u64, fr: FlowResult) {
        let mut inner = self.inner.lock().unwrap();
        let shard = self.shard_of(key);
        if inner.flows.insert(key, fr).is_none() {
            inner.shards[shard].dirty = true;
        }
    }

    /// Full (flow + simulator) evaluation for an oracle key, if known.
    pub fn get_eval(&self, key: u64) -> Option<Evaluation> {
        let mut inner = self.inner.lock().unwrap();
        self.load_shard(&mut inner, self.shard_of(key));
        let hit = inner.evals.get(&key).copied();
        if hit.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Record a full evaluation (write-behind).
    pub fn put_eval(&self, key: u64, ev: Evaluation) {
        let mut inner = self.inner.lock().unwrap();
        let shard = self.shard_of(key);
        if inner.evals.insert(key, ev).is_none() {
            inner.shards[shard].dirty = true;
        }
    }

    /// Write every dirty shard atomically (temp file + rename in the
    /// same directory). Flushes from processes sharing the directory
    /// are serialized by a lock file, and each dirty shard is re-read
    /// from disk right before the rewrite (merge-on-flush), so a flush
    /// never drops entries — neither on-disk records this run did not
    /// happen to read, nor records a concurrent process flushed since.
    /// Returns the number of shard files written.
    pub fn flush(&self) -> Result<usize> {
        // cheap dirtiness pre-check, then take the cross-process lock
        // *without* holding the in-process Mutex: a contended DirLock
        // wait (up to the staleness window) must not stall every
        // worker thread doing get/put on the shared store
        {
            let inner = self.inner.lock().unwrap();
            if !inner.shards.iter().any(|s| s.dirty) {
                return Ok(0);
            }
        }
        let lock = DirLock::acquire(&self.dir)?;
        let mut inner = self.inner.lock().unwrap();
        // recompute under the lock: another thread may have flushed
        let dirty: Vec<usize> =
            (0..self.n_shards).filter(|&s| inner.shards[s].dirty).collect();
        if dirty.is_empty() {
            return Ok(0);
        }
        for &shard in &dirty {
            lock.refresh();
            self.parse_shard_lines(&mut inner, shard);
            inner.shards[shard].loaded = true;
            let mut lines: Vec<(u8, u64, String)> = Vec::new();
            for (&key, fr) in &inner.flows {
                if self.shard_of(key) == shard {
                    lines.push((0, key, flow_to_json(key, fr).to_string()));
                }
            }
            for (&key, ev) in &inner.evals {
                if self.shard_of(key) == shard {
                    lines.push((1, key, eval_to_json(key, ev).to_string()));
                }
            }
            // sorted (kind, key) order: shard bytes are deterministic
            lines.sort_by_key(|&(kind, key, _)| (kind, key));
            let mut body = String::new();
            for (_, _, line) in &lines {
                body.push_str(line);
                body.push('\n');
            }
            write_atomic(&self.shard_path(shard), body.as_bytes())?;
            inner.shards[shard].dirty = false;
        }
        self.flushes.fetch_add(1, Ordering::Relaxed);
        Ok(dirty.len())
    }

    /// Snapshot the store counters.
    pub fn stats(&self) -> CacheStoreStats {
        let inner = self.inner.lock().unwrap();
        let pending: usize = {
            // dirty shards hold the not-yet-durable entries; count them
            let dirty: Vec<bool> = inner.shards.iter().map(|s| s.dirty).collect();
            inner
                .flows
                .keys()
                .chain(inner.evals.keys())
                .filter(|&&k| dirty[self.shard_of(k)])
                .count()
        };
        CacheStoreStats {
            hits: self.hits.load(Ordering::Relaxed),
            shard_loads: self.shard_loads.load(Ordering::Relaxed),
            flushes: self.flushes.load(Ordering::Relaxed),
            entries: inner.flows.len() + inner.evals.len(),
            pending,
        }
    }

    /// Store-level hit count (also surfaced via `stats`).
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn shard_loads(&self) -> usize {
        self.shard_loads.load(Ordering::Relaxed)
    }

    pub fn flush_count(&self) -> usize {
        self.flushes.load(Ordering::Relaxed)
    }
}

impl Drop for CacheStore {
    /// Best-effort durability for callers that forget an explicit
    /// flush; errors are swallowed (Drop cannot fail).
    fn drop(&mut self) {
        let _ = self.flush();
    }
}

/// Cross-process flush serialization for a store directory: a
/// `.store.lock` file created with `create_new` (atomic on every
/// filesystem we care about) and removed on drop. A lock whose *file*
/// has not changed for the staleness window is presumed to belong to a
/// crashed process and is broken — flushes must never wedge a run
/// forever. Staleness is judged by the lock file's age, never by how
/// long this waiter has been waiting: a live holder mid-long-flush, or
/// a sequence of short-lived locks taken by other processes, must not
/// get stolen (stealing a live lock reintroduces the lost-update race
/// the lock exists to prevent). Shared by `CacheStore` and
/// `ModelStore` (separate directories, so their locks never contend).
pub(crate) struct DirLock {
    path: PathBuf,
    /// Unique content written into the lock file; `drop` unlinks the
    /// file only while it still holds this token, so a holder whose
    /// lock was stolen never deletes the new holder's lock.
    token: String,
    /// The handle from `create_new`: `refresh` touches mtime through
    /// it, so a stalled holder whose lock was stolen (path renamed and
    /// recreated by the new holder) touches its own orphaned inode,
    /// never the new holder's file.
    file: fs::File,
}

impl DirLock {
    const STALE_MS: u128 = 30_000;
    /// A lock file stamped in the *future* only reads as stale past
    /// this much skew. It is deliberately much larger than `STALE_MS`:
    /// a live holder whose clock runs ahead by less than this ages out
    /// naturally (its mtime drifts into the past as real time passes),
    /// while an absurd future timestamp — which could otherwise never
    /// age out and would wedge every flusher forever — is eventually
    /// broken. NTP-grade skew is well under a second; ten minutes of
    /// skew between hosts cooperating on one cache dir is operational
    /// pathology, and progress wins at that point.
    const FUTURE_SKEW_STALE_MS: u128 = 600_000;
    const POLL_MS: u64 = 20;

    pub(crate) fn acquire(dir: &Path) -> Result<DirLock> {
        static NONCE: AtomicUsize = AtomicUsize::new(0);
        let path = dir.join(".store.lock");
        let token = format!(
            "{}-{}",
            std::process::id(),
            NONCE.fetch_add(1, Ordering::Relaxed)
        );
        loop {
            match fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    let _ = f.write_all(token.as_bytes());
                    let _ = f.sync_all();
                    return Ok(DirLock { path, token, file: f });
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let stale = match fs::metadata(&path).and_then(|m| m.modified()) {
                        Ok(mtime) => match mtime.elapsed() {
                            Ok(age) => age.as_millis() >= Self::STALE_MS,
                            // mtime ahead of our clock: see
                            // FUTURE_SKEW_STALE_MS for why this bound
                            // is far looser than the normal window
                            Err(skew) => {
                                skew.duration().as_millis() >= Self::FUTURE_SKEW_STALE_MS
                            }
                        },
                        // lock vanished between create_new and the stat
                        // (holder released): just retry create_new
                        Err(_) => false,
                    };
                    if stale {
                        // crashed holder (the file itself went stale,
                        // see `refresh`). Steal by *rename*, which is
                        // atomic: exactly one contender claims the
                        // stale file; the losers' renames fail and
                        // they re-poll — so a fresh lock created by
                        // the winner is never unlinked by a loser.
                        let stolen = dir.join(format!(".store.lock.stale-{token}"));
                        if fs::rename(&path, &stolen).is_ok() {
                            let _ = fs::remove_file(&stolen);
                        }
                        continue;
                    }
                    std::thread::sleep(std::time::Duration::from_millis(Self::POLL_MS));
                }
                Err(e) => {
                    return Err(e).with_context(|| format!("locking {}", path.display()))
                }
            }
        }
    }

    /// Keep the holder visibly live during a long multi-shard flush
    /// (staleness is judged by file mtime): touch mtime through the
    /// handle opened at acquire — never through the path, which may
    /// by now belong to a new holder after a staleness steal. Call
    /// between expensive write steps.
    pub(crate) fn refresh(&self) {
        let _ = self.file.set_modified(std::time::SystemTime::now());
    }
}

impl Drop for DirLock {
    fn drop(&mut self) {
        // unlink only while we still own the file: after a staleness
        // steal the path holds the new holder's token, and removing it
        // would admit a third concurrent writer
        if fs::read_to_string(&self.path).is_ok_and(|s| s == self.token) {
            let _ = fs::remove_file(&self.path);
        }
    }
}

/// Write `bytes` to `path` atomically: temp file in the same directory
/// (same filesystem, so the rename is atomic), then rename over.
pub(crate) fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let dir = path.parent().context("cache path has no parent directory")?;
    let base = path.file_name().context("cache path has no file name")?;
    let tmp = dir.join(format!(".{}.tmp-{}", base.to_string_lossy(), std::process::id()));
    {
        let mut f = fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes)
            .with_context(|| format!("writing {}", tmp.display()))?;
        f.sync_all().ok(); // durability best-effort; atomicity is the rename
    }
    fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} over {}", tmp.display(), path.display()))?;
    Ok(())
}

pub(crate) fn parse_hex_key(s: &str) -> Option<u64> {
    u64::from_str_radix(s, 16).ok()
}

pub(crate) fn hex_key(key: u64) -> String {
    format!("{key:016x}")
}

// ---- record (de)serialization --------------------------------------
//
// u64 keys are stored as 16-hex-digit strings (JSON numbers are f64 —
// 53 mantissa bits would corrupt hash keys). f64 fields are stored as
// JSON numbers: `util::json` prints the shortest exact representation
// and parses it back bit-identically; non-finite values round-trip
// through the `null` sentinel (becoming NaN on re-load).

fn synth_to_json(s: &SynthResult) -> Json {
    Json::obj(vec![
        ("cell_area_um2", s.cell_area_um2.into()),
        ("macro_area_um2", s.macro_area_um2.into()),
        ("upsize", s.upsize.into()),
        ("syn_power_w", s.syn_power_w.into()),
        ("syn_fmax_ghz", s.syn_fmax_ghz.into()),
        ("logic_delay_ps", s.logic_delay_ps.into()),
    ])
}

/// Read a numeric field, requiring the key to be *present*: a present
/// `null` is the non-finite sentinel (decodes to NaN), but an absent
/// key fails the whole record — schema drift must read as corrupt and
/// fall back to a cold entry, never as NaN-filled data.
fn num_field(j: &Json, name: &str) -> Option<f64> {
    j.as_obj()?.get(name)?.as_f64_or_nan()
}

fn synth_from_json(j: &Json) -> Option<SynthResult> {
    Some(SynthResult {
        cell_area_um2: num_field(j, "cell_area_um2")?,
        macro_area_um2: num_field(j, "macro_area_um2")?,
        upsize: num_field(j, "upsize")?,
        syn_power_w: num_field(j, "syn_power_w")?,
        syn_fmax_ghz: num_field(j, "syn_fmax_ghz")?,
        logic_delay_ps: num_field(j, "logic_delay_ps")?,
    })
}

fn backend_to_json(b: &BackendResult) -> Json {
    Json::obj(vec![
        ("f_effective_ghz", b.f_effective_ghz.into()),
        ("f_max_ghz", b.f_max_ghz.into()),
        ("internal_w", b.power.internal_w.into()),
        ("switching_w", b.power.switching_w.into()),
        ("leakage_w", b.power.leakage_w.into()),
        ("sram_w", b.power.sram_w.into()),
        ("chip_area_mm2", b.chip_area_mm2.into()),
        ("cell_area_um2", b.cell_area_um2.into()),
        ("macro_area_um2", b.macro_area_um2.into()),
        ("congestion", b.congestion.into()),
    ])
}

fn backend_from_json(j: &Json) -> Option<BackendResult> {
    Some(BackendResult {
        f_effective_ghz: num_field(j, "f_effective_ghz")?,
        f_max_ghz: num_field(j, "f_max_ghz")?,
        power: PowerBreakdown {
            internal_w: num_field(j, "internal_w")?,
            switching_w: num_field(j, "switching_w")?,
            leakage_w: num_field(j, "leakage_w")?,
            sram_w: num_field(j, "sram_w")?,
        },
        chip_area_mm2: num_field(j, "chip_area_mm2")?,
        cell_area_um2: num_field(j, "cell_area_um2")?,
        macro_area_um2: num_field(j, "macro_area_um2")?,
        congestion: num_field(j, "congestion")?,
    })
}

fn system_to_json(s: &SystemMetrics) -> Json {
    Json::obj(vec![
        ("runtime_s", s.runtime_s.into()),
        ("energy_j", s.energy_j.into()),
        ("cycles", s.cycles.into()),
        ("busy_frac", s.busy_frac.into()),
        ("dram_bytes", s.dram_bytes.into()),
    ])
}

fn system_from_json(j: &Json) -> Option<SystemMetrics> {
    Some(SystemMetrics {
        runtime_s: num_field(j, "runtime_s")?,
        energy_j: num_field(j, "energy_j")?,
        cycles: num_field(j, "cycles")?,
        busy_frac: num_field(j, "busy_frac")?,
        dram_bytes: num_field(j, "dram_bytes")?,
    })
}

fn flow_to_json(key: u64, fr: &FlowResult) -> Json {
    Json::obj(vec![
        ("v", Json::from(SCHEMA_VERSION as usize)),
        ("kind", "flow".into()),
        ("key", hex_key(key).as_str().into()),
        ("synth", synth_to_json(&fr.synth)),
        ("backend", backend_to_json(&fr.backend)),
    ])
}

fn flow_from_json(rec: &Json) -> Option<FlowResult> {
    Some(FlowResult {
        synth: synth_from_json(rec.get("synth"))?,
        backend: backend_from_json(rec.get("backend"))?,
    })
}

fn eval_to_json(key: u64, ev: &Evaluation) -> Json {
    Json::obj(vec![
        ("v", Json::from(SCHEMA_VERSION as usize)),
        ("kind", "eval".into()),
        ("key", hex_key(key).as_str().into()),
        ("synth", synth_to_json(&ev.flow.synth)),
        ("backend", backend_to_json(&ev.flow.backend)),
        ("system", system_to_json(&ev.system)),
    ])
}

fn eval_from_json(rec: &Json) -> Option<Evaluation> {
    Some(Evaluation {
        flow: flow_from_json(rec)?,
        system: system_from_json(rec.get("system"))?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{BackendConfig, Enablement, SpnrFlow};
    use crate::generators::{ArchConfig, Platform};
    use crate::simulators::simulate;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("fso-cache-store-{}-{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn sample_eval() -> Evaluation {
        let p = Platform::Axiline;
        let arch = ArchConfig::new(
            p,
            p.param_space().iter().map(|s| s.kind.from_unit(0.5)).collect(),
        );
        let flow = SpnrFlow::new(Enablement::Gf12, 7);
        let fr = flow.run(&arch, BackendConfig::new(0.8, 0.5)).unwrap();
        let system = simulate(&arch, &fr.backend, Enablement::Gf12).unwrap();
        Evaluation { flow: fr, system }
    }

    #[test]
    fn flow_and_eval_records_roundtrip_exactly() {
        let dir = tmp_dir("roundtrip");
        let ev = sample_eval();
        {
            let store = CacheStore::open(&dir).unwrap();
            store.put_flow(0x0123_4567_89ab_cdef, ev.flow);
            store.put_eval(0xfedc_ba98_7654_3210, ev);
            assert_eq!(store.stats().pending, 2);
            store.flush().unwrap();
            assert_eq!(store.stats().pending, 0);
        }
        let store = CacheStore::open(&dir).unwrap();
        let fr = store.get_flow(0x0123_4567_89ab_cdef).expect("flow survives reopen");
        assert_eq!(fr.synth, ev.flow.synth);
        assert_eq!(fr.backend, ev.flow.backend);
        let got = store.get_eval(0xfedc_ba98_7654_3210).expect("eval survives reopen");
        assert_eq!(got.flow.backend, ev.flow.backend);
        assert_eq!(got.system, ev.system);
        // bit-exact f64 round-trip, not just approximate
        assert_eq!(
            got.flow.backend.f_effective_ghz.to_bits(),
            ev.flow.backend.f_effective_ghz.to_bits()
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_keys_miss_and_lazy_loading_counts_shards() {
        let dir = tmp_dir("lazy");
        let ev = sample_eval();
        {
            let store = CacheStore::open(&dir).unwrap();
            // two keys routed to different shards (top bytes 0x00 and
            // 0x01 land in shards 0 and 1 of the 16-shard default)
            store.put_eval(0x00ff_0000_0000_0001, ev);
            store.put_eval(0x01ff_0000_0000_0002, ev);
            store.flush().unwrap();
        }
        let store = CacheStore::open(&dir).unwrap();
        assert_eq!(store.shard_loads(), 0, "opening must not read shards");
        assert!(store.get_eval(0x00ff_0000_0000_0001).is_some());
        assert_eq!(store.shard_loads(), 1, "one lookup loads one shard");
        assert!(store.get_eval(0x00ff_0000_0000_0003).is_none());
        assert_eq!(store.shard_loads(), 1, "same-shard miss loads nothing new");
        assert!(store.get_eval(0x01ff_0000_0000_0002).is_some());
        assert_eq!(store.shard_loads(), 2);
        assert_eq!(store.hits(), 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn flush_is_atomic_and_files_are_deterministic() {
        let dir_a = tmp_dir("atomic-a");
        let dir_b = tmp_dir("atomic-b");
        let ev = sample_eval();
        let keys: Vec<u64> = (0..40u64)
            .map(|i| crate::util::rng::hash_bytes(&i.to_le_bytes()))
            .collect();
        // same entries, opposite insertion orders (the in-memory maps
        // iterate in hash order; the flush must sort that away)
        {
            let store = CacheStore::open(&dir_a).unwrap();
            for &key in &keys {
                store.put_eval(key, ev);
            }
            store.flush().unwrap();
        }
        {
            let store = CacheStore::open(&dir_b).unwrap();
            for &key in keys.iter().rev() {
                store.put_eval(key, ev);
            }
            store.flush().unwrap();
        }
        let list = |dir: &Path| -> Vec<(String, Vec<u8>)> {
            let mut files: Vec<_> = fs::read_dir(dir)
                .unwrap()
                .map(|e| e.unwrap().path())
                .collect();
            files.sort();
            files
                .iter()
                .map(|p| {
                    let name = p.file_name().unwrap().to_string_lossy().to_string();
                    assert!(!name.contains(".tmp"), "leftover temp file {name}");
                    (name, fs::read(p).unwrap())
                })
                .collect()
        };
        assert_eq!(
            list(&dir_a),
            list(&dir_b),
            "shard files must be byte-deterministic for a given entry set"
        );
        let _ = fs::remove_dir_all(&dir_a);
        let _ = fs::remove_dir_all(&dir_b);
    }

    #[test]
    fn unknown_versions_and_corrupt_lines_are_skipped() {
        let dir = tmp_dir("skip");
        let ev = sample_eval();
        let key = 0x0500_0000_0000_0042u64;
        {
            let store = CacheStore::open(&dir).unwrap();
            store.put_eval(key, ev);
            store.flush().unwrap();
        }
        // append garbage + a future-schema record to the shard file
        let store = CacheStore::open(&dir).unwrap();
        let shard_path = store.shard_path(store.shard_of(key));
        drop(store);
        let mut text = fs::read_to_string(&shard_path).unwrap();
        text.push_str("{ this is not json\n");
        text.push_str("{\"v\":999,\"kind\":\"eval\",\"key\":\"0500000000000043\"}\n");
        // current-schema record with the metric fields missing entirely:
        // must be skipped, not decoded as a NaN-filled evaluation
        text.push_str("{\"v\":1,\"kind\":\"eval\",\"key\":\"0500000000000044\"}\n");
        fs::write(&shard_path, text).unwrap();

        let store = CacheStore::open(&dir).unwrap();
        assert!(store.get_eval(key).is_some(), "good record still loads");
        assert!(store.get_eval(0x0500_0000_0000_0043).is_none(), "v999 skipped");
        assert!(
            store.get_eval(0x0500_0000_0000_0044).is_none(),
            "field-less record must read as corrupt, not as NaNs"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn concurrent_writers_merge_on_flush() {
        // ISSUE 3: two store instances (stand-ins for two processes)
        // write distinct keys routed to the same shard. The classic
        // lost-update: the later flush used to rewrite the shard from
        // its own memory only, dropping the earlier writer's record.
        let dir = tmp_dir("merge");
        let ev = sample_eval();
        let a = CacheStore::open(&dir).unwrap();
        let b = CacheStore::open(&dir).unwrap();
        a.put_eval(0x0aff_0000_0000_0001, ev);
        b.put_eval(0x0aff_0000_0000_0002, ev);
        a.flush().unwrap();
        b.flush().unwrap(); // b never read a's entry in memory
        drop(a);
        drop(b);
        let c = CacheStore::open(&dir).unwrap();
        assert!(
            c.get_eval(0x0aff_0000_0000_0001).is_some(),
            "a's entry must survive b's flush (merge-on-flush)"
        );
        assert!(c.get_eval(0x0aff_0000_0000_0002).is_some());
        assert!(
            !dir.join(".store.lock").exists(),
            "flush must release the directory lock"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn reopen_keeps_original_shard_count() {
        let dir = tmp_dir("meta");
        {
            let store = CacheStore::open_sharded(&dir, 4).unwrap();
            assert_eq!(store.shard_count(), 4);
        }
        let store = CacheStore::open_sharded(&dir, 64).unwrap();
        assert_eq!(store.shard_count(), 4, "meta.json pins the shard count");
        let _ = fs::remove_dir_all(&dir);
    }
}
