//! Persistent sharded oracle cache (ISSUE 2; rebased onto the shared
//! `coordinator::store` core in ISSUE 4).
//!
//! The `EvalService` (PR 1) memoizes SP&R-flow and full-evaluation
//! results in process memory, so every new datagen or DSE run re-pays
//! the oracle cost from zero. This store makes that cache durable and
//! shareable. All of the persistence *protocol* — content-hash shard
//! routing, lazy per-shard load, schema-tagged JSONL encode/decode,
//! atomic temp+rename flush, `.store.lock` ordering, merge-on-flush,
//! LRU eviction budgets, and compaction — lives in the generic
//! [`ShardedStore`]; this file only defines the oracle record family:
//!
//! - **Keys** are the u64 content hashes the service already computes
//!   (`flow_key` / `oracle_key`): they encode the enablement, seed,
//!   trial stream, and (for full evaluations) the workload, so several
//!   `EvalService` instances — different enablements, workloads,
//!   processes — share one directory without collisions. The
//!   workload-free flow key from PR 1 means the expensive SP&R flow
//!   result is shared across every workload that touches the same
//!   (design, knobs, enablement, seed).
//! - **Records** are the two oracle kinds (`flow`, `eval`), encoded
//!   through `util::json` so every finite f64 round-trips bit-exactly
//!   (non-finite values ride the `null` sentinel). Design aggregates
//!   are *not* persisted: regenerating a module tree is cheap relative
//!   to a flow run.
//!
//! Determinism contract: evaluations are pure functions of their key
//! inputs, so a warm-start run returns byte-identical results to the
//! cold run that populated the store — before or after an `fso store
//! compact`. `tests/warm_start.rs` pins this end to end.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::backend::{BackendResult, FlowResult, PowerBreakdown, SynthResult};
use crate::simulators::SystemMetrics;
use crate::util::json::Json;

use super::eval_service::Evaluation;
use super::store::{Codec, CompactReport, Record, ShardedStore, StoreConfig, StorePolicy};

/// Record schema version. Bump on any *breaking* layout change to the
/// per-record JSON; loaders skip records whose tag does not match.
/// The ISSUE 4 store core added envelope fields **additively** (an
/// optional `used` stamp, defaulting to oldest, and a `tomb` kind that
/// pre-core loaders skip as unknown), deliberately *without* a bump so
/// PR 2/3 cache directories stay warm. Caveat of that choice: a
/// pre-core binary sharing a directory with this one drops tombstones
/// and stamps when it rewrites a shard — mixed-version *concurrent*
/// writers degrade eviction to best-effort (never correctness: values
/// are pure functions of their keys).
pub const SCHEMA_VERSION: u64 = 1;

/// Default shard-file count (keys are routed by their top byte).
pub const DEFAULT_SHARDS: usize = 16;

/// Counters for the store (surfaced through `EvalStats` when a service
/// is attached, and printable on their own for CLI summaries).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CacheStoreStats {
    /// Lookups answered by the store (loaded from disk or written by
    /// another service sharing the store this run).
    pub hits: usize,
    /// Lookups that found nothing (the caller runs the oracle).
    pub misses: usize,
    /// Shard files parsed so far (lazy loading).
    pub shard_loads: usize,
    /// `flush` calls that wrote at least one shard.
    pub flushes: usize,
    /// Entries currently held (flow + eval records).
    pub entries: usize,
    /// Entries not yet durable on disk. Exact per-record accounting
    /// (ISSUE 4 fix): a merge-on-flush that folds disk records into a
    /// shard no longer inflates this.
    pub pending: usize,
    /// Eviction tombstones currently held (reclaimed at compaction).
    pub tombstones: usize,
    /// Serialized bytes of the live records (what the `max_bytes`
    /// eviction budget is judged against).
    pub live_bytes: u64,
    /// Records evicted (policy budgets or explicit `evict`) since open.
    pub evictions: usize,
    /// Compaction passes since open (explicit + automatic).
    pub compactions: usize,
    /// Frames loaded as undecoded spans whose body was never
    /// tree-parsed (storage engine v2 streaming scans).
    pub lazy_skips: usize,
    /// Lazy frames actually decoded into records.
    pub full_decodes: usize,
    /// Point lookups answered by a sidecar index (definitive miss or
    /// single-frame fetch — either way no shard scan).
    pub sidecar_hits: usize,
    /// Sidecars rebuilt after being found missing, torn, or stale.
    pub sidecar_rebuilds: usize,
    /// Records transcoded from the other codec during a rewrite of a
    /// mixed-codec directory.
    pub transcoded_records: usize,
}

impl std::fmt::Display for CacheStoreStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} entries ({} pending, {} B live) | {} disk hits | {} shard loads | {} flushes | {} evicted, {} tombstones, {} compactions | {} lazy skips, {} decodes, {} sidecar hits, {} rebuilds, {} transcoded",
            self.entries,
            self.pending,
            self.live_bytes,
            self.hits,
            self.shard_loads,
            self.flushes,
            self.evictions,
            self.tombstones,
            self.compactions,
            self.lazy_skips,
            self.full_decodes,
            self.sidecar_hits,
            self.sidecar_rebuilds,
            self.transcoded_records
        )
    }
}

/// The oracle record family: the workload-free SP&R flow result and
/// the full (flow + simulator) evaluation.
#[derive(Debug, Clone, Copy)]
pub enum OracleRecord {
    Flow(FlowResult),
    Eval(Evaluation),
}

/// Bit-pattern equality, not derived f64 equality: the store's
/// identical-re-put check must treat a record as unchanged when its
/// bits are unchanged. Derived `==` would make any NaN-bearing record
/// (the `null`-sentinel round-trip, PR 2) compare unequal to itself
/// and re-dirty its shard on every re-put, forever.
impl PartialEq for OracleRecord {
    fn eq(&self, other: &OracleRecord) -> bool {
        fn synth_bits(s: &SynthResult) -> [u64; 6] {
            [
                s.cell_area_um2.to_bits(),
                s.macro_area_um2.to_bits(),
                s.upsize.to_bits(),
                s.syn_power_w.to_bits(),
                s.syn_fmax_ghz.to_bits(),
                s.logic_delay_ps.to_bits(),
            ]
        }
        fn backend_bits(b: &BackendResult) -> [u64; 10] {
            [
                b.f_effective_ghz.to_bits(),
                b.f_max_ghz.to_bits(),
                b.power.internal_w.to_bits(),
                b.power.switching_w.to_bits(),
                b.power.leakage_w.to_bits(),
                b.power.sram_w.to_bits(),
                b.chip_area_mm2.to_bits(),
                b.cell_area_um2.to_bits(),
                b.macro_area_um2.to_bits(),
                b.congestion.to_bits(),
            ]
        }
        fn system_bits(s: &SystemMetrics) -> [u64; 5] {
            [
                s.runtime_s.to_bits(),
                s.energy_j.to_bits(),
                s.cycles.to_bits(),
                s.busy_frac.to_bits(),
                s.dram_bytes.to_bits(),
            ]
        }
        match (self, other) {
            (OracleRecord::Flow(a), OracleRecord::Flow(b)) => {
                synth_bits(&a.synth) == synth_bits(&b.synth)
                    && backend_bits(&a.backend) == backend_bits(&b.backend)
            }
            (OracleRecord::Eval(a), OracleRecord::Eval(b)) => {
                synth_bits(&a.flow.synth) == synth_bits(&b.flow.synth)
                    && backend_bits(&a.flow.backend) == backend_bits(&b.flow.backend)
                    && system_bits(&a.system) == system_bits(&b.system)
            }
            _ => false,
        }
    }
}

impl Record for OracleRecord {
    fn kind(&self) -> std::borrow::Cow<'_, str> {
        match self {
            OracleRecord::Flow(_) => "flow".into(),
            OracleRecord::Eval(_) => "eval".into(),
        }
    }

    fn encode(&self, out: &mut Vec<(&'static str, Json)>) {
        match self {
            OracleRecord::Flow(fr) => {
                out.push(("synth", synth_to_json(&fr.synth)));
                out.push(("backend", backend_to_json(&fr.backend)));
            }
            OracleRecord::Eval(ev) => {
                out.push(("synth", synth_to_json(&ev.flow.synth)));
                out.push(("backend", backend_to_json(&ev.flow.backend)));
                out.push(("system", system_to_json(&ev.system)));
            }
        }
    }

    fn decode(kind: &str, rec: &Json) -> Option<OracleRecord> {
        match kind {
            "flow" => Some(OracleRecord::Flow(flow_from_json(rec)?)),
            "eval" => Some(OracleRecord::Eval(eval_from_json(rec)?)),
            _ => None,
        }
    }
}

/// Disk-backed, sharded, read-through/write-behind cache for oracle
/// results: a thin typed wrapper over the shared [`ShardedStore`]
/// core. Thread-safe; share one instance across services via `Arc`.
pub struct CacheStore {
    core: ShardedStore<OracleRecord>,
}

impl CacheStore {
    fn config() -> StoreConfig {
        StoreConfig {
            schema_version: SCHEMA_VERSION,
            default_shards: DEFAULT_SHARDS,
            file_prefix: "shard",
            label: "cache dir",
            policy: StorePolicy::default_auto(),
            codec: Codec::V2Binary,
        }
    }

    /// Open (creating if needed) a cache directory with the default
    /// shard count. An existing directory keeps the shard count it was
    /// created with (recorded in `meta.json`), so reopening with a
    /// different default never mis-routes keys.
    pub fn open(dir: impl Into<PathBuf>) -> Result<CacheStore> {
        CacheStore::open_sharded(dir, DEFAULT_SHARDS)
    }

    /// Open with an explicit shard count (ignored when the directory
    /// already records one).
    pub fn open_sharded(dir: impl Into<PathBuf>, n_shards: usize) -> Result<CacheStore> {
        Ok(CacheStore {
            core: ShardedStore::open_sharded(dir, CacheStore::config(), n_shards)?,
        })
    }

    /// Replace the lifecycle policy (eviction budgets, auto-compaction
    /// ratio) before sharing the store.
    pub fn with_policy(self, policy: StorePolicy) -> CacheStore {
        CacheStore { core: self.core.with_policy(policy) }
    }

    /// Replace the write codec (`--store-codec`); reads auto-detect
    /// both codecs regardless.
    pub fn with_codec(self, codec: Codec) -> CacheStore {
        CacheStore { core: self.core.with_codec(codec) }
    }

    pub fn codec(&self) -> Codec {
        self.core.codec()
    }

    pub fn dir(&self) -> &Path {
        self.core.dir()
    }

    pub fn shard_count(&self) -> usize {
        self.core.shard_count()
    }

    /// Workload-free SP&R flow result for a flow key, if known.
    pub fn get_flow(&self, key: u64) -> Option<FlowResult> {
        match self.core.get("flow", key) {
            Some(OracleRecord::Flow(fr)) => Some(fr),
            _ => None,
        }
    }

    /// Record a flow result (write-behind: durable at the next flush).
    pub fn put_flow(&self, key: u64, fr: FlowResult) {
        self.core.put(key, OracleRecord::Flow(fr));
    }

    /// Full (flow + simulator) evaluation for an oracle key, if known.
    pub fn get_eval(&self, key: u64) -> Option<Evaluation> {
        match self.core.get("eval", key) {
            Some(OracleRecord::Eval(ev)) => Some(ev),
            _ => None,
        }
    }

    /// Record a full evaluation (write-behind).
    pub fn put_eval(&self, key: u64, ev: Evaluation) {
        self.core.put(key, OracleRecord::Eval(ev));
    }

    /// Evict a key (tombstoned: reads miss, concurrent writers cannot
    /// resurrect it). Returns whether a live record was evicted.
    pub fn evict(&self, key: u64) -> bool {
        self.core.evict(key)
    }

    /// Write every dirty shard atomically, serialized across processes
    /// and merged with the disk state first (see the store core docs).
    /// Returns the number of shard files written.
    pub fn flush(&self) -> Result<usize> {
        self.core.flush()
    }

    /// Compaction pass: drop tombstones and dead lines, enforce the
    /// eviction policy, rewrite only the shards whose bytes change.
    pub fn compact(&self) -> Result<CompactReport> {
        self.core.compact()
    }

    /// Force every shard into memory (CLI stats / maintenance; normal
    /// traffic relies on lazy loading).
    pub fn load_all(&self) {
        self.core.load_all()
    }

    /// Snapshot the store counters.
    pub fn stats(&self) -> CacheStoreStats {
        let s = self.core.stats();
        CacheStoreStats {
            hits: s.hits,
            misses: s.misses,
            shard_loads: s.shard_loads,
            flushes: s.flushes,
            entries: s.entries,
            pending: s.pending,
            tombstones: s.tombstones,
            live_bytes: s.live_bytes,
            evictions: s.evictions,
            compactions: s.compactions,
            lazy_skips: s.lazy_skips,
            full_decodes: s.full_decodes,
            sidecar_hits: s.sidecar_hits,
            sidecar_rebuilds: s.sidecar_rebuilds,
            transcoded_records: s.transcoded_records,
        }
    }

    /// Store-level hit count (also surfaced via `stats`).
    pub fn hits(&self) -> usize {
        self.core.hits()
    }

    pub fn shard_loads(&self) -> usize {
        self.core.shard_loads()
    }

    pub fn flush_count(&self) -> usize {
        self.core.flush_count()
    }

    pub fn evictions(&self) -> usize {
        self.core.evictions()
    }

    pub fn compactions(&self) -> usize {
        self.core.compactions()
    }

    pub fn lazy_skips(&self) -> usize {
        self.core.lazy_skips()
    }

    pub fn full_decodes(&self) -> usize {
        self.core.full_decodes()
    }

    pub fn sidecar_hits(&self) -> usize {
        self.core.sidecar_hits()
    }

    pub fn sidecar_rebuilds(&self) -> usize {
        self.core.sidecar_rebuilds()
    }

    pub fn transcoded_records(&self) -> usize {
        self.core.transcoded_records()
    }
}

// ---- record (de)serialization --------------------------------------
//
// The envelope (`v`, `kind`, `key`, `used`) belongs to the store core;
// only the payload fields are defined here. f64 fields are stored as
// JSON numbers: `util::json` prints the shortest exact representation
// and parses it back bit-identically; non-finite values round-trip
// through the `null` sentinel (becoming NaN on re-load).

pub(crate) fn synth_to_json(s: &SynthResult) -> Json {
    Json::obj(vec![
        ("cell_area_um2", s.cell_area_um2.into()),
        ("macro_area_um2", s.macro_area_um2.into()),
        ("upsize", s.upsize.into()),
        ("syn_power_w", s.syn_power_w.into()),
        ("syn_fmax_ghz", s.syn_fmax_ghz.into()),
        ("logic_delay_ps", s.logic_delay_ps.into()),
    ])
}

/// Read a numeric field, requiring the key to be *present*: a present
/// `null` is the non-finite sentinel (decodes to NaN), but an absent
/// key fails the whole record — schema drift must read as corrupt and
/// fall back to a cold entry, never as NaN-filled data.
fn num_field(j: &Json, name: &str) -> Option<f64> {
    j.as_obj()?.get(name)?.as_f64_or_nan()
}

fn synth_from_json(j: &Json) -> Option<SynthResult> {
    Some(SynthResult {
        cell_area_um2: num_field(j, "cell_area_um2")?,
        macro_area_um2: num_field(j, "macro_area_um2")?,
        upsize: num_field(j, "upsize")?,
        syn_power_w: num_field(j, "syn_power_w")?,
        syn_fmax_ghz: num_field(j, "syn_fmax_ghz")?,
        logic_delay_ps: num_field(j, "logic_delay_ps")?,
    })
}

pub(crate) fn backend_to_json(b: &BackendResult) -> Json {
    Json::obj(vec![
        ("f_effective_ghz", b.f_effective_ghz.into()),
        ("f_max_ghz", b.f_max_ghz.into()),
        ("internal_w", b.power.internal_w.into()),
        ("switching_w", b.power.switching_w.into()),
        ("leakage_w", b.power.leakage_w.into()),
        ("sram_w", b.power.sram_w.into()),
        ("chip_area_mm2", b.chip_area_mm2.into()),
        ("cell_area_um2", b.cell_area_um2.into()),
        ("macro_area_um2", b.macro_area_um2.into()),
        ("congestion", b.congestion.into()),
    ])
}

fn backend_from_json(j: &Json) -> Option<BackendResult> {
    Some(BackendResult {
        f_effective_ghz: num_field(j, "f_effective_ghz")?,
        f_max_ghz: num_field(j, "f_max_ghz")?,
        power: PowerBreakdown {
            internal_w: num_field(j, "internal_w")?,
            switching_w: num_field(j, "switching_w")?,
            leakage_w: num_field(j, "leakage_w")?,
            sram_w: num_field(j, "sram_w")?,
        },
        chip_area_mm2: num_field(j, "chip_area_mm2")?,
        cell_area_um2: num_field(j, "cell_area_um2")?,
        macro_area_um2: num_field(j, "macro_area_um2")?,
        congestion: num_field(j, "congestion")?,
    })
}

pub(crate) fn system_to_json(s: &SystemMetrics) -> Json {
    Json::obj(vec![
        ("runtime_s", s.runtime_s.into()),
        ("energy_j", s.energy_j.into()),
        ("cycles", s.cycles.into()),
        ("busy_frac", s.busy_frac.into()),
        ("dram_bytes", s.dram_bytes.into()),
    ])
}

fn system_from_json(j: &Json) -> Option<SystemMetrics> {
    Some(SystemMetrics {
        runtime_s: num_field(j, "runtime_s")?,
        energy_j: num_field(j, "energy_j")?,
        cycles: num_field(j, "cycles")?,
        busy_frac: num_field(j, "busy_frac")?,
        dram_bytes: num_field(j, "dram_bytes")?,
    })
}

pub(crate) fn flow_from_json(rec: &Json) -> Option<FlowResult> {
    Some(FlowResult {
        synth: synth_from_json(rec.get("synth"))?,
        backend: backend_from_json(rec.get("backend"))?,
    })
}

pub(crate) fn eval_from_json(rec: &Json) -> Option<Evaluation> {
    Some(Evaluation {
        flow: flow_from_json(rec)?,
        system: system_from_json(rec.get("system"))?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{BackendConfig, Enablement, SpnrFlow};
    use crate::generators::{ArchConfig, Platform};
    use crate::simulators::simulate;
    use std::fs;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("fso-cache-store-{}-{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn sample_eval() -> Evaluation {
        let p = Platform::Axiline;
        let arch = ArchConfig::new(
            p,
            p.param_space().iter().map(|s| s.kind.from_unit(0.5)).collect(),
        );
        let flow = SpnrFlow::new(Enablement::Gf12, 7);
        let fr = flow.run(&arch, BackendConfig::new(0.8, 0.5)).unwrap();
        let system = simulate(&arch, &fr.backend, Enablement::Gf12).unwrap();
        Evaluation { flow: fr, system }
    }

    fn shard_file_of(store: &CacheStore, key: u64) -> PathBuf {
        let shard = ((key >> 56) as usize) % store.shard_count();
        store.dir().join(format!("shard-{shard:03}.jsonl"))
    }

    #[test]
    fn flow_and_eval_records_roundtrip_exactly() {
        let dir = tmp_dir("roundtrip");
        let ev = sample_eval();
        {
            let store = CacheStore::open(&dir).unwrap();
            store.put_flow(0x0123_4567_89ab_cdef, ev.flow);
            store.put_eval(0xfedc_ba98_7654_3210, ev);
            assert_eq!(store.stats().pending, 2);
            store.flush().unwrap();
            assert_eq!(store.stats().pending, 0);
        }
        let store = CacheStore::open(&dir).unwrap();
        let fr = store.get_flow(0x0123_4567_89ab_cdef).expect("flow survives reopen");
        assert_eq!(fr.synth, ev.flow.synth);
        assert_eq!(fr.backend, ev.flow.backend);
        let got = store.get_eval(0xfedc_ba98_7654_3210).expect("eval survives reopen");
        assert_eq!(got.flow.backend, ev.flow.backend);
        assert_eq!(got.system, ev.system);
        // bit-exact f64 round-trip, not just approximate
        assert_eq!(
            got.flow.backend.f_effective_ghz.to_bits(),
            ev.flow.backend.f_effective_ghz.to_bits()
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_keys_miss_and_lazy_loading_counts_shards() {
        let dir = tmp_dir("lazy");
        let ev = sample_eval();
        {
            let store = CacheStore::open(&dir).unwrap();
            // two keys routed to different shards (top bytes 0x00 and
            // 0x01 land in shards 0 and 1 of the 16-shard default)
            store.put_eval(0x00ff_0000_0000_0001, ev);
            store.put_eval(0x01ff_0000_0000_0002, ev);
            store.flush().unwrap();
        }
        let store = CacheStore::open(&dir).unwrap();
        assert_eq!(store.shard_loads(), 0, "opening must not read shards");
        assert!(store.get_eval(0x00ff_0000_0000_0001).is_some());
        assert_eq!(store.sidecar_hits(), 1, "a point lookup goes through the sidecar");
        assert_eq!(store.shard_loads(), 0, "no shard scan for an indexed key");
        assert!(store.get_eval(0x00ff_0000_0000_0003).is_none());
        assert_eq!(store.sidecar_hits(), 2, "the index answers the miss definitively");
        assert_eq!(
            store.full_decodes(),
            1,
            "a lookup miss never pays a full-tree parse"
        );
        assert!(store.get_eval(0x01ff_0000_0000_0002).is_some());
        assert_eq!(store.sidecar_hits(), 3);
        assert_eq!(store.shard_loads(), 0);
        assert_eq!(store.hits(), 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn flush_is_atomic_and_files_are_deterministic() {
        let dir_a = tmp_dir("atomic-a");
        let dir_b = tmp_dir("atomic-b");
        let ev = sample_eval();
        let keys: Vec<u64> = (0..40u64)
            .map(|i| crate::util::rng::hash_bytes(&i.to_le_bytes()))
            .collect();
        // same entries, opposite insertion orders (the in-memory maps
        // iterate in hash order; the flush must sort that away)
        {
            let store = CacheStore::open(&dir_a).unwrap();
            for &key in &keys {
                store.put_eval(key, ev);
            }
            store.flush().unwrap();
        }
        {
            let store = CacheStore::open(&dir_b).unwrap();
            for &key in keys.iter().rev() {
                store.put_eval(key, ev);
            }
            store.flush().unwrap();
        }
        let list = |dir: &Path| -> Vec<(String, Vec<u8>)> {
            let mut files: Vec<_> = fs::read_dir(dir)
                .unwrap()
                .map(|e| e.unwrap().path())
                .collect();
            files.sort();
            files
                .iter()
                .map(|p| {
                    let name = p.file_name().unwrap().to_string_lossy().to_string();
                    assert!(!name.contains(".tmp"), "leftover temp file {name}");
                    (name, fs::read(p).unwrap())
                })
                .collect()
        };
        assert_eq!(
            list(&dir_a),
            list(&dir_b),
            "shard files must be byte-deterministic for a given entry set"
        );
        let _ = fs::remove_dir_all(&dir_a);
        let _ = fs::remove_dir_all(&dir_b);
    }

    #[test]
    fn unknown_versions_and_corrupt_lines_are_skipped() {
        // written under the v1 JSONL codec so garbage can be appended
        // as text; the reopen (v2 default) must auto-detect and still
        // skip every bad line
        let dir = tmp_dir("skip");
        let ev = sample_eval();
        let key = 0x0500_0000_0000_0042u64;
        {
            let store = CacheStore::open(&dir).unwrap().with_codec(Codec::V1Jsonl);
            store.put_eval(key, ev);
            store.flush().unwrap();
        }
        // append garbage + a future-schema record to the shard file
        let store = CacheStore::open(&dir).unwrap();
        let shard_path = shard_file_of(&store, key);
        drop(store);
        let mut text = fs::read_to_string(&shard_path).unwrap();
        text.push_str("{ this is not json\n");
        text.push_str("{\"v\":999,\"kind\":\"eval\",\"key\":\"0500000000000043\"}\n");
        // current-schema record with the metric fields missing entirely:
        // must be skipped, not decoded as a NaN-filled evaluation
        text.push_str("{\"v\":1,\"kind\":\"eval\",\"key\":\"0500000000000044\"}\n");
        fs::write(&shard_path, text).unwrap();

        let store = CacheStore::open(&dir).unwrap();
        assert!(store.get_eval(key).is_some(), "good record still loads");
        assert!(store.get_eval(0x0500_0000_0000_0043).is_none(), "v999 skipped");
        assert!(
            store.get_eval(0x0500_0000_0000_0044).is_none(),
            "field-less record must read as corrupt, not as NaNs"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn concurrent_writers_merge_on_flush() {
        // ISSUE 3: two store instances (stand-ins for two processes)
        // write distinct keys routed to the same shard. The classic
        // lost-update: the later flush used to rewrite the shard from
        // its own memory only, dropping the earlier writer's record.
        let dir = tmp_dir("merge");
        let ev = sample_eval();
        let a = CacheStore::open(&dir).unwrap();
        let b = CacheStore::open(&dir).unwrap();
        a.put_eval(0x0aff_0000_0000_0001, ev);
        b.put_eval(0x0aff_0000_0000_0002, ev);
        a.flush().unwrap();
        b.flush().unwrap(); // b never read a's entry in memory
        drop(a);
        drop(b);
        let c = CacheStore::open(&dir).unwrap();
        assert!(
            c.get_eval(0x0aff_0000_0000_0001).is_some(),
            "a's entry must survive b's flush (merge-on-flush)"
        );
        assert!(c.get_eval(0x0aff_0000_0000_0002).is_some());
        assert!(
            !dir.join(".store.lock").exists(),
            "flush must release the directory lock"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn reopen_keeps_original_shard_count() {
        let dir = tmp_dir("meta");
        {
            let store = CacheStore::open_sharded(&dir, 4).unwrap();
            assert_eq!(store.shard_count(), 4);
        }
        let store = CacheStore::open_sharded(&dir, 64).unwrap();
        assert_eq!(store.shard_count(), 4, "meta.json pins the shard count");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn pending_count_is_exact_after_merge_on_flush() {
        // ISSUE 4 satellite regression: `pending` used to count every
        // entry residing in a dirty shard — so a merge-on-flush that
        // folded another writer's disk records into memory, followed by
        // one new put, reported the whole shard as pending. It must
        // count exactly the not-yet-durable records.
        let dir = tmp_dir("pending-drift");
        let ev = sample_eval();
        {
            let other = CacheStore::open(&dir).unwrap();
            other.put_eval(0x0bff_0000_0000_0001, ev);
            other.put_eval(0x0bff_0000_0000_0002, ev);
            other.flush().unwrap();
        }
        let store = CacheStore::open(&dir).unwrap();
        store.put_eval(0x0bff_0000_0000_0003, ev);
        assert_eq!(store.stats().pending, 1);
        store.flush().unwrap(); // merges the other writer's two records
        let s = store.stats();
        assert_eq!(s.entries, 3);
        assert_eq!(s.pending, 0, "everything durable after the flush: {s}");
        store.put_eval(0x0bff_0000_0000_0004, ev);
        let s = store.stats();
        assert_eq!(s.entries, 4);
        assert_eq!(
            s.pending, 1,
            "only the new record is pending, not its disk-merged shardmates: {s}"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn evicted_oracle_keys_miss_and_repopulate() {
        let dir = tmp_dir("evict");
        let ev = sample_eval();
        {
            let store = CacheStore::open(&dir).unwrap();
            store.put_eval(0x0cff_0000_0000_0001, ev);
            store.put_flow(0x0cff_0000_0000_0002, ev.flow);
            store.flush().unwrap();
            assert!(store.evict(0x0cff_0000_0000_0001));
            store.flush().unwrap();
        }
        let store = CacheStore::open(&dir).unwrap();
        assert!(
            store.get_eval(0x0cff_0000_0000_0001).is_none(),
            "evicted key must read as a miss after reopen"
        );
        assert!(store.get_flow(0x0cff_0000_0000_0002).is_some());
        // the caller re-runs the oracle and repopulates
        store.put_eval(0x0cff_0000_0000_0001, ev);
        store.flush().unwrap();
        drop(store);
        let store = CacheStore::open(&dir).unwrap();
        assert!(store.get_eval(0x0cff_0000_0000_0001).is_some());
        let _ = fs::remove_dir_all(&dir);
    }
}
