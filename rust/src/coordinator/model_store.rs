//! Persistent surrogate-model store (ISSUE 3 tentpole; ROADMAP
//! "surrogate-model persistence so a warm start skips refitting too").
//!
//! PR 2 made the *oracle* cache durable, but every warm start still
//! re-tuned and refit the GBDT/RF/ensemble surrogates from scratch —
//! with the oracle served from disk, refitting now dominates restart
//! wall-clock. This store makes the fitted models durable too,
//! mirroring `cache_store.rs` discipline:
//!
//! - **Content-hash keys**: a model artifact is keyed by a hash of
//!   everything the fit is a pure function of — training matrices (a
//!   dataset + split + metric fingerprint), tuning budget, and seed —
//!   built through [`ModelKey`]. Same inputs ⇒ same key ⇒ the stored
//!   model replays **bit-identical predictions**, because every model
//!   family serializes its f64s through `util::json`'s exact
//!   round-trip.
//! - **Schema-tagged JSONL shards**: records carry `{"v", "kind",
//!   "key", "model"}`; unknown versions and corrupt lines are skipped
//!   on load, and a payload that fails a family's `from_json` reads as
//!   a miss — callers fall back to refitting (and overwrite the bad
//!   artifact at the next flush). Shard files are written in sorted
//!   (kind, key) order, so they are byte-deterministic for an entry
//!   set.
//! - **Lazy load, atomic flush, merge-on-flush**: shard files parse on
//!   first touch; flushes rewrite dirty shards via temp + rename under
//!   the shared `.store.lock`, re-reading the disk shard first so a
//!   concurrent trainer/DSE process sharing the directory never loses
//!   records (same cross-process contract as the oracle store).
//! - **Cohabitation**: the store lives in a `models/` subdirectory of
//!   the oracle cache dir ([`ModelStore::open_under`]), so one
//!   `--cache-dir` carries both oracle shards and model artifacts
//!   without the two stores' files or locks ever colliding.
//!
//! Readers/writers: `Trainer` (tuned GBDT/RF, ROI classifier, stacked
//! ensemble), `SurrogateBundle::fit_cached` (the DSE surrogate), and
//! `EvalService::fit_surrogate` route through here — read-through on
//! fit requests, write-behind after tuning, flushed by the CLI or the
//! last `Drop`. `--no-model-cache` is the CLI escape hatch.
//!
//! NB: the shard/lock/flush *protocol* here deliberately mirrors
//! `cache_store.rs` line for line (only the record schema and sort key
//! differ). Until the two grow a shared generic core (ROADMAP), any
//! change to the lazy-load / merge-on-flush / DirLock-ordering logic
//! must be applied to BOTH files.

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::util::rng::hash_bytes;

use super::cache_store::{hex_key, parse_hex_key, write_atomic, DirLock};

/// Record schema version; bump on any layout change. Loaders skip
/// records whose tag does not match.
pub const SCHEMA_VERSION: u64 = 1;

/// Default shard-file count (model artifacts are few but large, so
/// fewer shards than the oracle store).
pub const DEFAULT_SHARDS: usize = 8;

/// Deterministic content-hash key builder for model artifacts: feed it
/// everything the fitted model is a pure function of (family tag,
/// training matrices, labels, tuning budget, seeds) and `finish`.
/// f64s are hashed by bit pattern, and every field is length-prefixed
/// so adjacent fields cannot alias.
pub struct ModelKey {
    bytes: Vec<u8>,
}

impl ModelKey {
    pub fn new(tag: &str) -> ModelKey {
        let mut bytes = Vec::with_capacity(256);
        bytes.extend_from_slice(tag.as_bytes());
        bytes.push(0);
        ModelKey { bytes }
    }

    pub fn u64(mut self, v: u64) -> ModelKey {
        self.bytes.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn usize(self, v: usize) -> ModelKey {
        self.u64(v as u64)
    }

    pub fn str(mut self, s: &str) -> ModelKey {
        self.bytes.extend_from_slice(&(s.len() as u64).to_le_bytes());
        self.bytes.extend_from_slice(s.as_bytes());
        self
    }

    pub fn f64s(mut self, vs: &[f64]) -> ModelKey {
        self.bytes.extend_from_slice(&(vs.len() as u64).to_le_bytes());
        for v in vs {
            self.bytes.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        self
    }

    pub fn rows(mut self, rows: &[Vec<f64>]) -> ModelKey {
        self.bytes.extend_from_slice(&(rows.len() as u64).to_le_bytes());
        for r in rows {
            self = self.f64s(r);
        }
        self
    }

    pub fn bools(mut self, bs: &[bool]) -> ModelKey {
        self.bytes.extend_from_slice(&(bs.len() as u64).to_le_bytes());
        self.bytes.extend(bs.iter().map(|&b| b as u8));
        self
    }

    pub fn finish(self) -> u64 {
        hash_bytes(&self.bytes)
    }
}

/// Counters for the store (surfaced through `EvalStats` when a service
/// is attached, and printable on their own for CLI summaries).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ModelStoreStats {
    /// Lookups answered with a stored artifact of the requested kind.
    pub hits: usize,
    /// Lookups that found nothing (or a kind mismatch) — the caller
    /// refits.
    pub misses: usize,
    /// Shard files parsed so far (lazy loading).
    pub shard_loads: usize,
    /// `flush` calls that wrote at least one shard.
    pub flushes: usize,
    /// Artifacts currently held.
    pub entries: usize,
    /// Artifacts residing in shards with unflushed changes (an upper
    /// bound on the write-behind backlog: a dirty shard's disk-loaded
    /// entries count too, since the whole shard rewrites at flush).
    pub pending: usize,
}

impl std::fmt::Display for ModelStoreStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} artifacts ({} pending) | {} hits / {} misses | {} shard loads | {} flushes",
            self.entries, self.pending, self.hits, self.misses, self.shard_loads, self.flushes
        )
    }
}

#[derive(Clone, Copy)]
struct ShardState {
    loaded: bool,
    dirty: bool,
}

struct Entry {
    kind: String,
    payload: Json,
}

struct Inner {
    entries: HashMap<u64, Entry>,
    shards: Vec<ShardState>,
}

/// Disk-backed, sharded, read-through/write-behind store for fitted
/// surrogate models. Thread-safe; share one instance across the
/// trainer and services via `Arc`.
pub struct ModelStore {
    dir: PathBuf,
    n_shards: usize,
    inner: Mutex<Inner>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    shard_loads: AtomicUsize,
    flushes: AtomicUsize,
}

impl ModelStore {
    /// Open (creating if needed) a model-store directory with the
    /// default shard count. An existing directory keeps the shard
    /// count it was created with (recorded in `meta.json`).
    pub fn open(dir: impl Into<PathBuf>) -> Result<ModelStore> {
        ModelStore::open_sharded(dir, DEFAULT_SHARDS)
    }

    /// The cohabitation entry point: open the model store that lives
    /// under an oracle cache directory (`<cache-dir>/models/`), so one
    /// `--cache-dir` carries both stores.
    pub fn open_under(cache_dir: impl AsRef<Path>) -> Result<ModelStore> {
        ModelStore::open(cache_dir.as_ref().join("models"))
    }

    /// Open with an explicit shard count (ignored when the directory
    /// already records one).
    pub fn open_sharded(dir: impl Into<PathBuf>, n_shards: usize) -> Result<ModelStore> {
        let dir = dir.into();
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating model store dir {}", dir.display()))?;
        let meta_path = dir.join("meta.json");
        let n_shards = match fs::read_to_string(&meta_path) {
            Ok(text) => {
                let meta = Json::parse(&text)
                    .with_context(|| format!("parsing {}", meta_path.display()))?;
                let v = meta.get("v").as_usize().unwrap_or(0) as u64;
                anyhow::ensure!(
                    v == SCHEMA_VERSION,
                    "model store {} has schema v{v}, this binary expects v{SCHEMA_VERSION}",
                    dir.display()
                );
                meta.get("shards")
                    .as_usize()
                    .filter(|&s| s > 0)
                    .with_context(|| format!("{}: bad shard count", meta_path.display()))?
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                let n = n_shards.max(1);
                let meta = Json::obj(vec![
                    ("v", Json::from(SCHEMA_VERSION as usize)),
                    ("shards", Json::from(n)),
                ]);
                write_atomic(&meta_path, format!("{meta}\n").as_bytes())?;
                n
            }
            Err(e) => {
                return Err(e).with_context(|| format!("reading {}", meta_path.display()))
            }
        };
        Ok(ModelStore {
            dir,
            n_shards,
            inner: Mutex::new(Inner {
                entries: HashMap::new(),
                shards: vec![ShardState { loaded: false, dirty: false }; n_shards],
            }),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            shard_loads: AtomicUsize::new(0),
            flushes: AtomicUsize::new(0),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn shard_count(&self) -> usize {
        self.n_shards
    }

    fn shard_of(&self, key: u64) -> usize {
        ((key >> 56) as usize) % self.n_shards
    }

    fn shard_path(&self, shard: usize) -> PathBuf {
        self.dir.join(format!("model-{shard:03}.jsonl"))
    }

    fn load_shard(&self, inner: &mut Inner, shard: usize) {
        if inner.shards[shard].loaded {
            return;
        }
        inner.shards[shard].loaded = true;
        self.shard_loads.fetch_add(1, Ordering::Relaxed);
        self.parse_shard_lines(inner, shard);
    }

    /// Disk-to-map merge (in-memory entries win). Unknown schema
    /// versions and corrupt lines are skipped; payloads are *not*
    /// validated here — a family's `from_json` is the arbiter, so a
    /// structurally-valid but semantically-corrupt artifact surfaces
    /// as a refit, never a crash.
    fn parse_shard_lines(&self, inner: &mut Inner, shard: usize) {
        let text = match fs::read_to_string(self.shard_path(shard)) {
            Ok(t) => t,
            Err(_) => return,
        };
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let rec = match Json::parse(line) {
                Ok(r) => r,
                Err(_) => continue,
            };
            if rec.get("v").as_usize().map(|v| v as u64) != Some(SCHEMA_VERSION) {
                continue;
            }
            let key = match rec.get("key").as_str().and_then(parse_hex_key) {
                Some(k) => k,
                None => continue,
            };
            let kind = match rec.get("kind").as_str() {
                Some(k) => k.to_string(),
                None => continue,
            };
            let payload = rec.get("model").clone();
            if payload == Json::Null {
                continue;
            }
            inner
                .entries
                .entry(key)
                .or_insert(Entry { kind, payload });
        }
    }

    /// Stored artifact payload for (kind, key), if present. A key held
    /// under a different kind reads as a miss (content-hash keys embed
    /// the family tag, so this only happens on adversarial input).
    pub fn get(&self, kind: &str, key: u64) -> Option<Json> {
        let mut inner = self.inner.lock().unwrap();
        self.load_shard(&mut inner, self.shard_of(key));
        match inner.entries.get(&key) {
            Some(e) if e.kind == kind => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(e.payload.clone())
            }
            _ => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Record an artifact (write-behind: durable at the next flush).
    /// Overwrites an existing entry whose payload differs — that is
    /// how a corrupt artifact gets repaired after the fallback refit.
    pub fn put(&self, kind: &str, key: u64, payload: Json) {
        let mut inner = self.inner.lock().unwrap();
        let shard = self.shard_of(key);
        let changed = match inner.entries.get(&key) {
            Some(e) => e.kind != kind || e.payload != payload,
            None => true,
        };
        if changed {
            inner
                .entries
                .insert(key, Entry { kind: kind.to_string(), payload });
            inner.shards[shard].dirty = true;
        }
    }

    /// Write every dirty shard atomically, serialized across processes
    /// by the directory lock and merged with the disk state first
    /// (same contract as `CacheStore::flush`). Returns the number of
    /// shard files written.
    pub fn flush(&self) -> Result<usize> {
        // dirtiness pre-check, then the cross-process lock *without*
        // the in-process Mutex held (a contended lock wait must not
        // stall concurrent get/put callers), then recompute under it
        {
            let inner = self.inner.lock().unwrap();
            if !inner.shards.iter().any(|s| s.dirty) {
                return Ok(0);
            }
        }
        let lock = DirLock::acquire(&self.dir)?;
        let mut inner = self.inner.lock().unwrap();
        let dirty: Vec<usize> =
            (0..self.n_shards).filter(|&s| inner.shards[s].dirty).collect();
        if dirty.is_empty() {
            return Ok(0);
        }
        for &shard in &dirty {
            lock.refresh();
            self.parse_shard_lines(&mut inner, shard);
            inner.shards[shard].loaded = true;
            let mut lines: Vec<(String, u64, String)> = inner
                .entries
                .iter()
                .filter(|(k, _)| self.shard_of(**k) == shard)
                .map(|(&k, e)| {
                    let rec = Json::obj(vec![
                        ("v", Json::from(SCHEMA_VERSION as usize)),
                        ("kind", e.kind.as_str().into()),
                        ("key", hex_key(k).as_str().into()),
                        ("model", e.payload.clone()),
                    ]);
                    (e.kind.clone(), k, rec.to_string())
                })
                .collect();
            // sorted (kind, key) order: shard bytes are deterministic
            lines.sort_by(|a, b| (a.0.as_str(), a.1).cmp(&(b.0.as_str(), b.1)));
            let mut body = String::new();
            for (_, _, line) in &lines {
                body.push_str(line);
                body.push('\n');
            }
            write_atomic(&self.shard_path(shard), body.as_bytes())?;
            inner.shards[shard].dirty = false;
        }
        self.flushes.fetch_add(1, Ordering::Relaxed);
        Ok(dirty.len())
    }

    /// Snapshot the store counters.
    pub fn stats(&self) -> ModelStoreStats {
        let inner = self.inner.lock().unwrap();
        let pending = inner
            .entries
            .keys()
            .filter(|&&k| inner.shards[self.shard_of(k)].dirty)
            .count();
        ModelStoreStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            shard_loads: self.shard_loads.load(Ordering::Relaxed),
            flushes: self.flushes.load(Ordering::Relaxed),
            entries: inner.entries.len(),
            pending,
        }
    }

    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn shard_loads(&self) -> usize {
        self.shard_loads.load(Ordering::Relaxed)
    }

    pub fn flush_count(&self) -> usize {
        self.flushes.load(Ordering::Relaxed)
    }
}

impl Drop for ModelStore {
    /// Best-effort durability for callers that forget an explicit
    /// flush; errors are swallowed (Drop cannot fail).
    fn drop(&mut self) {
        let _ = self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("fso-model-store-{}-{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn payload(v: f64) -> Json {
        Json::obj(vec![("w", Json::arr_f64(&[v, -v])), ("b", v.into())])
    }

    #[test]
    fn artifacts_survive_reopen_byte_exactly() {
        let dir = tmp_dir("roundtrip");
        let key = 0x0123_4567_89ab_cdefu64;
        {
            let store = ModelStore::open(&dir).unwrap();
            store.put("test-family", key, payload(1.0 / 3.0));
            assert_eq!(store.stats().pending, 1);
            store.flush().unwrap();
            assert_eq!(store.stats().pending, 0);
        }
        let store = ModelStore::open(&dir).unwrap();
        let got = store.get("test-family", key).expect("artifact survives reopen");
        assert_eq!(got, payload(1.0 / 3.0));
        assert_eq!(
            got.get("b").as_f64().unwrap().to_bits(),
            (1.0f64 / 3.0).to_bits(),
            "f64 payloads must round-trip bit-exactly"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn kind_mismatch_and_missing_keys_are_misses() {
        let dir = tmp_dir("miss");
        let store = ModelStore::open(&dir).unwrap();
        store.put("family-a", 42, payload(2.0));
        assert!(store.get("family-b", 42).is_none(), "kind mismatch is a miss");
        assert!(store.get("family-a", 43).is_none());
        assert!(store.get("family-a", 42).is_some());
        assert_eq!(store.misses(), 2);
        assert_eq!(store.hits(), 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn put_overwrites_changed_payloads() {
        // the corrupt-artifact repair path: a refit must replace the
        // stored payload, not be swallowed by insert-if-absent
        let dir = tmp_dir("overwrite");
        {
            let store = ModelStore::open(&dir).unwrap();
            store.put("f", 7, payload(1.0));
            store.flush().unwrap();
            store.put("f", 7, payload(2.0));
            assert_eq!(store.stats().pending, 1, "changed payload re-dirties");
            store.put("f", 7, payload(2.0));
            store.flush().unwrap();
        }
        let store = ModelStore::open(&dir).unwrap();
        assert_eq!(store.get("f", 7).unwrap(), payload(2.0));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_lines_and_unknown_versions_are_skipped() {
        let dir = tmp_dir("skip");
        let key = 0x0500_0000_0000_0042u64;
        {
            let store = ModelStore::open(&dir).unwrap();
            store.put("f", key, payload(3.0));
            store.flush().unwrap();
        }
        let store = ModelStore::open(&dir).unwrap();
        let shard_path = store.shard_path(store.shard_of(key));
        drop(store);
        let mut text = fs::read_to_string(&shard_path).unwrap();
        text.push_str("{ not json\n");
        text.push_str("{\"v\":999,\"kind\":\"f\",\"key\":\"0500000000000043\",\"model\":{}}\n");
        text.push_str("{\"v\":1,\"kind\":\"f\",\"key\":\"0500000000000044\"}\n"); // no payload
        fs::write(&shard_path, text).unwrap();
        let store = ModelStore::open(&dir).unwrap();
        assert!(store.get("f", key).is_some(), "good record still loads");
        assert!(store.get("f", 0x0500_0000_0000_0043).is_none(), "v999 skipped");
        assert!(store.get("f", 0x0500_0000_0000_0044).is_none(), "payload-less skipped");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn concurrent_stores_merge_on_flush() {
        let dir = tmp_dir("merge");
        let a = ModelStore::open(&dir).unwrap();
        let b = ModelStore::open(&dir).unwrap();
        // same shard (same top byte), different keys
        a.put("f", 0x0b00_0000_0000_0001, payload(1.0));
        b.put("f", 0x0b00_0000_0000_0002, payload(2.0));
        a.flush().unwrap();
        b.flush().unwrap();
        drop(a);
        drop(b);
        let c = ModelStore::open(&dir).unwrap();
        assert!(c.get("f", 0x0b00_0000_0000_0001).is_some(), "merge-on-flush");
        assert!(c.get("f", 0x0b00_0000_0000_0002).is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn shard_files_are_byte_deterministic() {
        let dir_a = tmp_dir("det-a");
        let dir_b = tmp_dir("det-b");
        let keys: Vec<u64> = (0..24u64)
            .map(|i| crate::util::rng::hash_bytes(&i.to_le_bytes()))
            .collect();
        {
            let store = ModelStore::open(&dir_a).unwrap();
            for &k in &keys {
                store.put("f", k, payload(k as f64));
            }
            store.flush().unwrap();
        }
        {
            let store = ModelStore::open(&dir_b).unwrap();
            for &k in keys.iter().rev() {
                store.put("f", k, payload(k as f64));
            }
            store.flush().unwrap();
        }
        let list = |dir: &Path| -> Vec<(String, Vec<u8>)> {
            let mut files: Vec<_> =
                fs::read_dir(dir).unwrap().map(|e| e.unwrap().path()).collect();
            files.sort();
            files
                .iter()
                .map(|p| {
                    let name = p.file_name().unwrap().to_string_lossy().to_string();
                    assert!(!name.contains(".tmp"), "leftover temp file {name}");
                    (name, fs::read(p).unwrap())
                })
                .collect()
        };
        assert_eq!(list(&dir_a), list(&dir_b));
        let _ = fs::remove_dir_all(&dir_a);
        let _ = fs::remove_dir_all(&dir_b);
    }

    #[test]
    fn model_keys_separate_tags_inputs_and_seeds() {
        let base = || ModelKey::new("fam").rows(&[vec![1.0, 2.0]]).u64(7);
        let k0 = base().finish();
        assert_eq!(k0, base().finish(), "keys are deterministic");
        assert_ne!(k0, ModelKey::new("fam2").rows(&[vec![1.0, 2.0]]).u64(7).finish());
        assert_ne!(k0, base().u64(0).finish());
        assert_ne!(
            ModelKey::new("f").f64s(&[1.0]).f64s(&[]).finish(),
            ModelKey::new("f").f64s(&[]).f64s(&[1.0]).finish(),
            "length prefixes must prevent field aliasing"
        );
        assert_ne!(
            ModelKey::new("f").f64s(&[0.0]).finish(),
            ModelKey::new("f").f64s(&[-0.0]).finish(),
            "bit-pattern hashing distinguishes -0.0"
        );
    }
}
