//! Persistent surrogate-model store (ISSUE 3; rebased onto the shared
//! `coordinator::store` core in ISSUE 4).
//!
//! PR 2 made the *oracle* cache durable, but every warm start still
//! re-tuned and refit the GBDT/RF/ensemble surrogates from scratch —
//! with the oracle served from disk, refitting dominates restart
//! wall-clock. This store makes the fitted models durable too. The
//! whole persistence protocol (shard routing, lazy load, atomic
//! flush, `.store.lock` ordering, merge-on-flush, eviction budgets,
//! compaction) lives in the generic [`ShardedStore`]; this file only
//! defines the artifact record family and the [`ModelKey`] builder:
//!
//! - **Content-hash keys**: a model artifact is keyed by a hash of
//!   everything the fit is a pure function of — training matrices (a
//!   dataset + split + metric fingerprint), tuning budget, and seed —
//!   built through [`ModelKey`]. Same inputs ⇒ same key ⇒ the stored
//!   model replays **bit-identical predictions**, because every model
//!   family serializes its f64s through `util::json`'s exact
//!   round-trip.
//! - **Artifacts** carry their family tag as the record kind and the
//!   family's `to_json` payload under `"model"`; a payload that fails
//!   a family's `from_json` reads as a miss — callers fall back to
//!   refitting (and overwrite the bad artifact at the next flush).
//! - **Cohabitation**: the store lives in a `models/` subdirectory of
//!   the oracle cache dir ([`ModelStore::open_under`]), so one
//!   `--cache-dir` carries both oracle shards and model artifacts
//!   without the two stores' files or locks ever colliding.
//!
//! Readers/writers: `Trainer` (tuned GBDT/RF, ROI classifier, stacked
//! ensemble), `SurrogateBundle::fit_cached` (the DSE surrogate), and
//! `EvalService::fit_surrogate` route through here — read-through on
//! fit requests, write-behind after tuning, flushed by the CLI or the
//! last `Drop`. `--no-model-cache` is the CLI escape hatch.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::util::json::Json;
use crate::util::rng::hash_bytes;

use super::store::{Codec, CompactReport, Record, ShardedStore, StoreConfig, StorePolicy};

/// Record schema version; bump on any *breaking* layout change
/// (loaders skip records whose tag does not match). The ISSUE 4 store
/// core's envelope additions (`used` stamp, `tomb` kind) are additive
/// and deliberately unbumped so PR 3 model directories stay warm — see
/// the matching note on `cache_store::SCHEMA_VERSION`.
pub const SCHEMA_VERSION: u64 = 1;

/// Default shard-file count (model artifacts are few but large, so
/// fewer shards than the oracle store).
pub const DEFAULT_SHARDS: usize = 8;

/// Deterministic content-hash key builder for model artifacts: feed it
/// everything the fitted model is a pure function of (family tag,
/// training matrices, labels, tuning budget, seeds) and `finish`.
/// f64s are hashed by bit pattern, and every field is length-prefixed
/// so adjacent fields cannot alias.
pub struct ModelKey {
    bytes: Vec<u8>,
}

impl ModelKey {
    pub fn new(tag: &str) -> ModelKey {
        let mut bytes = Vec::with_capacity(256);
        bytes.extend_from_slice(tag.as_bytes());
        bytes.push(0);
        ModelKey { bytes }
    }

    pub fn u64(mut self, v: u64) -> ModelKey {
        self.bytes.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn usize(self, v: usize) -> ModelKey {
        self.u64(v as u64)
    }

    pub fn str(mut self, s: &str) -> ModelKey {
        self.bytes.extend_from_slice(&(s.len() as u64).to_le_bytes());
        self.bytes.extend_from_slice(s.as_bytes());
        self
    }

    pub fn f64s(mut self, vs: &[f64]) -> ModelKey {
        self.bytes.extend_from_slice(&(vs.len() as u64).to_le_bytes());
        for v in vs {
            self.bytes.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        self
    }

    pub fn rows(mut self, rows: &[Vec<f64>]) -> ModelKey {
        self.bytes.extend_from_slice(&(rows.len() as u64).to_le_bytes());
        for r in rows {
            self = self.f64s(r);
        }
        self
    }

    pub fn bools(mut self, bs: &[bool]) -> ModelKey {
        self.bytes.extend_from_slice(&(bs.len() as u64).to_le_bytes());
        self.bytes.extend(bs.iter().map(|&b| b as u8));
        self
    }

    pub fn finish(self) -> u64 {
        hash_bytes(&self.bytes)
    }
}

/// Counters for the store (surfaced through `EvalStats` when a service
/// is attached, and printable on their own for CLI summaries).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ModelStoreStats {
    /// Lookups answered with a stored artifact of the requested kind.
    pub hits: usize,
    /// Lookups that found nothing (or a kind mismatch) — the caller
    /// refits.
    pub misses: usize,
    /// Shard files parsed so far (lazy loading).
    pub shard_loads: usize,
    /// `flush` calls that wrote at least one shard.
    pub flushes: usize,
    /// Artifacts currently held.
    pub entries: usize,
    /// Artifacts not yet durable on disk. Exact per-record accounting
    /// (ISSUE 4 fix): a merge-on-flush that folds disk artifacts into
    /// a shard no longer inflates this.
    pub pending: usize,
    /// Eviction tombstones currently held (reclaimed at compaction).
    pub tombstones: usize,
    /// Serialized bytes of the live artifacts (what the `max_bytes`
    /// eviction budget is judged against).
    pub live_bytes: u64,
    /// Artifacts evicted (policy budgets or explicit `evict`) since
    /// open.
    pub evictions: usize,
    /// Compaction passes since open (explicit + automatic).
    pub compactions: usize,
    /// Artifacts scanned but *not* decoded at shard load (storage
    /// engine v2: bodies stay raw frames until materialized).
    pub lazy_skips: usize,
    /// Lazy frames actually decoded into artifacts.
    pub full_decodes: usize,
    /// Point lookups answered by a shard's `.idx` sidecar (definitive
    /// miss or single-frame fetch) without loading the shard.
    pub sidecar_hits: usize,
    /// Sidecars rebuilt after being found missing, torn, or stale.
    pub sidecar_rebuilds: usize,
    /// Artifacts rewritten from the other codec at flush/compact
    /// (mixed-codec directory migration).
    pub transcoded_records: usize,
}

impl std::fmt::Display for ModelStoreStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} artifacts ({} pending, {} B live) | {} hits / {} misses | {} shard loads | {} flushes | {} evicted, {} tombstones, {} compactions | {} lazy skips, {} decodes, {} sidecar hits, {} rebuilds, {} transcoded",
            self.entries,
            self.pending,
            self.live_bytes,
            self.hits,
            self.misses,
            self.shard_loads,
            self.flushes,
            self.evictions,
            self.tombstones,
            self.compactions,
            self.lazy_skips,
            self.full_decodes,
            self.sidecar_hits,
            self.sidecar_rebuilds,
            self.transcoded_records
        )
    }
}

/// One stored artifact: the family tag (record kind) plus the
/// family's `to_json` payload.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelArtifact {
    pub kind: String,
    pub payload: Json,
}

impl Record for ModelArtifact {
    fn kind(&self) -> std::borrow::Cow<'_, str> {
        std::borrow::Cow::Borrowed(self.kind.as_str())
    }

    fn encode(&self, out: &mut Vec<(&'static str, Json)>) {
        out.push(("model", self.payload.clone()));
    }

    fn decode(kind: &str, rec: &Json) -> Option<ModelArtifact> {
        let payload = rec.get("model").clone();
        if payload == Json::Null {
            return None;
        }
        Some(ModelArtifact { kind: kind.to_string(), payload })
    }
}

/// Disk-backed, sharded, read-through/write-behind store for fitted
/// surrogate models: a thin typed wrapper over the shared
/// [`ShardedStore`] core. Thread-safe; share one instance across the
/// trainer and services via `Arc`.
pub struct ModelStore {
    core: ShardedStore<ModelArtifact>,
}

impl ModelStore {
    fn config() -> StoreConfig {
        StoreConfig {
            schema_version: SCHEMA_VERSION,
            default_shards: DEFAULT_SHARDS,
            file_prefix: "model",
            label: "model store",
            policy: StorePolicy::default_auto(),
            codec: Codec::V2Binary,
        }
    }

    /// Open (creating if needed) a model-store directory with the
    /// default shard count. An existing directory keeps the shard
    /// count it was created with (recorded in `meta.json`).
    pub fn open(dir: impl Into<PathBuf>) -> Result<ModelStore> {
        ModelStore::open_sharded(dir, DEFAULT_SHARDS)
    }

    /// The cohabitation entry point: open the model store that lives
    /// under an oracle cache directory (`<cache-dir>/models/`), so one
    /// `--cache-dir` carries both stores.
    pub fn open_under(cache_dir: impl AsRef<Path>) -> Result<ModelStore> {
        ModelStore::open(cache_dir.as_ref().join("models"))
    }

    /// Open with an explicit shard count (ignored when the directory
    /// already records one).
    pub fn open_sharded(dir: impl Into<PathBuf>, n_shards: usize) -> Result<ModelStore> {
        Ok(ModelStore {
            core: ShardedStore::open_sharded(dir, ModelStore::config(), n_shards)?,
        })
    }

    /// Replace the lifecycle policy (eviction budgets, auto-compaction
    /// ratio) before sharing the store.
    pub fn with_policy(self, policy: StorePolicy) -> ModelStore {
        ModelStore { core: self.core.with_policy(policy) }
    }

    /// Select the record codec new shard files are written in
    /// (`--store-codec`). Reads auto-detect either codec regardless.
    pub fn with_codec(self, codec: Codec) -> ModelStore {
        ModelStore { core: self.core.with_codec(codec) }
    }

    /// Active write codec.
    pub fn codec(&self) -> Codec {
        self.core.codec()
    }

    pub fn dir(&self) -> &Path {
        self.core.dir()
    }

    pub fn shard_count(&self) -> usize {
        self.core.shard_count()
    }

    /// Stored artifact payload for (kind, key), if present. A key held
    /// under a different kind reads as a miss (content-hash keys embed
    /// the family tag, so this only happens on adversarial input).
    pub fn get(&self, kind: &str, key: u64) -> Option<Json> {
        self.core.get(kind, key).map(|a| a.payload)
    }

    /// Record an artifact (write-behind: durable at the next flush).
    /// Overwrites an existing entry whose payload differs — that is
    /// how a corrupt artifact gets repaired after the fallback refit.
    pub fn put(&self, kind: &str, key: u64, payload: Json) {
        self.core.put(key, ModelArtifact { kind: kind.to_string(), payload });
    }

    /// Evict an artifact (tombstoned: reads miss, concurrent writers
    /// cannot resurrect it). Returns whether a live artifact was
    /// evicted.
    pub fn evict(&self, key: u64) -> bool {
        self.core.evict(key)
    }

    /// Write every dirty shard atomically, serialized across processes
    /// by the directory lock and merged with the disk state first
    /// (same contract as `CacheStore::flush` — it is literally the
    /// same code). Returns the number of shard files written.
    pub fn flush(&self) -> Result<usize> {
        self.core.flush()
    }

    /// Compaction pass: drop tombstones and dead lines, enforce the
    /// eviction policy, rewrite only the shards whose bytes change.
    pub fn compact(&self) -> Result<CompactReport> {
        self.core.compact()
    }

    /// Force every shard into memory (CLI stats / maintenance).
    pub fn load_all(&self) {
        self.core.load_all()
    }

    /// Snapshot the store counters.
    pub fn stats(&self) -> ModelStoreStats {
        let s = self.core.stats();
        ModelStoreStats {
            hits: s.hits,
            misses: s.misses,
            shard_loads: s.shard_loads,
            flushes: s.flushes,
            entries: s.entries,
            pending: s.pending,
            tombstones: s.tombstones,
            live_bytes: s.live_bytes,
            evictions: s.evictions,
            compactions: s.compactions,
            lazy_skips: s.lazy_skips,
            full_decodes: s.full_decodes,
            sidecar_hits: s.sidecar_hits,
            sidecar_rebuilds: s.sidecar_rebuilds,
            transcoded_records: s.transcoded_records,
        }
    }

    pub fn hits(&self) -> usize {
        self.core.hits()
    }

    pub fn misses(&self) -> usize {
        self.core.misses()
    }

    pub fn shard_loads(&self) -> usize {
        self.core.shard_loads()
    }

    pub fn flush_count(&self) -> usize {
        self.core.flush_count()
    }

    pub fn evictions(&self) -> usize {
        self.core.evictions()
    }

    pub fn compactions(&self) -> usize {
        self.core.compactions()
    }

    pub fn lazy_skips(&self) -> usize {
        self.core.lazy_skips()
    }

    pub fn full_decodes(&self) -> usize {
        self.core.full_decodes()
    }

    pub fn sidecar_hits(&self) -> usize {
        self.core.sidecar_hits()
    }

    pub fn sidecar_rebuilds(&self) -> usize {
        self.core.sidecar_rebuilds()
    }

    pub fn transcoded_records(&self) -> usize {
        self.core.transcoded_records()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("fso-model-store-{}-{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn payload(v: f64) -> Json {
        Json::obj(vec![("w", Json::arr_f64(&[v, -v])), ("b", v.into())])
    }

    /// v1 (JSONL) shard path — only meaningful for stores opened with
    /// `.with_codec(Codec::V1Jsonl)`.
    fn shard_file_of(store: &ModelStore, key: u64) -> PathBuf {
        let shard = ((key >> 56) as usize) % store.shard_count();
        store.dir().join(format!("model-{shard:03}.jsonl"))
    }

    #[test]
    fn artifacts_survive_reopen_byte_exactly() {
        let dir = tmp_dir("roundtrip");
        let key = 0x0123_4567_89ab_cdefu64;
        {
            let store = ModelStore::open(&dir).unwrap();
            store.put("test-family", key, payload(1.0 / 3.0));
            assert_eq!(store.stats().pending, 1);
            store.flush().unwrap();
            assert_eq!(store.stats().pending, 0);
        }
        let store = ModelStore::open(&dir).unwrap();
        let got = store.get("test-family", key).expect("artifact survives reopen");
        assert_eq!(got, payload(1.0 / 3.0));
        assert_eq!(
            got.get("b").as_f64().unwrap().to_bits(),
            (1.0f64 / 3.0).to_bits(),
            "f64 payloads must round-trip bit-exactly"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn kind_mismatch_and_missing_keys_are_misses() {
        let dir = tmp_dir("miss");
        let store = ModelStore::open(&dir).unwrap();
        store.put("family-a", 42, payload(2.0));
        assert!(store.get("family-b", 42).is_none(), "kind mismatch is a miss");
        assert!(store.get("family-a", 43).is_none());
        assert!(store.get("family-a", 42).is_some());
        assert_eq!(store.misses(), 2);
        assert_eq!(store.hits(), 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn put_overwrites_changed_payloads() {
        // the corrupt-artifact repair path: a refit must replace the
        // stored payload, not be swallowed by insert-if-absent
        let dir = tmp_dir("overwrite");
        {
            let store = ModelStore::open(&dir).unwrap();
            store.put("f", 7, payload(1.0));
            store.flush().unwrap();
            store.put("f", 7, payload(2.0));
            assert_eq!(store.stats().pending, 1, "changed payload re-dirties");
            store.put("f", 7, payload(2.0));
            store.flush().unwrap();
        }
        let store = ModelStore::open(&dir).unwrap();
        assert_eq!(store.get("f", 7).unwrap(), payload(2.0));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_lines_and_unknown_versions_are_skipped() {
        let dir = tmp_dir("skip");
        let key = 0x0500_0000_0000_0042u64;
        {
            // write as v1 JSONL so raw text lines can be appended below
            let store = ModelStore::open(&dir).unwrap().with_codec(Codec::V1Jsonl);
            store.put("f", key, payload(3.0));
            store.flush().unwrap();
        }
        let store = ModelStore::open(&dir).unwrap();
        let shard_path = shard_file_of(&store, key);
        drop(store);
        let mut text = fs::read_to_string(&shard_path).unwrap();
        text.push_str("{ not json\n");
        text.push_str("{\"v\":999,\"kind\":\"f\",\"key\":\"0500000000000043\",\"model\":{}}\n");
        text.push_str("{\"v\":1,\"kind\":\"f\",\"key\":\"0500000000000044\"}\n"); // no payload
        fs::write(&shard_path, text).unwrap();
        let store = ModelStore::open(&dir).unwrap();
        assert!(store.get("f", key).is_some(), "good record still loads");
        assert!(store.get("f", 0x0500_0000_0000_0043).is_none(), "v999 skipped");
        assert!(store.get("f", 0x0500_0000_0000_0044).is_none(), "payload-less skipped");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn concurrent_stores_merge_on_flush() {
        let dir = tmp_dir("merge");
        let a = ModelStore::open(&dir).unwrap();
        let b = ModelStore::open(&dir).unwrap();
        // same shard (same top byte), different keys
        a.put("f", 0x0b00_0000_0000_0001, payload(1.0));
        b.put("f", 0x0b00_0000_0000_0002, payload(2.0));
        a.flush().unwrap();
        b.flush().unwrap();
        drop(a);
        drop(b);
        let c = ModelStore::open(&dir).unwrap();
        assert!(c.get("f", 0x0b00_0000_0000_0001).is_some(), "merge-on-flush");
        assert!(c.get("f", 0x0b00_0000_0000_0002).is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn shard_files_are_byte_deterministic() {
        let dir_a = tmp_dir("det-a");
        let dir_b = tmp_dir("det-b");
        let keys: Vec<u64> = (0..24u64)
            .map(|i| crate::util::rng::hash_bytes(&i.to_le_bytes()))
            .collect();
        {
            let store = ModelStore::open(&dir_a).unwrap();
            for &k in &keys {
                store.put("f", k, payload(k as f64));
            }
            store.flush().unwrap();
        }
        {
            let store = ModelStore::open(&dir_b).unwrap();
            for &k in keys.iter().rev() {
                store.put("f", k, payload(k as f64));
            }
            store.flush().unwrap();
        }
        let list = |dir: &Path| -> Vec<(String, Vec<u8>)> {
            let mut files: Vec<_> =
                fs::read_dir(dir).unwrap().map(|e| e.unwrap().path()).collect();
            files.sort();
            files
                .iter()
                .map(|p| {
                    let name = p.file_name().unwrap().to_string_lossy().to_string();
                    assert!(!name.contains(".tmp"), "leftover temp file {name}");
                    (name, fs::read(p).unwrap())
                })
                .collect()
        };
        assert_eq!(list(&dir_a), list(&dir_b));
        let _ = fs::remove_dir_all(&dir_a);
        let _ = fs::remove_dir_all(&dir_b);
    }

    #[test]
    fn model_keys_separate_tags_inputs_and_seeds() {
        let base = || ModelKey::new("fam").rows(&[vec![1.0, 2.0]]).u64(7);
        let k0 = base().finish();
        assert_eq!(k0, base().finish(), "keys are deterministic");
        assert_ne!(k0, ModelKey::new("fam2").rows(&[vec![1.0, 2.0]]).u64(7).finish());
        assert_ne!(k0, base().u64(0).finish());
        assert_ne!(
            ModelKey::new("f").f64s(&[1.0]).f64s(&[]).finish(),
            ModelKey::new("f").f64s(&[]).f64s(&[1.0]).finish(),
            "length prefixes must prevent field aliasing"
        );
        assert_ne!(
            ModelKey::new("f").f64s(&[0.0]).finish(),
            ModelKey::new("f").f64s(&[-0.0]).finish(),
            "bit-pattern hashing distinguishes -0.0"
        );
    }

    #[test]
    fn pending_count_is_exact_after_merge_on_flush() {
        // ISSUE 4 satellite regression, model-store side (same drift
        // as the oracle store: pending must never count disk-merged
        // shardmates of a dirty record)
        let dir = tmp_dir("pending-drift");
        {
            let other = ModelStore::open(&dir).unwrap();
            other.put("f", 0x0c00_0000_0000_0001, payload(1.0));
            other.put("f", 0x0c00_0000_0000_0002, payload(2.0));
            other.flush().unwrap();
        }
        let store = ModelStore::open(&dir).unwrap();
        store.put("f", 0x0c00_0000_0000_0003, payload(3.0));
        assert_eq!(store.stats().pending, 1);
        store.flush().unwrap();
        let s = store.stats();
        assert_eq!(s.entries, 3);
        assert_eq!(s.pending, 0, "everything durable after the flush: {s}");
        store.put("f", 0x0c00_0000_0000_0004, payload(4.0));
        let s = store.stats();
        assert_eq!(
            s.pending, 1,
            "only the new artifact is pending, not its disk-merged shardmates: {s}"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn record_budget_evicts_lru_artifacts() {
        use crate::coordinator::store::StorePolicy;
        let dir = tmp_dir("budget");
        {
            let store = ModelStore::open(&dir).unwrap(); // epoch 1
            for i in 0..5u64 {
                store.put("f", 0x0d00_0000_0000_0000 + i, payload(i as f64));
            }
            store.flush().unwrap();
        }
        // epoch 2: keep 2; key 1 is freshly used, key 9 freshly put
        let store = ModelStore::open(&dir)
            .unwrap()
            .with_policy(StorePolicy { max_records: Some(2), ..StorePolicy::default() });
        assert!(store.get("f", 0x0d00_0000_0000_0001).is_some());
        store.put("f", 0x0d00_0000_0000_0009, payload(9.0));
        store.flush().unwrap();
        let s = store.stats();
        assert_eq!(s.entries, 2, "budget must hold: {s}");
        assert!(s.evictions >= 4, "4 stale artifacts evicted: {s}");
        assert!(store.get("f", 0x0d00_0000_0000_0001).is_some(), "LRU keeps fresh use");
        assert!(store.get("f", 0x0d00_0000_0000_0009).is_some(), "LRU keeps fresh put");
        assert!(store.get("f", 0x0d00_0000_0000_0000).is_none());
        assert!(store.get("f", 0x0d00_0000_0000_0002).is_none());
        let _ = fs::remove_dir_all(&dir);
    }
}
