//! Dynamic-batching prediction server: the PJRT engine is Rc-based and
//! thread-bound, so it lives on a dedicated service thread; clients
//! submit rows over a channel and the server coalesces whatever is
//! queued into padded fixed-B batches (one PJRT call per batch) before
//! replying. This is the vLLM-router-shaped L3 piece: DSE workers fan
//! requests in concurrently and batching amortizes the FFI boundary.

use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::runtime::{Batcher, Engine};
use crate::util::tensor::Tensor;

enum Msg {
    Predict {
        /// ANN variant name.
        variant: String,
        /// Fitted flat parameters.
        theta: Vec<f32>,
        /// Feature rows (already scaled/encoded).
        rows: Vec<Vec<f32>>,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    Stats(mpsc::Sender<ServerStats>),
    Shutdown,
}

#[derive(Debug, Clone, Default)]
pub struct ServerStats {
    pub requests: usize,
    pub rows: usize,
    pub batches: usize,
    /// Mean rows per issued batch (batching efficiency).
    pub mean_occupancy: f64,
}

pub struct PredictServer {
    tx: mpsc::Sender<Msg>,
    handle: Option<JoinHandle<()>>,
}

/// Cheap cloneable submit handle.
#[derive(Clone)]
pub struct PredictClient {
    tx: mpsc::Sender<Msg>,
}

impl PredictServer {
    /// Boot the service thread with its own Engine.
    pub fn start(artifacts_dir: std::path::PathBuf) -> Result<PredictServer> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let handle = std::thread::spawn(move || {
            let engine = match Engine::load(&artifacts_dir) {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            let mut stats = ServerStats::default();
            serve(engine, rx, &mut stats);
        });
        ready_rx
            .recv()
            .context("predict server died at startup")??;
        Ok(PredictServer { tx, handle: Some(handle) })
    }

    pub fn client(&self) -> PredictClient {
        PredictClient { tx: self.tx.clone() }
    }

    pub fn stats(&self) -> Result<ServerStats> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Msg::Stats(tx)).context("server gone")?;
        rx.recv().context("server gone")
    }
}

impl Drop for PredictServer {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl PredictClient {
    /// Synchronous predict (the server batches across concurrent
    /// clients; a single client's rows are also internally chunked).
    pub fn predict(
        &self,
        variant: &str,
        theta: &[f32],
        rows: Vec<Vec<f32>>,
    ) -> Result<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Predict {
                variant: variant.to_string(),
                theta: theta.to_vec(),
                rows,
                reply,
            })
            .context("predict server gone")?;
        rx.recv().context("predict server dropped the request")?
    }
}

fn serve(engine: Engine, rx: mpsc::Receiver<Msg>, stats: &mut ServerStats) {
    while let Ok(msg) = rx.recv() {
        // Drain whatever else is queued: coalescing window.
        let mut pending = vec![msg];
        while let Ok(m) = rx.try_recv() {
            pending.push(m);
        }
        // group Predict requests by (variant, theta) so they can share
        // batches; reply to everything else inline
        let mut groups: Vec<(String, Vec<f32>, Vec<(Vec<Vec<f32>>, mpsc::Sender<Result<Vec<f32>>>)>)> =
            Vec::new();
        for m in pending {
            match m {
                Msg::Shutdown => return,
                Msg::Stats(tx) => {
                    let mut s = stats.clone();
                    s.mean_occupancy = if s.batches > 0 {
                        s.rows as f64 / s.batches as f64
                    } else {
                        0.0
                    };
                    let _ = tx.send(s);
                }
                Msg::Predict { variant, theta, rows, reply } => {
                    stats.requests += 1;
                    stats.rows += rows.len();
                    if let Some(g) = groups
                        .iter_mut()
                        .find(|(v, t, _)| *v == variant && *t == theta)
                    {
                        g.2.push((rows, reply));
                    } else {
                        groups.push((variant, theta, vec![(rows, reply)]));
                    }
                }
            }
        }
        for (variant, theta, requests) in groups {
            run_group(&engine, &variant, &theta, requests, stats);
        }
    }
}

type PredictRequest = (Vec<Vec<f32>>, mpsc::Sender<Result<Vec<f32>>>);

/// Reject requests whose feature rows don't match the manifest width
/// (ISSUE 5 satellite): each offending request gets a per-request
/// error reply and is dropped from the batch, so cohabiting requests
/// in the same coalescing window are scored normally. Rows used to be
/// silently zero-padded or truncated to fit, corrupting predictions.
fn reject_bad_rows(requests: Vec<PredictRequest>, feat: usize) -> Vec<PredictRequest> {
    let mut valid = Vec::with_capacity(requests.len());
    for (rows, reply) in requests {
        match rows.iter().find(|r| r.len() != feat) {
            Some(bad) => {
                let _ = reply.send(Err(anyhow::anyhow!(
                    "feature row has {} values, manifest expects {feat}",
                    bad.len()
                )));
            }
            None => valid.push((rows, reply)),
        }
    }
    valid
}

fn run_group(
    engine: &Engine,
    variant: &str,
    theta: &[f32],
    requests: Vec<PredictRequest>,
    stats: &mut ServerStats,
) {
    let requests = reject_bad_rows(requests, engine.manifest.feat);
    if requests.is_empty() {
        return;
    }
    let mut run = || -> Result<Vec<Vec<f32>>> {
        let v = engine.manifest.variant(variant)?;
        let file = v.entrypoint("predict")?.file.clone();
        let b = engine.manifest.batch;
        let f = engine.manifest.feat;
        let theta_t = Tensor::from_vec(&[v.param_total], theta.to_vec())?;
        // flatten all requests into one row stream
        let all_rows: Vec<&Vec<f32>> =
            requests.iter().flat_map(|(rows, _)| rows.iter()).collect();
        let batcher = Batcher::new(b);
        let mut flat_out = vec![0.0f32; all_rows.len()];
        for plan in batcher.plan(all_rows.len()) {
            let mut packed = vec![0.0f32; b * f];
            for (slot, &src) in plan.rows.iter().enumerate() {
                // row widths are validated above: exact copy
                let row = all_rows[src];
                packed[slot * f..(slot + 1) * f].copy_from_slice(row);
            }
            let x = Tensor::from_vec(&[b, f], packed)?;
            let out = engine.run(&file, &[theta_t.clone(), x])?;
            batcher.unpack(&plan, out[0].data(), &mut flat_out);
            stats.batches += 1;
        }
        // split back per request
        let mut result = Vec::with_capacity(requests.len());
        let mut off = 0;
        for (rows, _) in &requests {
            result.push(flat_out[off..off + rows.len()].to_vec());
            off += rows.len();
        }
        Ok(result)
    };
    match run() {
        Ok(outputs) => {
            for ((_, reply), out) in requests.into_iter().zip(outputs) {
                let _ = reply.send(Ok(out));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for (_, reply) in requests {
                let _ = reply.send(Err(anyhow::anyhow!("{msg}")));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bad_row_widths_error_per_request_without_poisoning_neighbors() {
        // ISSUE 5 satellite regression: a mis-sized feature row used to
        // be silently zero-padded/truncated into the packed batch; now
        // the offending request errors and its neighbors score normally
        let (tx_ok, rx_ok) = mpsc::channel();
        let (tx_short, rx_short) = mpsc::channel();
        let (tx_long, rx_long) = mpsc::channel();
        let requests: Vec<PredictRequest> = vec![
            (vec![vec![0.0; 4], vec![1.0; 4]], tx_ok),
            (vec![vec![0.0; 4], vec![0.0; 3]], tx_short),
            (vec![vec![0.0; 5]], tx_long),
        ];
        let valid = reject_bad_rows(requests, 4);
        assert_eq!(valid.len(), 1, "only the well-formed request survives");
        assert_eq!(valid[0].0.len(), 2);
        assert!(
            rx_ok.try_recv().is_err(),
            "the surviving request must not be answered by validation"
        );
        let err = rx_short.recv().unwrap().expect_err("short row must error");
        assert!(format!("{err:#}").contains("3 values"), "{err:#}");
        let err = rx_long.recv().unwrap().expect_err("long row must error");
        assert!(format!("{err:#}").contains("5 values"), "{err:#}");
    }

    #[test]
    fn empty_and_exact_requests_pass_validation() {
        let (tx_a, _rx_a) = mpsc::channel();
        let (tx_b, _rx_b) = mpsc::channel();
        let valid =
            reject_bad_rows(vec![(vec![], tx_a), (vec![vec![0.5; 7]], tx_b)], 7);
        assert_eq!(valid.len(), 2, "zero-row and exact-width requests are fine");
    }
}
