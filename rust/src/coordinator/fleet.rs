//! Distributed evaluation fleet (ISSUE 10 tentpole): a leader process
//! (`fso fleet lead`) owns the MOTPE/strategy loop, the single-flight
//! table, and the sharded stores, while N worker processes
//! (`fso fleet work --connect`) run the SP&R-oracle + simulator
//! evaluations and ship the results back over the PR 9 newline-JSON
//! protocol (`claim` / `result` / `heartbeat` ops in the route table).
//!
//! Topology:
//!
//! ```text
//!   fso fleet lead ──(TcpListener, serve_loop)──┬── fso fleet work #1
//!     │  MOTPE loop → EvalService               ├── fso fleet work #2
//!     │    └─ RemoteOracle = FleetOracle        └── fso fleet work #N
//!     │         └─ FleetQueue (lease + requeue)
//!     └─ ShardedStore (leader-only writer)
//! ```
//!
//! Claim/lease protocol: the leader enqueues one task per *full* cache
//! miss (memo and store hits never leave the leader); a worker `claim`
//! takes the oldest queued key under a lease; `heartbeat` renews every
//! lease the worker holds; a lease that expires without a `result`
//! requeues the key so another worker picks it up. The first `result`
//! per key wins — late duplicates from a slow-but-alive worker are
//! counted and dropped, never double-applied.
//!
//! Determinism contract (the repo's spine, now at fleet scale): a fixed
//! seed and *any* worker count produce byte-identical CSV rows, Pareto
//! fronts, and flushed shard files. The leader is the only store
//! writer, workers recompute the deterministic oracle from
//! `(enablement, seed)` shipped in each task, and the wire codec
//! reuses the store's bit-exact f64 JSON round-trip — so a remote
//! evaluation is bit-for-bit the evaluation the leader would have
//! computed itself.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::backend::{BackendConfig, Enablement};
use crate::generators::{ArchConfig, Platform};
use crate::util::json::Json;
use crate::workloads::{self, NonDnnAlgo, NonDnnWorkload, WorkloadSpec};

use super::cache_store;
use super::coalesce::EvalRouter;
use super::eval_service::{EvalService, Evaluation, RemoteOracle, RemoteTask};
use super::server::listener::serve_loop;
use super::server::protocol::{LineEvent, LineReader};
use super::server::router::ServerState;
use super::server::{drain, ServeStats};
use super::store::{hex_key, parse_hex_key};

/// Default lease on a claimed task before the leader assumes the
/// worker died and requeues the key.
pub const DEFAULT_LEASE_MS: u64 = 3_000;

/// Worker heartbeat period. Comfortably inside both the default lease
/// and the shortened leases the recovery tests use (500 ms).
const HEARTBEAT_MS: u64 = 150;

/// How long an idle worker sleeps between empty `claim` polls.
const IDLE_POLL_MS: u64 = 10;

// ---- task wire format ----------------------------------------------

/// Everything a worker needs to recompute one evaluation, plus the
/// leader-side keys that correlate the result back to its waiter.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    /// Full oracle cache key (arch × backend × workload × trial) — the
    /// correlation id for `result`.
    pub key: u64,
    /// Flow-level key (arch × backend), carried for log correlation.
    pub flow_key: u64,
    pub arch: ArchConfig,
    pub f_target_ghz: f64,
    pub util: f64,
    pub workload: Option<WorkloadSpec>,
    pub trial: u64,
    pub enablement: Enablement,
    pub seed: u64,
}

impl TaskSpec {
    pub fn from_remote(task: &RemoteTask<'_>) -> TaskSpec {
        TaskSpec {
            key: task.key,
            flow_key: task.flow_key,
            arch: task.arch.clone(),
            f_target_ghz: task.bcfg.f_target_ghz,
            util: task.bcfg.util,
            workload: task.wl.cloned(),
            trial: task.trial,
            enablement: task.enablement,
            seed: task.seed,
        }
    }

    /// Wire encoding. Keys and the seed ride as 16-digit hex strings:
    /// request ids decode through f64 and a u64 above 2^53 would lose
    /// bits as a JSON number.
    pub fn to_json(&self) -> Json {
        let workload = match &self.workload {
            None => Json::Null,
            Some(WorkloadSpec::Dnn(net)) => Json::obj(vec![
                ("kind", Json::from("dnn")),
                ("name", Json::from(net.name)),
            ]),
            Some(WorkloadSpec::NonDnn(wl)) => Json::obj(vec![
                ("algo", Json::from(wl.algo.name())),
                ("epochs", Json::from(wl.epochs)),
                ("features", Json::from(wl.features)),
                ("kind", Json::from("nondnn")),
                ("samples", Json::from(wl.samples)),
            ]),
        };
        Json::obj(vec![
            ("arch", Json::arr_f64(&self.arch.values)),
            ("enablement", Json::from(self.enablement.name())),
            ("f", Json::from(self.f_target_ghz)),
            ("flow_key", Json::from(hex_key(self.flow_key).as_str())),
            ("key", Json::from(hex_key(self.key).as_str())),
            ("platform", Json::from(self.arch.platform.name())),
            ("seed", Json::from(hex_key(self.seed).as_str())),
            ("trial", Json::from(self.trial as usize)),
            ("util", Json::from(self.util)),
            ("workload", workload),
        ])
    }

    pub fn from_json(j: &Json) -> Result<TaskSpec> {
        let hex = |field: &str| -> Result<u64> {
            j.get(field)
                .as_str()
                .and_then(parse_hex_key)
                .ok_or_else(|| anyhow!("task field {field:?} must be a hex key string"))
        };
        let num = |field: &str| -> Result<f64> {
            j.get(field).as_f64().ok_or_else(|| anyhow!("task field {field:?} must be a number"))
        };
        let platform = Platform::from_name(
            j.get("platform").as_str().ok_or_else(|| anyhow!("task field \"platform\" missing"))?,
        )?;
        let values = j
            .get("arch")
            .as_arr()
            .ok_or_else(|| anyhow!("task field \"arch\" must be an array"))?
            .iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow!("task \"arch\" must hold numbers")))
            .collect::<Result<Vec<f64>>>()?;
        let workload = match j.get("workload") {
            Json::Null => None,
            w => Some(workload_from_json(w)?),
        };
        Ok(TaskSpec {
            key: hex("key")?,
            flow_key: hex("flow_key")?,
            arch: ArchConfig::new(platform, values),
            f_target_ghz: num("f")?,
            util: num("util")?,
            workload,
            trial: num("trial")? as u64,
            enablement: Enablement::from_name(
                j.get("enablement")
                    .as_str()
                    .ok_or_else(|| anyhow!("task field \"enablement\" missing"))?,
            )?,
            seed: hex("seed")?,
        })
    }
}

fn workload_from_json(w: &Json) -> Result<WorkloadSpec> {
    match w.get("kind").as_str() {
        Some("dnn") => {
            let name =
                w.get("name").as_str().ok_or_else(|| anyhow!("dnn workload needs \"name\""))?;
            let spec = workloads::lookup(name)?;
            if !spec.is_dnn() {
                bail!("workload {name:?} is not a DNN");
            }
            Ok(spec)
        }
        Some("nondnn") => {
            let algo_name =
                w.get("algo").as_str().ok_or_else(|| anyhow!("nondnn workload needs \"algo\""))?;
            let algo = NonDnnAlgo::from_name(algo_name)
                .ok_or_else(|| anyhow!("unknown nondnn algo {algo_name:?}"))?;
            let usz = |field: &str| -> Result<usize> {
                w.get(field)
                    .as_usize()
                    .ok_or_else(|| anyhow!("nondnn workload field {field:?} must be a count"))
            };
            Ok(WorkloadSpec::NonDnn(NonDnnWorkload {
                algo,
                features: usz("features")?,
                samples: usz("samples")?,
                epochs: usz("epochs")?,
            }))
        }
        other => bail!("unknown workload kind {other:?} (dnn|nondnn)"),
    }
}

/// Encode a computed evaluation in the cache store's record shape
/// (`synth` / `backend` / `system` sub-objects), so the decode side is
/// the store's own bit-exact `eval_from_json` — one f64 codec for disk
/// and wire.
pub fn eval_to_json(ev: &Evaluation) -> Json {
    Json::obj(vec![
        ("backend", cache_store::backend_to_json(&ev.flow.backend)),
        ("synth", cache_store::synth_to_json(&ev.flow.synth)),
        ("system", cache_store::system_to_json(&ev.system)),
    ])
}

/// Decode a worker's evaluation payload (inverse of [`eval_to_json`]).
pub fn eval_from_wire(j: &Json) -> Result<Evaluation> {
    cache_store::eval_from_json(j)
        .ok_or_else(|| anyhow!("malformed evaluation payload (need synth/backend/system)"))
}

// ---- the leader's task queue ---------------------------------------

enum TaskState {
    Queued,
    Claimed { worker: u64, deadline: Instant },
    Done,
}

struct TaskEntry {
    spec: TaskSpec,
    state: TaskState,
}

#[derive(Default)]
struct QueueInner {
    /// Every live task by key (BTreeMap: deterministic iteration for
    /// lease-expiry sweeps and the summary line).
    tasks: BTreeMap<u64, TaskEntry>,
    /// Claim order: oldest enqueued key first. May hold stale keys
    /// (completed while requeued); `claim` skips anything not Queued.
    pending: VecDeque<u64>,
    /// First-result-wins result slots, consumed by `await_result`.
    results: BTreeMap<u64, Result<Evaluation, String>>,
    draining: bool,
    tasks_enqueued: usize,
    claims: usize,
    completions: usize,
    requeues: usize,
    duplicate_results: usize,
}

/// Leader-side work queue shared between the experiment loop (producer
/// via [`FleetOracle`]) and the `claim`/`result`/`heartbeat` handlers
/// (consumers, one per worker connection thread).
pub struct FleetQueue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
    lease: Duration,
}

/// Counter snapshot for the leader's exit summary (and the recovery
/// test's `requeues >= 1` assertion).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FleetCounters {
    pub tasks_enqueued: usize,
    pub claims: usize,
    pub completions: usize,
    pub requeues: usize,
    pub duplicate_results: usize,
}

impl FleetQueue {
    pub fn new(lease_ms: u64) -> FleetQueue {
        FleetQueue {
            inner: Mutex::new(QueueInner::default()),
            cv: Condvar::new(),
            lease: Duration::from_millis(lease_ms.max(1)),
        }
    }

    pub fn lease_ms(&self) -> u64 {
        self.lease.as_millis() as u64
    }

    /// Requeue every claimed task whose lease has expired (worker died
    /// or wedged). Caller holds the lock.
    fn requeue_expired_locked(inner: &mut QueueInner, now: Instant) {
        let mut expired: Vec<u64> = Vec::new();
        for (key, entry) in &inner.tasks {
            if let TaskState::Claimed { deadline, .. } = entry.state {
                if deadline <= now {
                    expired.push(*key);
                }
            }
        }
        for key in expired {
            if let Some(entry) = inner.tasks.get_mut(&key) {
                entry.state = TaskState::Queued;
                inner.pending.push_back(key);
                inner.requeues += 1;
            }
        }
    }

    /// Queue a task for the fleet. Returns `false` (and does nothing)
    /// if the key is already queued, claimed, or completed-unconsumed —
    /// the leader's single-flight table makes that unreachable in
    /// practice, but the queue stays safe without it.
    pub fn enqueue(&self, spec: TaskSpec) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if inner.tasks.contains_key(&spec.key) {
            return false;
        }
        let key = spec.key;
        inner.tasks.insert(key, TaskEntry { spec, state: TaskState::Queued });
        inner.pending.push_back(key);
        inner.tasks_enqueued += 1;
        self.cv.notify_all();
        true
    }

    /// Worker claim: oldest queued task, under a fresh lease. `None`
    /// when the queue is dry (the worker sleeps and re-polls).
    pub fn claim(&self, worker: u64) -> Option<TaskSpec> {
        let now = Instant::now();
        let mut inner = self.inner.lock().unwrap();
        Self::requeue_expired_locked(&mut inner, now);
        while let Some(key) = inner.pending.pop_front() {
            let lease = self.lease;
            if let Some(entry) = inner.tasks.get_mut(&key) {
                if matches!(entry.state, TaskState::Queued) {
                    entry.state = TaskState::Claimed { worker, deadline: now + lease };
                    inner.claims += 1;
                    return Some(entry.spec.clone());
                }
            }
            // stale pending entry (completed or re-claimed): skip
        }
        None
    }

    /// Renew every lease the worker holds; returns how many.
    pub fn heartbeat(&self, worker: u64) -> usize {
        let deadline = Instant::now() + self.lease;
        let mut inner = self.inner.lock().unwrap();
        let mut renewed = 0;
        for entry in inner.tasks.values_mut() {
            if let TaskState::Claimed { worker: w, deadline: d } = &mut entry.state {
                if *w == worker {
                    *d = deadline;
                    renewed += 1;
                }
            }
        }
        renewed
    }

    /// Record a worker's result. First result per key wins; duplicates
    /// (a requeued key completed twice, or a result for an already
    /// consumed key) are counted and dropped. Returns whether the
    /// result was fresh.
    pub fn complete(&self, key: u64, result: Result<Evaluation, String>) -> bool {
        let mut inner = self.inner.lock().unwrap();
        match inner.tasks.get_mut(&key) {
            Some(entry) if !matches!(entry.state, TaskState::Done) => {
                entry.state = TaskState::Done;
                inner.results.insert(key, result);
                inner.completions += 1;
                self.cv.notify_all();
                true
            }
            _ => {
                inner.duplicate_results += 1;
                false
            }
        }
    }

    /// Block the experiment loop until some worker completes `key`.
    /// Wakes periodically to requeue expired leases, so a worker dying
    /// mid-task delays the result by one lease instead of hanging the
    /// run.
    pub fn await_result(&self, key: u64) -> Result<Evaluation> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            Self::requeue_expired_locked(&mut inner, Instant::now());
            if let Some(result) = inner.results.remove(&key) {
                inner.tasks.remove(&key);
                return result.map_err(|msg| {
                    anyhow!("{msg}").context("fleet worker evaluation failed")
                });
            }
            let (guard, _) = self.cv.wait_timeout(inner, Duration::from_millis(50)).unwrap();
            inner = guard;
        }
    }

    /// Tell claiming workers to exit (`drain: true` on the next claim).
    pub fn drain(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.draining = true;
        self.cv.notify_all();
    }

    pub fn draining(&self) -> bool {
        self.inner.lock().unwrap().draining
    }

    pub fn counters(&self) -> FleetCounters {
        let inner = self.inner.lock().unwrap();
        FleetCounters {
            tasks_enqueued: inner.tasks_enqueued,
            claims: inner.claims,
            completions: inner.completions,
            requeues: inner.requeues,
            duplicate_results: inner.duplicate_results,
        }
    }
}

/// The leader's [`RemoteOracle`]: ship each full cache miss to the
/// fleet and block the calling (single-flight leader) thread on the
/// result.
pub struct FleetOracle {
    queue: Arc<FleetQueue>,
}

impl FleetOracle {
    pub fn new(queue: Arc<FleetQueue>) -> FleetOracle {
        FleetOracle { queue }
    }
}

impl RemoteOracle for FleetOracle {
    fn evaluate_remote(&self, task: &RemoteTask<'_>) -> Result<Evaluation> {
        self.queue.enqueue(TaskSpec::from_remote(task));
        self.queue.await_result(task.key)
    }
}

// ---- the worker's client loop --------------------------------------

/// A blocking newline-JSON client connection to the leader.
pub struct FleetConn {
    stream: TcpStream,
    reader: LineReader,
    next_id: u64,
}

impl FleetConn {
    pub fn connect(addr: &str) -> Result<FleetConn> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to fleet leader at {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(FleetConn { stream, reader: LineReader::new(), next_id: 0 })
    }

    /// One request/response round-trip. Any transport or protocol
    /// error is terminal for the connection.
    pub fn request(&mut self, op: &str, body: Json) -> Result<Json> {
        self.next_id += 1;
        let mut line = Json::obj(vec![
            ("body", body),
            ("id", Json::from(self.next_id as usize)),
            ("op", Json::from(op)),
        ])
        .to_string();
        line.push('\n');
        self.stream
            .write_all(line.as_bytes())
            .with_context(|| format!("sending {op:?} to fleet leader"))?;
        loop {
            match self.reader.poll_line(&mut self.stream)? {
                LineEvent::Line(bytes) => {
                    let text = std::str::from_utf8(&bytes)
                        .map_err(|_| anyhow!("non-UTF8 response line from leader"))?;
                    let doc = Json::parse(text.trim())
                        .map_err(|e| anyhow!("malformed response line from leader: {e}"))?;
                    if doc.get("ok").as_bool() == Some(true) {
                        return Ok(doc.get("body").clone());
                    }
                    bail!(
                        "fleet {op:?} request failed (code {}): {}",
                        doc.get("code").as_usize().unwrap_or(0),
                        doc.get("error").as_str().unwrap_or("unknown error"),
                    );
                }
                LineEvent::TimedOut => continue,
                LineEvent::Eof => bail!("fleet leader closed the connection"),
                LineEvent::Oversized => bail!("oversized response line from leader"),
            }
        }
    }
}

fn heartbeat_loop(addr: &str, worker: u64, stop: &AtomicBool) {
    let mut conn = match FleetConn::connect(addr) {
        Ok(c) => c,
        Err(_) => return,
    };
    let body = || Json::obj(vec![("worker", Json::from(worker as usize))]);
    while !stop.load(Ordering::SeqCst) {
        // HEARTBEAT_MS period in small slices so stop is prompt
        for _ in 0..(HEARTBEAT_MS / IDLE_POLL_MS) {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            std::thread::sleep(Duration::from_millis(IDLE_POLL_MS));
        }
        if conn.request("heartbeat", body()).is_err() {
            return;
        }
    }
}

/// `fso fleet work --connect ADDR`: claim → evaluate → result until
/// the leader drains (or the connection drops). `exit_after` is the
/// recovery tests' deterministic kill switch: the process dies right
/// after its Nth claim, *before* the result ships, so the leader must
/// requeue exactly that key.
pub fn run_worker(connect: &str, exit_after: Option<usize>) -> Result<()> {
    let worker = std::process::id() as u64;
    let mut conn = FleetConn::connect(connect)?;
    let stop = Arc::new(AtomicBool::new(false));
    let hb = {
        let stop = Arc::clone(&stop);
        let addr = connect.to_string();
        std::thread::spawn(move || heartbeat_loop(&addr, worker, &stop))
    };
    eprintln!("[fleet] worker {worker} connected to {connect}");

    // one deterministic evaluation stack per (enablement, seed) the
    // leader ships — storeless: the leader is the only store writer
    let mut services: HashMap<(&'static str, u64), EvalService> = HashMap::new();
    let mut claimed = 0usize;
    let mut completed = 0usize;
    let claim_body = Json::obj(vec![("worker", Json::from(worker as usize))]);
    loop {
        let resp = match conn.request("claim", claim_body.clone()) {
            Ok(r) => r,
            // leader drained and closed the socket: a clean exit
            Err(_) => break,
        };
        if resp.get("drain").as_bool() == Some(true) {
            break;
        }
        let task = resp.get("task");
        if matches!(task, Json::Null) {
            std::thread::sleep(Duration::from_millis(IDLE_POLL_MS));
            continue;
        }
        let spec = TaskSpec::from_json(task).context("decoding claimed task")?;
        claimed += 1;
        if exit_after == Some(claimed) {
            eprintln!("[fleet] worker {worker} dying after claim #{claimed} (--exit-after)");
            std::process::exit(17);
        }
        let svc = services
            .entry((spec.enablement.name(), spec.seed))
            .or_insert_with(|| EvalService::new(spec.enablement, spec.seed));
        let bcfg = BackendConfig::new(spec.f_target_ghz, spec.util);
        let key_json = Json::from(hex_key(spec.key).as_str());
        let body = match svc.evaluate_trial(&spec.arch, bcfg, spec.workload.as_ref(), spec.trial) {
            Ok(ev) => Json::obj(vec![("eval", eval_to_json(&ev)), ("key", key_json)]),
            Err(e) => {
                Json::obj(vec![("error", Json::from(format!("{e:#}").as_str())), ("key", key_json)])
            }
        };
        match conn.request("result", body) {
            Ok(_) => completed += 1,
            Err(_) => break,
        }
    }
    stop.store(true, Ordering::SeqCst);
    let _ = hb.join();
    eprintln!("[fleet] worker {worker} done claimed={claimed} completed={completed}");
    Ok(())
}

// ---- the leader ----------------------------------------------------

/// Configuration for [`run_leader`].
pub struct LeaderOptions {
    /// `HOST:PORT` to bind; port 0 picks an ephemeral port (the bound
    /// address is printed to stdout as `listening on ADDR`, same as
    /// `fso serve`).
    pub listen: String,
    /// Claim lease in milliseconds before a silent worker's task is
    /// requeued.
    pub lease_ms: u64,
}

impl Default for LeaderOptions {
    fn default() -> LeaderOptions {
        LeaderOptions { listen: "127.0.0.1:0".to_string(), lease_ms: DEFAULT_LEASE_MS }
    }
}

/// Run an experiment as the fleet leader: bind the claim/result
/// listener, hand the experiment closure the shared [`FleetQueue`] (it
/// wires a [`FleetOracle`] into its `EvalService`), and drain the
/// fleet when the experiment returns. The leader's listener state uses
/// a storeless service — the experiment owns the real stores, exactly
/// as a single-process run does, which is what keeps flushed shard
/// bytes identical across worker counts.
pub fn run_leader<T>(
    enablement: Enablement,
    seed: u64,
    opts: &LeaderOptions,
    experiment: impl FnOnce(Arc<FleetQueue>) -> Result<T>,
) -> Result<T> {
    drain::reset();
    drain::install_signal_handlers();
    let listener = TcpListener::bind(opts.listen.as_str())
        .with_context(|| format!("binding fleet leader on {}", opts.listen))?;
    let local = listener.local_addr()?;
    println!("listening on {local}");
    std::io::stdout().flush().ok();
    listener.set_nonblocking(true)?;

    let queue = Arc::new(FleetQueue::new(opts.lease_ms));
    let service = Arc::new(EvalService::new(enablement, seed));
    let state = Arc::new(ServerState {
        service: Arc::clone(&service),
        router: Arc::new(EvalRouter::start(Arc::clone(&service))),
        stats: Arc::new(ServeStats::default()),
        feat_dim: 0,
        test_hooks: false,
        fleet: Some(Arc::clone(&queue)),
    });
    eprintln!(
        "[fleet] leader up addr={local} seed={seed} enablement={} lease_ms={}",
        enablement.name(),
        queue.lease_ms(),
    );
    let serve = {
        let state = Arc::clone(&state);
        std::thread::spawn(move || serve_loop(listener, state, None, 0.0))
    };

    let result = experiment(Arc::clone(&queue));

    // drain in both orders of visibility: claims answered before the
    // accept loop stops get `drain: true`; everything else sees the
    // socket close when the connection threads are joined
    queue.drain();
    drain::request();
    match serve.join() {
        Ok(r) => r.context("fleet leader serve loop")?,
        Err(_) => bail!("fleet leader serve loop panicked"),
    }
    drop(state);
    let c = queue.counters();
    eprintln!(
        "[fleet] leader down tasks={} claims={} completions={} requeues={} duplicate_results={}",
        c.tasks_enqueued, c.claims, c.completions, c.requeues, c.duplicate_results,
    );
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::flow::FlowResult;
    use crate::backend::pnr::{BackendResult, PowerBreakdown};
    use crate::backend::synthesis::SynthResult;
    use crate::simulators::SystemMetrics;

    fn sample_eval(tag: f64) -> Evaluation {
        Evaluation {
            flow: FlowResult {
                synth: SynthResult {
                    cell_area_um2: 100.0 + tag,
                    macro_area_um2: 50.0,
                    upsize: 1.25,
                    syn_power_w: 0.5,
                    syn_fmax_ghz: 1.5,
                    logic_delay_ps: 333.0 + tag / 7.0,
                },
                backend: BackendResult {
                    f_effective_ghz: 0.9,
                    f_max_ghz: 1.1,
                    power: PowerBreakdown {
                        internal_w: 0.1,
                        switching_w: 0.2 + tag / 13.0,
                        leakage_w: 0.05,
                    },
                    chip_area_mm2: 2.5,
                    cell_area_um2: 120.0,
                    macro_area_um2: 50.0,
                },
            },
            system: SystemMetrics {
                runtime_s: 1e-3 + tag / 1e6,
                energy_j: 2e-3,
                cycles: 1e6,
                busy_frac: 0.75,
                dram_bytes: 1e7,
            },
        }
    }

    fn sample_spec(key: u64) -> TaskSpec {
        let space = Platform::Axiline.param_space();
        let values: Vec<f64> = space.iter().map(|p| p.kind.from_unit(0.4)).collect();
        TaskSpec {
            key,
            flow_key: key ^ 0xabcd,
            arch: ArchConfig::new(Platform::Axiline, values),
            f_target_ghz: 0.8,
            util: 0.55,
            workload: Some(WorkloadSpec::NonDnn(NonDnnWorkload {
                algo: NonDnnAlgo::Svm,
                features: 55,
                samples: 512,
                epochs: 3,
            })),
            trial: 2,
            enablement: Enablement::Gf12,
            seed: 0xdead_beef_cafe_f00d,
        }
    }

    #[test]
    fn task_spec_round_trips_through_the_wire_including_big_keys() {
        // keys above 2^53 are exactly where a JSON-number encoding
        // would silently corrupt: pin the hex-string path
        let spec = sample_spec(0xffff_ffff_ffff_fff7);
        let j = spec.to_json();
        let line = j.to_string();
        let back = TaskSpec::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back.key, spec.key);
        assert_eq!(back.flow_key, spec.flow_key);
        assert_eq!(back.seed, spec.seed);
        assert_eq!(back.trial, spec.trial);
        assert_eq!(back.arch.platform, spec.arch.platform);
        assert_eq!(back.arch.values, spec.arch.values);
        assert_eq!(back.f_target_ghz.to_bits(), spec.f_target_ghz.to_bits());
        assert_eq!(back.util.to_bits(), spec.util.to_bits());
        assert_eq!(back.enablement, spec.enablement);
        match (&back.workload, &spec.workload) {
            (Some(WorkloadSpec::NonDnn(a)), Some(WorkloadSpec::NonDnn(b))) => {
                assert_eq!(a.algo, b.algo);
                assert_eq!(a.features, b.features);
                assert_eq!(a.samples, b.samples);
                assert_eq!(a.epochs, b.epochs);
            }
            other => panic!("workload did not round-trip: {other:?}"),
        }

        // DNN and no-workload variants round-trip through their tags
        let mut dnn = sample_spec(7);
        dnn.workload = Some(workloads::lookup("mobilenet").unwrap());
        let back = TaskSpec::from_json(&dnn.to_json()).unwrap();
        assert!(matches!(back.workload, Some(WorkloadSpec::Dnn(ref net)) if net.name == "mobilenet_v1"));
        let mut none = sample_spec(8);
        none.workload = None;
        let back = TaskSpec::from_json(&none.to_json()).unwrap();
        assert!(back.workload.is_none());
    }

    #[test]
    fn evaluation_wire_codec_is_bit_exact() {
        let ev = sample_eval(3.0);
        let line = eval_to_json(&ev).to_string();
        let back = eval_from_wire(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, ev);
        let rendered_again = eval_to_json(&back).to_string();
        assert_eq!(rendered_again, line, "decode→re-encode is byte-stable");
        assert!(eval_from_wire(&Json::Null).is_err(), "junk payload is an error, not a panic");
    }

    #[test]
    fn queue_claims_in_fifo_order_and_first_result_wins() {
        let q = FleetQueue::new(60_000);
        assert!(q.enqueue(sample_spec(1)));
        assert!(q.enqueue(sample_spec(2)));
        assert!(!q.enqueue(sample_spec(1)), "double-enqueue of a live key is refused");

        assert_eq!(q.claim(10).map(|t| t.key), Some(1));
        assert_eq!(q.claim(11).map(|t| t.key), Some(2));
        assert_eq!(q.claim(12).map(|t| t.key), None, "dry queue claims nothing");

        assert!(q.complete(1, Ok(sample_eval(1.0))));
        assert!(!q.complete(1, Ok(sample_eval(9.0))), "late duplicate is dropped");
        assert_eq!(q.await_result(1).unwrap(), sample_eval(1.0), "first result won");

        assert!(q.complete(2, Err("flow exploded".to_string())));
        let e = q.await_result(2).unwrap_err();
        assert_eq!(format!("{e:#}"), "fleet worker evaluation failed: flow exploded");

        let c = q.counters();
        assert_eq!(
            (c.tasks_enqueued, c.claims, c.completions, c.requeues, c.duplicate_results),
            (2, 2, 2, 0, 1)
        );
    }

    #[test]
    fn expired_lease_requeues_and_heartbeat_prevents_it() {
        let q = FleetQueue::new(40);
        q.enqueue(sample_spec(1));
        q.enqueue(sample_spec(2));
        let a = q.claim(10).unwrap();
        let b = q.claim(11).unwrap();
        assert_eq!((a.key, b.key), (1, 2));

        // worker 11 heartbeats through the lease window; worker 10 is
        // silent, so only key 1 comes back up for grabs
        for _ in 0..4 {
            std::thread::sleep(Duration::from_millis(15));
            assert_eq!(q.heartbeat(11), 1);
        }
        let re = q.claim(12).expect("expired lease must requeue");
        assert_eq!(re.key, 1);
        assert_eq!(q.claim(12).map(|t| t.key), None, "heartbeated task stays claimed");
        assert!(q.counters().requeues >= 1);

        // the dead worker's result arriving *after* the requeue is the
        // duplicate-hazard moment: first result (from anyone) wins
        assert!(q.complete(1, Ok(sample_eval(1.0))));
        assert!(!q.complete(1, Ok(sample_eval(2.0))));
        assert_eq!(q.await_result(1).unwrap(), sample_eval(1.0));
    }

    #[test]
    fn await_result_unblocks_across_threads() {
        let q = Arc::new(FleetQueue::new(60_000));
        q.enqueue(sample_spec(42));
        let qc = Arc::clone(&q);
        let waiter = std::thread::spawn(move || qc.await_result(42).unwrap());
        let t = q.claim(7).unwrap();
        assert_eq!(t.key, 42);
        q.complete(42, Ok(sample_eval(5.0)));
        assert_eq!(waiter.join().unwrap(), sample_eval(5.0));
        assert!(q.enqueue(sample_spec(42)), "consumed key can be enqueued again");
    }
}
