//! Per-shard index sidecars (ISSUE 7 tentpole, layer 3): a bloom
//! filter + key→byte-offset table written next to each shard file as
//! `<shard>.idx`, rebuilt atomically at flush/compact.
//!
//! A sidecar is a **disposable cache**, never a source of truth:
//!
//! - a *missing* sidecar (a PR 6 dir, or a crash between the shard
//!   rename and the idx rename) falls back to the streaming scan and is
//!   rebuilt best-effort;
//! - a *torn or stale* sidecar is detected — file-length check at
//!   probe, per-frame key/schema re-validation at fetch — and discarded
//!   the same way;
//! - deleting every `.idx` in a store dir is always safe.
//!
//! The index is a pure function of the shard body, so sidecar files are
//! as deterministic as the shards themselves (fixed seed ⇒ identical
//! dir listings). Tombstoned keys are *not* indexed: a bloom/table miss
//! and a tombstone read both answer "miss", so point lookups skip the
//! lazy scan entirely — the sidecar's whole purpose.

use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::util::rng::hash_bytes;

use super::codec::{hex_key, parse_hex_key, Codec};

pub const SIDECAR_VERSION: u64 = 1;
/// ~10 bits/key with 4 probes: ~1% false-positive rate, and a false
/// positive only costs one wasted frame fetch.
const BLOOM_BITS_PER_KEY: usize = 10;
const BLOOM_PROBES: u8 = 4;

/// Sidecar path for a shard file: `t-002.fsb` -> `t-002.fsb.idx`.
pub fn idx_path(shard_path: &Path) -> PathBuf {
    let mut name = shard_path.file_name().unwrap_or_default().to_os_string();
    name.push(".idx");
    shard_path.with_file_name(name)
}

#[derive(Debug, Clone, PartialEq)]
pub struct SidecarIndex {
    /// Codec of the shard file this index describes.
    pub codec: Codec,
    /// Shard-file byte length at build time (the cheap staleness probe).
    pub len: u64,
    /// `hash_bytes` of the shard body (compact uses it to decide
    /// whether an on-disk sidecar is already fresh).
    pub hash: u64,
    /// Power-of-two word count; bit count is `words * 64`.
    bloom: Vec<u64>,
    /// `(key, offset, frame_len)` sorted by key; tombstones excluded.
    keys: Vec<(u64, u64, u64)>,
}

fn bloom_slots(key: u64, words: usize) -> impl Iterator<Item = (usize, u64)> {
    let bits = (words as u64) * 64;
    (0..BLOOM_PROBES).map(move |i| {
        let mut probe = [0u8; 9];
        probe[..8].copy_from_slice(&key.to_le_bytes());
        probe[8] = i;
        let bit = hash_bytes(&probe) & (bits - 1);
        ((bit >> 6) as usize, 1u64 << (bit & 63))
    })
}

impl SidecarIndex {
    /// Build from a rendered shard body and its live-frame table.
    /// `entries` may arrive in any order and with duplicate keys (first
    /// wins, matching the scan merge rule).
    pub fn build(codec: Codec, body: &[u8], entries: &[(u64, u64, u64)]) -> SidecarIndex {
        let mut keys: Vec<(u64, u64, u64)> = Vec::with_capacity(entries.len());
        let mut seen = std::collections::HashSet::new();
        for &e in entries {
            if seen.insert(e.0) {
                keys.push(e);
            }
        }
        keys.sort_unstable();
        let words = ((keys.len() * BLOOM_BITS_PER_KEY + 63) / 64).next_power_of_two().max(1);
        let mut bloom = vec![0u64; words];
        for &(key, _, _) in &keys {
            for (w, mask) in bloom_slots(key, words) {
                bloom[w] |= mask;
            }
        }
        SidecarIndex { codec, len: body.len() as u64, hash: hash_bytes(body), bloom, keys }
    }

    /// Definitely-absent filter; false positives cost one frame fetch.
    pub fn may_contain(&self, key: u64) -> bool {
        bloom_slots(key, self.bloom.len()).all(|(w, mask)| self.bloom[w] & mask != 0)
    }

    /// Exact `(offset, frame_len)` for a live key.
    pub fn lookup(&self, key: u64) -> Option<(u64, u64)> {
        let i = self.keys.binary_search_by_key(&key, |e| e.0).ok()?;
        let (_, off, len) = self.keys[i];
        Some((off, len))
    }

    pub fn n_keys(&self) -> usize {
        self.keys.len()
    }

    /// One-line JSON, alphabetical keys — deterministic for its inputs.
    pub fn render(&self) -> String {
        let bloom: Vec<String> = self.bloom.iter().map(|w| format!("{w:016x}")).collect();
        let keys: Vec<Json> = self
            .keys
            .iter()
            .map(|&(k, off, len)| {
                Json::Arr(vec![
                    Json::from(hex_key(k).as_str()),
                    Json::from(off as usize),
                    Json::from(len as usize),
                ])
            })
            .collect();
        let mut line = Json::obj(vec![
            ("bloom", Json::arr_str(&bloom)),
            ("codec", Json::from(self.codec.name())),
            ("hash", Json::from(hex_key(self.hash).as_str())),
            ("keys", Json::Arr(keys)),
            ("len", Json::from(self.len as usize)),
            ("v", Json::from(SIDECAR_VERSION as usize)),
        ])
        .to_string();
        line.push('\n');
        line
    }

    /// Strict parse: any defect (version drift, torn write, bad field,
    /// unsorted table) returns `None` and the caller treats the sidecar
    /// as missing.
    pub fn parse(text: &str) -> Option<SidecarIndex> {
        let j = Json::parse(text.trim()).ok()?;
        if j.get("v").as_usize()? as u64 != SIDECAR_VERSION {
            return None;
        }
        let codec = Codec::from_name(j.get("codec").as_str()?)?;
        let len = j.get("len").as_usize()? as u64;
        let hash = parse_hex_key(j.get("hash").as_str()?)?;
        let bloom: Vec<u64> = j
            .get("bloom")
            .as_arr()?
            .iter()
            .map(|w| w.as_str().and_then(parse_hex_key))
            .collect::<Option<_>>()?;
        if !bloom.len().is_power_of_two() {
            return None;
        }
        let keys: Vec<(u64, u64, u64)> = j
            .get("keys")
            .as_arr()?
            .iter()
            .map(|e| {
                let k = e.idx(0).as_str().and_then(parse_hex_key)?;
                Some((k, e.idx(1).as_usize()? as u64, e.idx(2).as_usize()? as u64))
            })
            .collect::<Option<_>>()?;
        if !keys.windows(2).all(|w| w[0].0 < w[1].0) {
            return None;
        }
        Some(SidecarIndex { codec, len, hash, bloom, keys })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SidecarIndex {
        let body = b"frame-one\nframe-two\nframe-three\n";
        let entries = [(0x0a01u64, 0u64, 9u64), (0x0a02, 10, 9), (0x0a03, 20, 11)];
        SidecarIndex::build(Codec::V2Binary, body, &entries)
    }

    #[test]
    fn roundtrips_through_render_and_parse() {
        let idx = sample();
        let text = idx.render();
        assert!(text.ends_with('\n') && !text[..text.len() - 1].contains('\n'));
        let back = SidecarIndex::parse(&text).expect("rendered sidecar re-parses");
        assert_eq!(back, idx);
        assert_eq!(idx.render(), back.render(), "render is deterministic");
    }

    #[test]
    fn lookup_and_bloom_answer_membership() {
        let idx = sample();
        assert_eq!(idx.lookup(0x0a02), Some((10, 9)));
        assert_eq!(idx.lookup(0x0a04), None);
        assert_eq!(idx.n_keys(), 3);
        for k in [0x0a01u64, 0x0a02, 0x0a03] {
            assert!(idx.may_contain(k), "present key {k:#x} must pass the bloom");
        }
        // bloom false positives are allowed but must be rare
        let fp = (0..10_000u64).filter(|&i| idx.may_contain(0xdead_0000 + i)).count();
        assert!(fp < 500, "false-positive rate too high: {fp}/10000");
    }

    #[test]
    fn duplicate_entries_first_wins_and_empty_index_misses_everything() {
        let idx = SidecarIndex::build(
            Codec::V1Jsonl,
            b"xy",
            &[(5, 0, 4), (5, 9, 9), (1, 4, 2)],
        );
        assert_eq!(idx.lookup(5), Some((0, 4)), "first entry for a key wins");
        assert_eq!(idx.n_keys(), 2);
        let empty = SidecarIndex::build(Codec::V1Jsonl, b"", &[]);
        assert!(!empty.may_contain(7));
        assert_eq!(empty.lookup(7), None);
        assert!(SidecarIndex::parse(&empty.render()).is_some());
    }

    #[test]
    fn torn_or_tampered_sidecars_parse_as_none() {
        let text = sample().render();
        for cut in 1..text.len().saturating_sub(1) {
            assert!(SidecarIndex::parse(&text[..cut]).is_none(), "torn at {cut}");
        }
        assert!(SidecarIndex::parse("").is_none());
        assert!(SidecarIndex::parse("{}").is_none());
        let wrong_v = text.replace("\"v\":1", "\"v\":99");
        assert!(SidecarIndex::parse(&wrong_v).is_none());
        // unsorted key table would break binary search: rejected
        let idx = sample();
        let mut j = idx.render();
        j = j.replace("\"0000000000000a01\"", "\"0000000000000a09\"");
        assert!(SidecarIndex::parse(&j).is_none());
    }

    #[test]
    fn idx_path_appends_to_the_shard_file_name() {
        let p = idx_path(Path::new("/tmp/store/t-002.fsb"));
        assert_eq!(p, Path::new("/tmp/store/t-002.fsb.idx"));
        let p = idx_path(Path::new("rel/shard-015.jsonl"));
        assert_eq!(p, Path::new("rel/shard-015.jsonl.idx"));
    }
}
