//! Record codecs — the on-disk frame format seam under `ShardedStore`
//! (ISSUE 7 tentpole, layer 2). The store core owns slots, locking,
//! merge, and policy; a [`RecordCodec`] owns only how an envelope
//! `{v, kind, key, used, payload...}` becomes bytes:
//!
//! - **v1** ([`V1Jsonl`]): schema-tagged JSONL, one envelope object per
//!   line — bit-identical to the PR 6 writer, so existing dirs keep
//!   reading (and, when selected, writing) byte-for-byte.
//! - **v2** ([`V2Binary`]): length-prefixed binary frames. Large forest
//!   model artifacts are the motivating payload: numbers are 8 raw
//!   bytes instead of shortest-decimal text, so those payloads shrink
//!   roughly 2x and re-load without float re-parsing.
//!
//! Scans are *streaming*: they surface the envelope fields plus the raw
//! frame span and never tree-parse the body — `decode_payload` runs
//! only when a record is actually materialized. Each shard file carries
//! its codec in its extension (`.jsonl` / `.fsb`), which is how mixed
//! dirs auto-detect on read.
//!
//! Determinism contract: both codecs render a given (schema, key, used,
//! kind, payload) to identical bytes on every run, and non-finite
//! floats canonicalize the same way (v1 writes the `null` sentinel, v2
//! writes the Null tag), so the two codecs decode to *equal* records
//! and transcoding either direction is lossless.

use std::borrow::Cow;

use crate::util::json::{Json, JsonToken, JsonTokenizer};

/// Magic byte opening every v2 binary frame (never valid leading JSON).
pub const V2_MAGIC: u8 = 0xF5;

const TAG_NULL: u8 = 0x00;
const TAG_FALSE: u8 = 0x01;
const TAG_TRUE: u8 = 0x02;
const TAG_NUM: u8 = 0x03;
const TAG_STR: u8 = 0x04;
const TAG_ARR: u8 = 0x05;
const TAG_OBJ: u8 = 0x06;

/// A frame field too large for its fixed-width length prefix. Raised
/// instead of silently truncating the prefix (a bare `as u32`/`as u8`
/// cast would corrupt the shard: the written length would wrap and the
/// decoder would mis-frame everything after it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EncodeError {
    /// Which field overflowed its prefix (`"kind"`, `"payload"`,
    /// `"str"`, `"arr"`, `"obj"`, `"obj key"`).
    pub what: &'static str,
    /// The offending length.
    pub len: usize,
    /// The prefix's maximum representable length.
    pub max: usize,
}

impl std::fmt::Display for EncodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "record {} length {} exceeds frame prefix limit {}",
            self.what, self.len, self.max
        )
    }
}

impl std::error::Error for EncodeError {}

/// Checked u32 length prefix: the only path from a `usize` length to
/// frame bytes. Errors instead of wrapping.
fn len_u32(len: usize, what: &'static str) -> Result<u32, EncodeError> {
    u32::try_from(len).map_err(|_| EncodeError { what, len, max: u32::MAX as usize })
}

/// Checked u8 length prefix (the v2 kind byte).
fn len_u8(len: usize, what: &'static str) -> Result<u8, EncodeError> {
    u8::try_from(len).map_err(|_| EncodeError { what, len, max: u8::MAX as usize })
}

/// Which frame format a store writes (reads auto-detect both).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Codec {
    /// Schema-tagged JSONL (the PR 6 format).
    V1Jsonl,
    /// Compact length-prefixed binary frames.
    V2Binary,
}

impl Codec {
    pub const ALL: [Codec; 2] = [Codec::V1Jsonl, Codec::V2Binary];

    pub fn name(self) -> &'static str {
        match self {
            Codec::V1Jsonl => "v1",
            Codec::V2Binary => "v2",
        }
    }

    pub fn from_name(s: &str) -> Option<Codec> {
        match s {
            "v1" | "jsonl" => Some(Codec::V1Jsonl),
            "v2" | "binary" => Some(Codec::V2Binary),
            _ => None,
        }
    }

    /// Shard-file extension — the auto-detect key on read.
    pub fn file_ext(self) -> &'static str {
        match self {
            Codec::V1Jsonl => "jsonl",
            Codec::V2Binary => "fsb",
        }
    }

    pub fn other(self) -> Codec {
        match self {
            Codec::V1Jsonl => Codec::V2Binary,
            Codec::V2Binary => Codec::V1Jsonl,
        }
    }

    /// Per-frame bytes outside the frame span (the v1 newline) — keeps
    /// the byte-budget accounting consistent across codecs.
    pub fn frame_overhead(self) -> usize {
        match self {
            Codec::V1Jsonl => 1,
            Codec::V2Binary => 0,
        }
    }

    pub fn imp(self) -> &'static dyn RecordCodec {
        match self {
            Codec::V1Jsonl => &V1Jsonl,
            Codec::V2Binary => &V2Binary,
        }
    }
}

/// One envelope frame surfaced by a codec scan. `bytes` spans the whole
/// frame with the body still encoded (decode is deferred), `offset` is
/// its position in the scanned buffer (what sidecars index).
pub struct Frame<'a> {
    pub key: u64,
    pub used: u64,
    pub kind: Cow<'a, str>,
    pub bytes: &'a [u8],
    pub offset: usize,
}

#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ScanStats {
    /// Frames encountered, including dead ones.
    pub frames: usize,
    /// Frames that can never serve a read: torn, foreign schema,
    /// garbage. (Tombstones and shadowed duplicates are accounted by
    /// the store, which owns that context.)
    pub dead: usize,
}

/// The codec seam at the `Record` boundary: envelope framing + payload
/// encoding. Implementations must render deterministically — fixed
/// inputs produce identical bytes on every run and machine.
pub trait RecordCodec: Sync {
    /// Append one frame (terminator included for line-oriented codecs)
    /// and return the frame-span length (terminator excluded). Errors
    /// (leaving `out` possibly extended with a partial frame the caller
    /// must discard) when a field overflows its length prefix.
    fn append_frame(
        &self,
        out: &mut Vec<u8>,
        schema: u64,
        key: u64,
        used: u64,
        kind: &str,
        payload: Vec<(&'static str, Json)>,
    ) -> Result<usize, EncodeError>;

    /// Stream every frame in `bytes`, emitting the envelope + raw span
    /// per readable frame. Bodies are never tree-parsed here.
    fn scan(&self, bytes: &[u8], schema: u64, emit: &mut dyn FnMut(Frame<'_>)) -> ScanStats;

    /// Decode one frame's payload into the record object that
    /// `Record::decode` reads. `None` = corrupt (never served).
    fn decode_payload(&self, frame: &[u8], schema: u64) -> Option<Json>;
}

pub fn parse_hex_key(s: &str) -> Option<u64> {
    u64::from_str_radix(s, 16).ok()
}

pub fn hex_key(key: u64) -> String {
    format!("{key:016x}")
}

// ---- v1: schema-tagged JSONL ---------------------------------------

pub struct V1Jsonl;

impl RecordCodec for V1Jsonl {
    fn append_frame(
        &self,
        out: &mut Vec<u8>,
        schema: u64,
        key: u64,
        used: u64,
        kind: &str,
        payload: Vec<(&'static str, Json)>,
    ) -> Result<usize, EncodeError> {
        // identical field set + `Json::obj` key sort as the PR 6
        // writer: v1 output stays byte-compatible with existing dirs
        let mut fields: Vec<(&str, Json)> = vec![
            ("v", Json::from(schema as usize)),
            ("kind", Json::from(kind)),
            ("key", Json::from(hex_key(key).as_str())),
            ("used", Json::from(used as usize)),
        ];
        for (k, v) in payload {
            fields.push((k, v));
        }
        let line = Json::obj(fields).to_string();
        out.extend_from_slice(line.as_bytes());
        out.push(b'\n');
        Ok(line.len())
    }

    fn scan(&self, bytes: &[u8], schema: u64, emit: &mut dyn FnMut(Frame<'_>)) -> ScanStats {
        let mut st = ScanStats::default();
        let mut pos = 0usize;
        while pos < bytes.len() {
            let end = bytes[pos..]
                .iter()
                .position(|&b| b == b'\n')
                .map(|i| pos + i)
                .unwrap_or(bytes.len());
            let (mut s, mut e) = (pos, end);
            pos = end + 1;
            while s < e && bytes[s].is_ascii_whitespace() {
                s += 1;
            }
            while e > s && bytes[e - 1].is_ascii_whitespace() {
                e -= 1;
            }
            if s == e {
                continue;
            }
            st.frames += 1;
            match scan_envelope(&bytes[s..e], schema) {
                Some((key, used, kind)) => {
                    emit(Frame { key, used, kind, bytes: &bytes[s..e], offset: s })
                }
                None => st.dead += 1,
            }
        }
        st
    }

    fn decode_payload(&self, frame: &[u8], _schema: u64) -> Option<Json> {
        // the full envelope object; `Record::decode` reads only its
        // payload fields, exactly as the eager loader passed it
        Json::parse(std::str::from_utf8(frame).ok()?).ok()
    }
}

/// Streaming envelope extraction for one JSONL frame: tokenize the
/// top-level object, pull `v`/`key`/`used`/`kind`, and *span-skip*
/// every other value (this is where body tree-parses are saved).
/// Acceptance matches the eager loader: bad `v`/`key`/`kind` types or
/// values are dead; a non-numeric `used` defaults to 0 (pre-core
/// records); structural damage anywhere is dead.
fn scan_envelope<'a>(line: &'a [u8], schema: u64) -> Option<(u64, u64, Cow<'a, str>)> {
    let mut t = JsonTokenizer::new(line);
    match t.next().ok()?? {
        JsonToken::ObjBegin => {}
        _ => return None,
    }
    let mut v: Option<u64> = None;
    let mut key: Option<u64> = None;
    let mut used: u64 = 0;
    let mut kind: Option<Cow<'a, str>> = None;
    loop {
        match t.next().ok()?? {
            JsonToken::Key(k) => match k.as_ref() {
                "v" => match t.next().ok()?? {
                    // f64-as-usize truncation matches the tree loader
                    JsonToken::Num(n) => v = Some(n as usize as u64),
                    _ => return None,
                },
                "key" => match t.next().ok()?? {
                    JsonToken::Str(s) => key = Some(parse_hex_key(s.as_ref())?),
                    _ => return None,
                },
                "used" => match t.next().ok()?? {
                    JsonToken::Num(n) => used = n as usize as u64,
                    JsonToken::Str(_) | JsonToken::Bool(_) | JsonToken::Null => used = 0,
                    JsonToken::ArrBegin | JsonToken::ObjBegin => {
                        drain_container(&mut t)?;
                        used = 0;
                    }
                    _ => return None,
                },
                "kind" => match t.next().ok()?? {
                    JsonToken::Str(s) => kind = Some(s),
                    _ => return None,
                },
                _ => {
                    // body field: validate + skip without building a tree
                    t.value_span().ok()?;
                }
            },
            JsonToken::ObjEnd => break,
            _ => return None,
        }
    }
    // trailing-garbage / torn-tail check, same as the tree parser
    if t.next().ok()?.is_some() {
        return None;
    }
    if v != Some(schema) {
        return None;
    }
    Some((key?, used, kind?))
}

/// Drain a just-opened container to its matching close.
fn drain_container(t: &mut JsonTokenizer<'_>) -> Option<()> {
    let mut depth = 1usize;
    while depth > 0 {
        match t.next().ok()?? {
            JsonToken::ObjBegin | JsonToken::ArrBegin => depth += 1,
            JsonToken::ObjEnd | JsonToken::ArrEnd => depth -= 1,
            _ => {}
        }
    }
    Some(())
}

// ---- v2: length-prefixed binary frames -----------------------------
//
// [0xF5][schema u64 LE][key u64 LE][used u64 LE]
// [kind_len u8][kind bytes][payload_len u32 LE][payload]
//
// The payload is a tagged binary encoding of the record object with
// keys in sorted order (same order `Json::obj` gives v1), values as:
// Null 0x00 | false 0x01 | true 0x02 | Num 0x03 + f64 bits LE |
// Str 0x04 + u32 len + bytes | Arr 0x05 + u32 count + values |
// Obj 0x06 + u32 count + (u32 key len + key + value)*.

pub struct V2Binary;

/// Fixed header bytes before the kind: magic + schema + key + used +
/// kind length.
const V2_HEAD: usize = 1 + 8 + 8 + 8 + 1;

impl RecordCodec for V2Binary {
    fn append_frame(
        &self,
        out: &mut Vec<u8>,
        schema: u64,
        key: u64,
        used: u64,
        kind: &str,
        payload: Vec<(&'static str, Json)>,
    ) -> Result<usize, EncodeError> {
        let start = out.len();
        out.push(V2_MAGIC);
        out.extend_from_slice(&schema.to_le_bytes());
        out.extend_from_slice(&key.to_le_bytes());
        out.extend_from_slice(&used.to_le_bytes());
        out.push(len_u8(kind.len(), "kind")?);
        out.extend_from_slice(kind.as_bytes());
        let len_at = out.len();
        out.extend_from_slice(&0u32.to_le_bytes());
        // Json::obj sorts the fields (BTreeMap) — identical logical
        // record to the v1 rendering of the same payload
        encode_value(out, &Json::obj(payload))?;
        let plen = len_u32(out.len() - len_at - 4, "payload")?;
        out[len_at..len_at + 4].copy_from_slice(&plen.to_le_bytes());
        Ok(out.len() - start)
    }

    fn scan(&self, bytes: &[u8], schema: u64, emit: &mut dyn FnMut(Frame<'_>)) -> ScanStats {
        let mut st = ScanStats::default();
        let mut pos = 0usize;
        while pos < bytes.len() {
            let Some((total, fschema, key, used, krange)) = v2_header(&bytes[pos..]) else {
                // bad magic or torn tail: nothing past this point has a
                // trustworthy frame boundary (no resync)
                st.frames += 1;
                st.dead += 1;
                break;
            };
            st.frames += 1;
            let span = &bytes[pos..pos + total];
            if fschema == schema {
                match std::str::from_utf8(&span[krange]) {
                    Ok(kind) => emit(Frame {
                        key,
                        used,
                        kind: Cow::Borrowed(kind),
                        bytes: span,
                        offset: pos,
                    }),
                    Err(_) => st.dead += 1,
                }
            } else {
                // foreign schema but intact framing: skip past it
                st.dead += 1;
            }
            pos += total;
        }
        st
    }

    fn decode_payload(&self, frame: &[u8], schema: u64) -> Option<Json> {
        let (total, fschema, _, _, krange) = v2_header(frame)?;
        if total != frame.len() || fschema != schema {
            return None;
        }
        let payload = &frame[krange.end + 4..total];
        let mut pos = 0usize;
        let v = decode_value(payload, &mut pos)?;
        if pos != payload.len() {
            return None;
        }
        match v {
            Json::Obj(_) => Some(v),
            _ => None,
        }
    }
}

/// Parse one v2 frame header at the start of `b`: `(frame_len, schema,
/// key, used, kind byte range)`. `None` when the magic is wrong or any
/// length runs past the buffer (a torn tail).
fn v2_header(b: &[u8]) -> Option<(usize, u64, u64, u64, std::ops::Range<usize>)> {
    if b.first() != Some(&V2_MAGIC) || b.len() < V2_HEAD {
        return None;
    }
    let schema = u64::from_le_bytes(b[1..9].try_into().unwrap());
    let key = u64::from_le_bytes(b[9..17].try_into().unwrap());
    let used = u64::from_le_bytes(b[17..25].try_into().unwrap());
    let klen = b[25] as usize;
    let plen_at = V2_HEAD + klen;
    if b.len() < plen_at + 4 {
        return None;
    }
    let plen = u32::from_le_bytes(b[plen_at..plen_at + 4].try_into().unwrap()) as usize;
    let total = plen_at.checked_add(4)?.checked_add(plen)?;
    if b.len() < total {
        return None;
    }
    Some((total, schema, key, used, V2_HEAD..plen_at))
}

fn encode_value(out: &mut Vec<u8>, v: &Json) -> Result<(), EncodeError> {
    match v {
        Json::Null => out.push(TAG_NULL),
        Json::Bool(false) => out.push(TAG_FALSE),
        Json::Bool(true) => out.push(TAG_TRUE),
        Json::Num(n) => {
            if n.is_finite() {
                out.push(TAG_NUM);
                out.extend_from_slice(&n.to_bits().to_le_bytes());
            } else {
                // canonicalize NaN/±Inf exactly like the v1 `null`
                // sentinel, so both codecs decode to equal records
                // (readers recover NaN via `as_f64_or_nan`)
                out.push(TAG_NULL);
            }
        }
        Json::Str(s) => {
            out.push(TAG_STR);
            out.extend_from_slice(&len_u32(s.len(), "str")?.to_le_bytes());
            out.extend_from_slice(s.as_bytes());
        }
        Json::Arr(a) => {
            out.push(TAG_ARR);
            out.extend_from_slice(&len_u32(a.len(), "arr")?.to_le_bytes());
            for x in a {
                encode_value(out, x)?;
            }
        }
        Json::Obj(o) => {
            out.push(TAG_OBJ);
            out.extend_from_slice(&len_u32(o.len(), "obj")?.to_le_bytes());
            for (k, x) in o {
                out.extend_from_slice(&len_u32(k.len(), "obj key")?.to_le_bytes());
                out.extend_from_slice(k.as_bytes());
                encode_value(out, x)?;
            }
        }
    }
    Ok(())
}

fn take<'a>(b: &'a [u8], pos: &mut usize, n: usize) -> Option<&'a [u8]> {
    let s = b.get(*pos..pos.checked_add(n)?)?;
    *pos += n;
    Some(s)
}

fn decode_value(b: &[u8], pos: &mut usize) -> Option<Json> {
    let tag = *b.get(*pos)?;
    *pos += 1;
    match tag {
        TAG_NULL => Some(Json::Null),
        TAG_FALSE => Some(Json::Bool(false)),
        TAG_TRUE => Some(Json::Bool(true)),
        TAG_NUM => {
            let raw = take(b, pos, 8)?;
            Some(Json::Num(f64::from_bits(u64::from_le_bytes(raw.try_into().unwrap()))))
        }
        TAG_STR => {
            let n = u32::from_le_bytes(take(b, pos, 4)?.try_into().unwrap()) as usize;
            let s = std::str::from_utf8(take(b, pos, n)?).ok()?;
            Some(Json::Str(s.to_string()))
        }
        TAG_ARR => {
            let n = u32::from_le_bytes(take(b, pos, 4)?.try_into().unwrap()) as usize;
            let mut a = Vec::new();
            for _ in 0..n {
                a.push(decode_value(b, pos)?);
            }
            Some(Json::Arr(a))
        }
        TAG_OBJ => {
            let n = u32::from_le_bytes(take(b, pos, 4)?.try_into().unwrap()) as usize;
            let mut o = std::collections::BTreeMap::new();
            for _ in 0..n {
                let klen = u32::from_le_bytes(take(b, pos, 4)?.try_into().unwrap()) as usize;
                let k = std::str::from_utf8(take(b, pos, klen)?).ok()?.to_string();
                o.insert(k, decode_value(b, pos)?);
            }
            Some(Json::Obj(o))
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload(v: f64) -> Vec<(&'static str, Json)> {
        vec![
            ("val", Json::from(v)),
            ("tags", Json::arr_str(&["x".to_string(), "y".to_string()])),
            ("nested", Json::obj(vec![("deep", Json::arr_f64(&[v, -0.0, 2.5]))])),
        ]
    }

    fn collect(codec: Codec, bytes: &[u8], schema: u64) -> (Vec<(u64, u64, String)>, ScanStats) {
        let mut out = Vec::new();
        let st = codec.imp().scan(bytes, schema, &mut |f: Frame<'_>| {
            out.push((f.key, f.used, f.kind.to_string()));
        });
        (out, st)
    }

    #[test]
    fn both_codecs_roundtrip_equal_records() {
        for codec in Codec::ALL {
            let imp = codec.imp();
            let mut buf = Vec::new();
            let flen = imp.append_frame(&mut buf, 7, 0xabcd, 3, "eval", payload(0.1)).unwrap();
            assert_eq!(flen + codec.frame_overhead(), buf.len());
            let (frames, st) = collect(codec, &buf, 7);
            assert_eq!(st, ScanStats { frames: 1, dead: 0 });
            assert_eq!(frames, vec![(0xabcd, 3, "eval".to_string())]);
            let rec = imp.decode_payload(&buf[..flen], 7).expect("payload decodes");
            assert_eq!(rec.get("val").as_f64(), Some(0.1));
            assert_eq!(rec.get("nested").get("deep").idx(1).as_f64(), Some(-0.0));
            assert_eq!(rec.get("tags").idx(1).as_str(), Some("y"));
        }
    }

    #[test]
    fn v1_and_v2_decode_to_equal_payload_fields() {
        // incl. the non-finite canonicalization: v1 null sentinel and
        // v2 Null tag must decode to the same Json
        let p = || {
            vec![
                ("a", Json::Num(f64::NAN)),
                ("b", Json::Num(f64::INFINITY)),
                ("c", Json::Num(-0.0)),
                ("d", Json::arr_f64(&[1.0 / 3.0])),
            ]
        };
        let mut b1 = Vec::new();
        let l1 = V1Jsonl.append_frame(&mut b1, 7, 9, 1, "eval", p()).unwrap();
        let mut b2 = Vec::new();
        let l2 = V2Binary.append_frame(&mut b2, 7, 9, 1, "eval", p()).unwrap();
        let r1 = V1Jsonl.decode_payload(&b1[..l1], 7).unwrap();
        let r2 = V2Binary.decode_payload(&b2[..l2], 7).unwrap();
        for f in ["a", "b", "c", "d"] {
            assert_eq!(r1.get(f), r2.get(f), "field {f} differs across codecs");
        }
        assert!(r1.get("a").as_f64_or_nan().unwrap().is_nan());
        assert_eq!(r1.get("c").as_f64().unwrap().to_bits(), (-0.0f64).to_bits());
    }

    #[test]
    fn v2_frames_are_much_smaller_for_numeric_payloads() {
        let nums: Vec<f64> = (0..64).map(|i| 1.0 / (i as f64 + 3.0)).collect();
        let p = || vec![("w", Json::arr_f64(&nums))];
        let mut b1 = Vec::new();
        V1Jsonl.append_frame(&mut b1, 7, 1, 1, "m", p()).unwrap();
        let mut b2 = Vec::new();
        V2Binary.append_frame(&mut b2, 7, 1, 1, "m", p()).unwrap();
        assert!(
            b1.len() as f64 / b2.len() as f64 > 1.5,
            "v1 {} B vs v2 {} B",
            b1.len(),
            b2.len()
        );
    }

    #[test]
    fn torn_tails_are_dead_in_both_codecs() {
        for codec in Codec::ALL {
            let imp = codec.imp();
            let mut buf = Vec::new();
            imp.append_frame(&mut buf, 7, 1, 1, "a", payload(1.0)).unwrap();
            let keep = buf.len();
            imp.append_frame(&mut buf, 7, 2, 1, "a", payload(2.0)).unwrap();
            for cut in keep + 1..buf.len() {
                let (frames, st) = collect(codec, &buf[..cut], 7);
                assert_eq!(
                    frames.iter().map(|f| f.0).collect::<Vec<_>>(),
                    vec![1],
                    "{}: torn tail must serve only the intact frame (cut {cut})",
                    codec.name()
                );
                assert!(st.dead >= 1, "{}: torn frame must count dead", codec.name());
            }
        }
    }

    #[test]
    fn foreign_schema_and_garbage_are_dead_not_fatal() {
        for codec in Codec::ALL {
            let imp = codec.imp();
            let mut buf = Vec::new();
            imp.append_frame(&mut buf, 99, 5, 1, "a", payload(5.0)).unwrap(); // foreign schema
            imp.append_frame(&mut buf, 7, 6, 1, "a", payload(6.0)).unwrap();
            let (frames, st) = collect(codec, &buf, 7);
            // both codecs skip a foreign-schema frame (its framing is
            // intact) and keep reading the rest of the file
            assert_eq!(frames.iter().map(|f| f.0).collect::<Vec<_>>(), vec![6]);
            assert_eq!(st.frames, 2);
            assert_eq!(st.dead, 1);
        }
        // v1 garbage lines + blank lines skip exactly like the old loader
        let text = b"\n  \nthis is not json\n{\"v\":7,\"key\":\"zz\",\"kind\":\"a\",\"used\":1}\n";
        let (frames, st) = collect(Codec::V1Jsonl, text, 7);
        assert!(frames.is_empty());
        assert_eq!(st, ScanStats { frames: 2, dead: 2 });
    }

    #[test]
    fn v1_scan_agrees_with_tree_parse_on_envelopes() {
        let lines = [
            r#"{"b":0.5,"key":"00000000000000aa","kind":"eval","used":4,"v":7}"#,
            // body before the envelope fields, deep nesting to span-skip
            r#"{"aaa":{"x":[1,[2,{"y":"}]"}]]},"key":"00000000000000bb","kind":"flow","v":7}"#,
            // pre-core record: no used stamp -> 0
            r#"{"key":"00000000000000cc","kind":"eval","v":7}"#,
        ];
        let text = lines.join("\n");
        let (frames, st) = collect(Codec::V1Jsonl, text.as_bytes(), 7);
        assert_eq!(st, ScanStats { frames: 3, dead: 0 });
        assert_eq!(
            frames,
            vec![
                (0xaa, 4, "eval".to_string()),
                (0xbb, 0, "flow".to_string()),
                (0xcc, 0, "eval".to_string()),
            ]
        );
        // and the spans decode to the same object the tree parser sees
        let mut spans = Vec::new();
        V1Jsonl.scan(text.as_bytes(), 7, &mut |f: Frame<'_>| spans.push(f.bytes.to_vec()));
        for (span, line) in spans.iter().zip(lines) {
            assert_eq!(
                V1Jsonl.decode_payload(span, 7).unwrap(),
                Json::parse(line).unwrap()
            );
        }
    }

    #[test]
    fn scan_offsets_index_fetchable_frames() {
        for codec in Codec::ALL {
            let imp = codec.imp();
            let mut buf = Vec::new();
            for i in 0..5u64 {
                imp.append_frame(&mut buf, 7, i, i, "a", payload(i as f64)).unwrap();
            }
            let mut spans: Vec<(u64, usize, usize)> = Vec::new();
            imp.scan(&buf, 7, &mut |f: Frame<'_>| {
                spans.push((f.key, f.offset, f.bytes.len()))
            });
            assert_eq!(spans.len(), 5);
            for (key, off, len) in spans {
                // a sidecar fetch reads exactly [off, off+len): re-scan
                // of that slice must yield the one frame, alive
                let (frames, st) = collect(codec, &buf[off..off + len], 7);
                assert_eq!(st, ScanStats { frames: 1, dead: 0 });
                assert_eq!(frames[0].0, key);
                let rec = imp.decode_payload(&buf[off..off + len], 7).unwrap();
                assert_eq!(rec.get("val").as_f64(), Some(key as f64));
            }
        }
    }

    #[test]
    fn oversized_lengths_are_typed_encode_errors_not_truncation() {
        // the length-prefix guard itself, probed directly so the test
        // never allocates a >4 GiB payload
        assert_eq!(len_u32(u32::MAX as usize, "payload").unwrap(), u32::MAX);
        let e = len_u32(u32::MAX as usize + 1, "payload").unwrap_err();
        assert_eq!(e, EncodeError { what: "payload", len: u32::MAX as usize + 1, max: u32::MAX as usize });
        assert!(e.to_string().contains("payload"), "error names the field: {e}");

        // the kind byte is the reachable small-prefix case: >255 bytes
        // must error (the old cast wrote kind.len() % 256 and
        // mis-framed every later frame)
        let long_kind = "k".repeat(300);
        let mut out = Vec::new();
        let e = V2Binary
            .append_frame(&mut out, 7, 1, 1, &long_kind, Vec::new())
            .unwrap_err();
        assert_eq!(e.what, "kind");
        assert_eq!(e.len, 300);
        assert_eq!(e.max, u8::MAX as usize);
        // v1 has no kind prefix; the same record encodes fine there
        let mut b1 = Vec::new();
        V1Jsonl.append_frame(&mut b1, 7, 1, 1, &long_kind, Vec::new()).unwrap();
    }

    #[test]
    fn v2_decoder_rejects_overrunning_length_prefixes_without_panic() {
        let mut buf = Vec::new();
        let flen = V2Binary.append_frame(&mut buf, 7, 3, 1, "eval", payload(3.0)).unwrap();
        let klen = buf[25] as usize;
        let plen_at = V2_HEAD + klen;

        // corrupt the frame-level payload length to overrun the buffer:
        // the scan must mark a torn frame dead, decode must refuse, and
        // neither may panic or read out of bounds
        let mut torn = buf.clone();
        torn[plen_at..plen_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        let (frames, st) = collect(Codec::V2Binary, &torn, 7);
        assert!(frames.is_empty());
        assert_eq!(st, ScanStats { frames: 1, dead: 1 });
        assert_eq!(V2Binary.decode_payload(&torn[..flen], 7), None);

        // corrupt an *inner* value prefix: framing stays intact so the
        // scan still serves the envelope, but the deferred body decode
        // must return None. Payload `{"s":"hello"}` encodes as
        // TAG_OBJ + count u32 + keylen u32 + "s" + TAG_STR + len u32,
        // so the string length sits 11 bytes into the body.
        let mut b = Vec::new();
        let flen =
            V2Binary.append_frame(&mut b, 7, 4, 1, "eval", vec![("s", Json::from("hello"))]).unwrap();
        let body_at = V2_HEAD + 4 + 4; // kind "eval" + payload-len u32
        assert_eq!(b[body_at + 10], TAG_STR);
        b[body_at + 11..body_at + 15].copy_from_slice(&u32::MAX.to_le_bytes());
        let (frames, st) = collect(Codec::V2Binary, &b, 7);
        assert_eq!(frames.len(), 1);
        assert_eq!(st, ScanStats { frames: 1, dead: 0 });
        assert_eq!(V2Binary.decode_payload(&b[..flen], 7), None);
    }
}
