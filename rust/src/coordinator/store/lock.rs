//! Cross-process flush serialization and atomic file replacement for
//! store directories — the two disk primitives every `ShardedStore`
//! protocol step is built from (extracted from `cache_store.rs`, which
//! previously mirrored them into `model_store.rs` by hand).

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use anyhow::{Context, Result};

/// Cross-process flush serialization for a store directory: a
/// `.store.lock` file created with `create_new` (atomic on every
/// filesystem we care about) and removed on drop. A lock whose *file*
/// has not changed for the staleness window is presumed to belong to a
/// crashed process and is broken — flushes must never wedge a run
/// forever. Staleness is judged by the lock file's age, never by how
/// long this waiter has been waiting: a live holder mid-long-flush, or
/// a sequence of short-lived locks taken by other processes, must not
/// get stolen (stealing a live lock reintroduces the lost-update race
/// the lock exists to prevent). One lock per directory, so the oracle
/// and model stores (separate directories) never contend.
pub(crate) struct DirLock {
    path: PathBuf,
    /// Unique content written into the lock file; `drop` unlinks the
    /// file only while it still holds this token, so a holder whose
    /// lock was stolen never deletes the new holder's lock.
    token: String,
    /// The handle from `create_new`: `refresh` touches mtime through
    /// it, so a stalled holder whose lock was stolen (path renamed and
    /// recreated by the new holder) touches its own orphaned inode,
    /// never the new holder's file.
    file: fs::File,
}

impl DirLock {
    /// A lock file stamped in the *future* only reads as stale past
    /// this much skew. It is deliberately much larger than the normal
    /// staleness window: a live holder whose clock runs ahead by less
    /// than this ages out naturally (its mtime drifts into the past as
    /// real time passes), while an absurd future timestamp — which
    /// could otherwise never age out and would wedge every flusher
    /// forever — is eventually broken. NTP-grade skew is well under a
    /// second; ten minutes of skew between hosts cooperating on one
    /// cache dir is operational pathology, and progress wins at that
    /// point.
    const FUTURE_SKEW_STALE_MS: u128 = 600_000;
    const POLL_MS: u64 = 20;

    /// Staleness window in milliseconds. Default 30 s; the
    /// `FSO_STORE_LOCK_STALE_MS` environment variable overrides it
    /// (crash-recovery tests shrink it so a leaked lock is stolen in
    /// milliseconds instead of half a minute). Read once per process.
    fn stale_ms() -> u128 {
        static MS: OnceLock<u128> = OnceLock::new();
        *MS.get_or_init(|| {
            std::env::var("FSO_STORE_LOCK_STALE_MS")
                .ok()
                .and_then(|s| s.parse().ok())
                .unwrap_or(30_000)
        })
    }

    pub(crate) fn acquire(dir: &Path) -> Result<DirLock> {
        static NONCE: AtomicUsize = AtomicUsize::new(0);
        let path = dir.join(".store.lock");
        let token = format!(
            "{}-{}",
            std::process::id(),
            NONCE.fetch_add(1, Ordering::Relaxed)
        );
        loop {
            match fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    let _ = f.write_all(token.as_bytes());
                    let _ = f.sync_all();
                    return Ok(DirLock { path, token, file: f });
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let stale = match fs::metadata(&path).and_then(|m| m.modified()) {
                        Ok(mtime) => match mtime.elapsed() {
                            Ok(age) => age.as_millis() >= Self::stale_ms(),
                            // mtime ahead of our clock: see
                            // FUTURE_SKEW_STALE_MS for why this bound
                            // is far looser than the normal window
                            Err(skew) => {
                                skew.duration().as_millis() >= Self::FUTURE_SKEW_STALE_MS
                            }
                        },
                        // lock vanished between create_new and the stat
                        // (holder released): just retry create_new
                        Err(_) => false,
                    };
                    if stale {
                        // crashed holder (the file itself went stale,
                        // see `refresh`). Steal by *rename*, which is
                        // atomic: exactly one contender claims the
                        // stale file; the losers' renames fail and
                        // they re-poll — so a fresh lock created by
                        // the winner is never unlinked by a loser.
                        let stolen = dir.join(format!(".store.lock.stale-{token}"));
                        if fs::rename(&path, &stolen).is_ok() {
                            let _ = fs::remove_file(&stolen);
                        }
                        continue;
                    }
                    std::thread::sleep(std::time::Duration::from_millis(Self::POLL_MS));
                }
                Err(e) => {
                    return Err(e).with_context(|| format!("locking {}", path.display()))
                }
            }
        }
    }

    /// Keep the holder visibly live during a long multi-shard flush
    /// (staleness is judged by file mtime): touch mtime through the
    /// handle opened at acquire — never through the path, which may
    /// by now belong to a new holder after a staleness steal. Call
    /// between expensive write steps.
    pub(crate) fn refresh(&self) {
        let _ = self.file.set_modified(std::time::SystemTime::now());
    }
}

impl Drop for DirLock {
    fn drop(&mut self) {
        // unlink only while we still own the file: after a staleness
        // steal the path holds the new holder's token, and removing it
        // would admit a third concurrent writer
        if fs::read_to_string(&self.path).is_ok_and(|s| s == self.token) {
            let _ = fs::remove_file(&self.path);
        }
    }
}

/// The temp-file path `write_atomic` stages through for `path` (shared
/// with the crash-injection fault hook, which must leave behind exactly
/// the temp file a killed writer would). The suffix is unique per call
/// — pid *and* a process-wide nonce — because two threads of one
/// process may race unlocked writes to the same target (the meta.json
/// epoch bump at open), and a shared temp path would let one thread's
/// rename steal or lose the other's staged file.
pub(crate) fn tmp_path(path: &Path) -> PathBuf {
    static NONCE: AtomicUsize = AtomicUsize::new(0);
    let dir = path.parent().unwrap_or_else(|| Path::new("."));
    let base = path
        .file_name()
        .map(|b| b.to_string_lossy().into_owned())
        .unwrap_or_default();
    dir.join(format!(
        ".{base}.tmp-{}-{}",
        std::process::id(),
        NONCE.fetch_add(1, Ordering::Relaxed)
    ))
}

/// Write `bytes` to `path` atomically: temp file in the same directory
/// (same filesystem, so the rename is atomic), then rename over.
pub(crate) fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    anyhow::ensure!(
        path.parent().is_some() && path.file_name().is_some(),
        "store path {} has no parent directory / file name",
        path.display()
    );
    let tmp = tmp_path(path);
    {
        let mut f = fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes)
            .with_context(|| format!("writing {}", tmp.display()))?;
        f.sync_all().ok(); // durability best-effort; atomicity is the rename
    }
    fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} over {}", tmp.display(), path.display()))?;
    Ok(())
}
