//! Crash-injection fault points for the shared flush path (ISSUE 4
//! satellite): tests arm a one-shot fault and the next flush dies at
//! that protocol step, leaving the exact on-disk state a `kill -9`
//! would — a staged temp file without the rename, or renamed shards
//! with the directory lock still held. Recovery tests then reopen the
//! directory with a fresh store (the moral equivalent of a fresh
//! process) and assert that no acknowledged record is lost and no torn
//! JSONL is ever served.
//!
//! The hook is process-global and one-shot: `arm` schedules a single
//! fault, the first flush to reach that point consumes it, and
//! everything after runs normally. Tests that arm faults must
//! serialize themselves (the fault does not know which store will
//! flush next).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Where in the flush protocol the injected crash happens.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlushFault {
    /// After the shard body is staged to its temp file, before the
    /// rename: the previous shard contents must survive intact and the
    /// orphaned temp file must be ignored by every later reader.
    BeforeRename,
    /// After every dirty shard is renamed into place, before the
    /// directory lock is released: the data is durable but the lock is
    /// left behind; a later flusher must steal it once stale.
    BeforeLockRelease,
    /// After a shard file is renamed into place, with its `.idx`
    /// sidecar staged to a temp file but not renamed: the record data
    /// is durable, the sidecar is missing/stale, and readers must fall
    /// back to the streaming scan and silently rebuild it (ISSUE 7
    /// satellite).
    IdxBeforeRename,
}

// 0 = disarmed, 1 = BeforeRename, 2 = BeforeLockRelease, 3 = IdxBeforeRename
static ARMED: AtomicUsize = AtomicUsize::new(0);

fn code(fault: FlushFault) -> usize {
    match fault {
        FlushFault::BeforeRename => 1,
        FlushFault::BeforeLockRelease => 2,
        FlushFault::IdxBeforeRename => 3,
    }
}

/// Arm a one-shot crash at `fault`; the next flush that reaches the
/// point consumes it.
pub fn arm(fault: FlushFault) {
    ARMED.store(code(fault), Ordering::SeqCst);
}

/// Cancel a pending fault (test cleanup).
pub fn disarm() {
    ARMED.store(0, Ordering::SeqCst);
}

/// True exactly once after `arm(point)` — the flush path calls this at
/// each fault point and dies when it fires.
pub(crate) fn trip(point: FlushFault) -> bool {
    ARMED
        .compare_exchange(code(point), 0, Ordering::SeqCst, Ordering::SeqCst)
        .is_ok()
}
