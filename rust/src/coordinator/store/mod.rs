//! Shared persistent-store subsystem (ISSUE 4 tentpole, storage
//! engine v2 in ISSUE 7): the generic sharded store core both
//! `CacheStore` and `ModelStore` are built on, plus the disk
//! primitives (atomic replace, directory lock), the pluggable record
//! codecs ([`codec`]: `v1` JSONL / `v2` binary frames), the per-shard
//! index sidecars ([`sidecar`]), and the crash-injection fault hook
//! the test suite drives.
//!
//! See [`sharded`] for the full protocol and lifecycle-policy docs,
//! and the README "Store subsystem" / "Storage engine v2" sections for
//! the on-disk layout and CLI (`fso store compact` / `fso store
//! stats`).

pub mod codec;
pub mod fault;
pub(crate) mod lock;
pub mod sharded;
pub mod sidecar;

pub use codec::{Codec, EncodeError};
pub use sharded::{
    hex_key, parse_hex_key, CompactReport, Record, ShardedStore, StoreConfig, StorePolicy,
    StoreStats, TOMB_KIND,
};
