//! Shared persistent-store subsystem (ISSUE 4 tentpole): the generic
//! sharded JSONL store core both `CacheStore` and `ModelStore` are
//! built on, plus the disk primitives (atomic replace, directory lock)
//! and the crash-injection fault hook its test suite drives.
//!
//! See [`sharded`] for the full protocol and lifecycle-policy docs,
//! and the README "Store subsystem" section for the on-disk layout and
//! CLI (`fso store compact` / `fso store stats`).

pub mod fault;
pub(crate) mod lock;
pub mod sharded;

pub use sharded::{
    hex_key, parse_hex_key, CompactReport, Record, ShardedStore, StoreConfig, StorePolicy,
    StoreStats, TOMB_KIND,
};
