//! `ShardedStore<R>` — the generic persistent-store core (ISSUE 4
//! tentpole). `CacheStore` (oracle results) and `ModelStore` (fitted
//! surrogates) used to mirror the same shard/lock/flush protocol line
//! for line; every drift between the two copies was a correctness
//! hazard. This module owns the protocol once, and both stores are now
//! thin typed wrappers:
//!
//! - **Content-hash shard routing**: u64 keys (splitmix-finalized
//!   hashes) route to one of N shard files by their top byte.
//! - **Schema-tagged JSONL records**: the store owns the envelope
//!   (`v`, `kind`, `key`, `used`); a [`Record`] implementation encodes
//!   and decodes the payload fields. Unknown schema versions and
//!   corrupt lines are skipped on load — a torn or foreign record is
//!   never served.
//! - **Lazy per-shard load**: a shard file parses the first time a key
//!   routed to it is requested.
//! - **Atomic flush**: dirty shards rewrite via temp + rename (same
//!   directory, so the rename is atomic) in sorted `(kind, key)` order
//!   — shard files are byte-deterministic for a given entry set.
//! - **`.store.lock` ordering + merge-on-flush**: flushes serialize
//!   through a directory lock (stolen after a staleness window, so a
//!   crashed holder never wedges the store), and each dirty shard is
//!   re-parsed from disk right before its rewrite so records another
//!   process flushed since our last read are folded in, never dropped.
//!
//! On top of the shared protocol sit the first **lifecycle policies**
//! ([`StorePolicy`]):
//!
//! - **Eviction** — LRU by last-used stamp under a byte / record /
//!   age budget. Stamps are *logical epochs* (the store's open
//!   counter, persisted in `meta.json`), not wall-clock times: two runs
//!   replaying the same operation sequence assign identical stamps, so
//!   eviction decisions — and therefore shard bytes — stay
//!   deterministic. Evicting a key plants a **tombstone** record, so
//!   merge-on-flush in a concurrent process cannot resurrect the
//!   evicted entry from its own stale shard read — for as long as the
//!   tombstone is on disk. Compaction reclaims tombstones, which
//!   narrows that guarantee: a concurrent writer that loaded the key
//!   before the eviction and flushes after the compact can write the
//!   record back. That is deliberate and safe for a cache — by the
//!   determinism contract the resurrected value is identical, so the
//!   cost is bytes, not correctness, and any active budget simply
//!   re-evicts it at its next flush or compact. Budgets apply to
//!   live-record bytes; they are enforced on every flush that has work
//!   to do, and on every compaction.
//! - **Compaction** — [`ShardedStore::compact`] (CLI: `fso store
//!   compact`) loads and merges every shard, applies the eviction
//!   policy, then rewrites shards dropping tombstones, superseded /
//!   unparseable lines, and orphaned temp files. A shard whose bytes
//!   would not change is left untouched, so compaction is idempotent
//!   and never perturbs a warm start: reads before and after compact
//!   are identical. Flush auto-compacts when the dead-line ratio on
//!   disk (tombstones + garbage + shadowed lines over total lines)
//!   crosses `auto_compact_ratio`.
//!
//! Pending-count contract (ISSUE 4 satellite): `StoreStats::pending`
//! counts exactly the records that are not yet durable — per-slot
//! dirty flags, not "everything in a dirty shard" — so a
//! merge-on-flush that folds disk records into memory can no longer
//! drift the count.

use std::borrow::Cow;
use std::collections::hash_map::Entry;
use std::collections::{HashMap, HashSet};
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::fault::{self, FlushFault};
use super::lock::{tmp_path, write_atomic, DirLock};

/// Reserved record kind for eviction tombstones (never a payload kind).
pub const TOMB_KIND: &str = "tomb";

/// A record family a `ShardedStore` can persist. The store owns the
/// envelope fields (`v`, `kind`, `key`, `used`); implementations own
/// only the payload.
pub trait Record: Clone + PartialEq + Send {
    /// Envelope kind tag — also the deterministic sort class within a
    /// shard file. Must never be [`TOMB_KIND`]. Borrowing from `self`
    /// is encouraged (`Cow::Borrowed`): the tag is compared on every
    /// `get` hit, so an owned allocation per call is pure overhead.
    fn kind(&self) -> Cow<'_, str>;
    /// Append the payload fields to the record object.
    fn encode(&self, out: &mut Vec<(&'static str, Json)>);
    /// Decode a payload from the full record object; `None` reads as a
    /// corrupt line (skipped on load, dropped at compaction).
    fn decode(kind: &str, rec: &Json) -> Option<Self>
    where
        Self: Sized;
}

/// Static knobs a typed wrapper fixes once for its record family.
#[derive(Debug, Clone)]
pub struct StoreConfig {
    /// Record schema version; bump on any layout change. Loaders skip
    /// records whose tag does not match.
    pub schema_version: u64,
    /// Shard-file count for fresh directories (existing directories
    /// keep the count recorded in `meta.json`).
    pub default_shards: usize,
    /// Shard file prefix (`shard` -> `shard-003.jsonl`).
    pub file_prefix: &'static str,
    /// Noun used in error messages ("cache dir", "model store").
    pub label: &'static str,
    /// Lifecycle policy (eviction budgets + auto-compaction).
    pub policy: StorePolicy,
}

/// Eviction / compaction policy. `Default` is unbounded with no
/// auto-compaction; [`StorePolicy::default_auto`] is what the wrappers
/// ship — unbounded, but auto-compacting once half the disk lines are
/// dead.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StorePolicy {
    /// Evict LRU records until live-record bytes fit this budget.
    /// (Shard files may transiently exceed it by tombstone overhead
    /// until the next compaction.)
    pub max_bytes: Option<u64>,
    /// Evict LRU records until at most this many live records remain.
    pub max_records: Option<usize>,
    /// Evict records whose last *persisted* use is more than this many
    /// epochs old (an epoch is one open of the store directory; 0 =
    /// only the current epoch survives). Caveat: runs with no budget
    /// configured never rewrite shards for reads, so a fully-warm
    /// unbounded run does not advance stamps on disk — pair `max_age`
    /// with budget-carrying runs (or use the byte/record budgets,
    /// whose *relative* LRU order is unaffected), and expect
    /// write-age semantics otherwise.
    pub max_age_epochs: Option<u64>,
    /// Auto-compact after a flush when dead disk lines (tombstones +
    /// garbage + shadowed) exceed this fraction of all lines.
    pub auto_compact_ratio: Option<f64>,
}

impl StorePolicy {
    /// The wrappers' default: unbounded, auto-compacting at 50% dead.
    pub fn default_auto() -> StorePolicy {
        StorePolicy { auto_compact_ratio: Some(0.5), ..StorePolicy::default() }
    }

    /// Whether any eviction budget is set (budget enforcement loads
    /// every shard at flush, so it only runs when asked for).
    pub fn is_bounded(&self) -> bool {
        self.max_bytes.is_some() || self.max_records.is_some() || self.max_age_epochs.is_some()
    }
}

/// Counter snapshot (wrappers re-surface these through their own
/// stats structs).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StoreStats {
    /// Lookups answered with a live record of the requested kind.
    pub hits: usize,
    /// Lookups that found nothing (or a kind mismatch / tombstone).
    pub misses: usize,
    /// Shard files parsed so far (lazy loading).
    pub shard_loads: usize,
    /// `flush` calls that wrote at least one shard.
    pub flushes: usize,
    /// Live records currently held in memory.
    pub entries: usize,
    /// Records (live or tombstone) not yet durable on disk — exactly
    /// the per-slot dirty flags, never "everything in a dirty shard".
    pub pending: usize,
    /// Tombstones currently held (reclaimed at compaction).
    pub tombstones: usize,
    /// Serialized bytes of the live records (the eviction byte budget
    /// is judged against this). Exact whenever `max_bytes` is set;
    /// without a byte budget, records put since the last flush count
    /// as 0 until a flush or load renders them.
    pub live_bytes: u64,
    /// Records evicted by policy or `evict` since open.
    pub evictions: usize,
    /// Compaction passes since open (explicit + automatic).
    pub compactions: usize,
    /// This instance's logical epoch (open counter of the directory).
    pub epoch: u64,
}

/// What one compaction pass did.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CompactReport {
    /// Shard files rewritten or removed (unchanged shards are skipped).
    pub shards_rewritten: usize,
    /// Live records in the compacted store.
    pub live_records: usize,
    /// Tombstones dropped from memory + disk.
    pub tombstones_dropped: usize,
    /// Dead disk lines reclaimed (tombstones, unparseable garbage,
    /// superseded-schema records, shadowed duplicates).
    pub dead_lines_dropped: usize,
    /// Records evicted by the policy during this pass.
    pub evicted: usize,
    /// Total shard-file bytes before / after.
    pub bytes_before: u64,
    pub bytes_after: u64,
}

impl std::fmt::Display for CompactReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} live records | dropped {} tombstones / {} dead lines | evicted {} | {} -> {} bytes | {} shards rewritten",
            self.live_records,
            self.tombstones_dropped,
            self.dead_lines_dropped,
            self.evicted,
            self.bytes_before,
            self.bytes_after,
            self.shards_rewritten
        )
    }
}

#[derive(Clone)]
enum SlotState<R> {
    Live(R),
    /// Evicted: reads miss; persisted as a tombstone record so a
    /// concurrent process's merge-on-flush cannot resurrect the key.
    Tomb,
}

#[derive(Clone)]
struct Slot<R> {
    state: SlotState<R>,
    /// Logical last-used stamp (the store epoch that last touched it).
    used: u64,
    /// Serialized line length in bytes (incl. newline) — the unit the
    /// byte budget is accounted in.
    bytes: usize,
    /// Not yet durable on disk.
    dirty: bool,
}

#[derive(Clone, Copy)]
struct ShardMeta {
    loaded: bool,
    /// Needs a rewrite at the next flush (dirty slots, stamp bumps
    /// under an active policy, or evictions).
    dirty: bool,
    /// Line stats from the most recent parse / rewrite of the disk
    /// file (drives the auto-compaction ratio).
    disk_lines: usize,
    disk_dead: usize,
}

struct Inner<R> {
    slots: HashMap<u64, Slot<R>>,
    shards: Vec<ShardMeta>,
}

/// Disk-backed, sharded, read-through/write-behind store. Thread-safe;
/// share one instance across services via `Arc`.
pub struct ShardedStore<R: Record> {
    dir: PathBuf,
    cfg: StoreConfig,
    n_shards: usize,
    /// Logical clock: how many times this directory has been opened
    /// (persisted in `meta.json`). All accesses in one instance stamp
    /// with this epoch, so stamps are independent of thread schedule —
    /// and shard bytes stay deterministic under parallel access.
    epoch: u64,
    inner: Mutex<Inner<R>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    shard_loads: AtomicUsize,
    flushes: AtomicUsize,
    evictions: AtomicUsize,
    compactions: AtomicUsize,
}

impl<R: Record> ShardedStore<R> {
    /// Open (creating if needed) a store directory with the config's
    /// default shard count. An existing directory keeps the shard
    /// count it was created with (recorded in `meta.json`), so
    /// reopening with a different default never mis-routes keys. Every
    /// open bumps the directory's logical epoch.
    pub fn open(dir: impl Into<PathBuf>, cfg: StoreConfig) -> Result<ShardedStore<R>> {
        let n = cfg.default_shards;
        ShardedStore::open_sharded(dir, cfg, n)
    }

    /// Open with an explicit shard count (ignored when the directory
    /// already records one).
    pub fn open_sharded(
        dir: impl Into<PathBuf>,
        cfg: StoreConfig,
        n_shards: usize,
    ) -> Result<ShardedStore<R>> {
        let dir = dir.into();
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating {} {}", cfg.label, dir.display()))?;
        let meta_path = dir.join("meta.json");
        let (n_shards, epoch, fresh) = match fs::read_to_string(&meta_path) {
            Ok(text) => {
                let meta = Json::parse(&text)
                    .with_context(|| format!("parsing {}", meta_path.display()))?;
                let v = meta.get("v").as_usize().unwrap_or(0) as u64;
                anyhow::ensure!(
                    v == cfg.schema_version,
                    "{} {} has schema v{v}, this binary expects v{}",
                    cfg.label,
                    dir.display(),
                    cfg.schema_version
                );
                let shards = meta
                    .get("shards")
                    .as_usize()
                    .filter(|&s| s > 0)
                    .with_context(|| format!("{}: bad shard count", meta_path.display()))?;
                // epoch was introduced with the store core; a pre-core
                // meta.json (no field) reads as epoch 0
                let epoch = meta.get("epoch").as_usize().unwrap_or(0) as u64;
                (shards, epoch.saturating_add(1), false)
            }
            // only a genuinely absent meta.json means "fresh directory";
            // any other read error (permissions, transient IO) must not
            // silently re-shard an existing store under a new layout
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => (n_shards.max(1), 1, true),
            Err(e) => {
                return Err(e).with_context(|| format!("reading {}", meta_path.display()))
            }
        };
        // persist the bumped epoch (concurrent opens race benignly:
        // the rename is atomic and the epoch only steers LRU policy)
        let meta = Json::obj(vec![
            ("v", Json::from(cfg.schema_version as usize)),
            ("shards", Json::from(n_shards)),
            ("epoch", Json::from(epoch as usize)),
        ]);
        let wrote = write_atomic(&meta_path, format!("{meta}\n").as_bytes());
        if fresh {
            // a store we cannot create is an error...
            wrote?;
        } else {
            // ...but an existing store on a read-only mount must stay
            // readable: the epoch bump is best-effort (LRU stamps just
            // stop advancing; pure readers never flush anyway)
            let _ = wrote;
        }
        Ok(ShardedStore {
            dir,
            cfg,
            n_shards,
            epoch,
            inner: Mutex::new(Inner {
                slots: HashMap::new(),
                shards: vec![
                    ShardMeta { loaded: false, dirty: false, disk_lines: 0, disk_dead: 0 };
                    n_shards
                ],
            }),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            shard_loads: AtomicUsize::new(0),
            flushes: AtomicUsize::new(0),
            evictions: AtomicUsize::new(0),
            compactions: AtomicUsize::new(0),
        })
    }

    /// Replace the lifecycle policy (builder-style, before sharing).
    pub fn with_policy(mut self, policy: StorePolicy) -> ShardedStore<R> {
        self.cfg.policy = policy;
        self
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn shard_count(&self) -> usize {
        self.n_shards
    }

    pub fn policy(&self) -> &StorePolicy {
        &self.cfg.policy
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    fn shard_of(&self, key: u64) -> usize {
        // content-hash prefix routing: the top byte spreads uniformly
        // because keys come out of splitmix-finalized hashes
        ((key >> 56) as usize) % self.n_shards
    }

    fn shard_path(&self, shard: usize) -> PathBuf {
        self.dir.join(format!("{}-{shard:03}.jsonl", self.cfg.file_prefix))
    }

    // ---- envelope (de)serialization --------------------------------
    //
    // u64 keys are stored as 16-hex-digit strings (JSON numbers are
    // f64 — 53 mantissa bits would corrupt hash keys). `Json::obj`
    // sorts keys, so a rendered line is deterministic for its fields.

    fn render_live(&self, key: u64, rec: &R, used: u64) -> String {
        let mut extra: Vec<(&'static str, Json)> = Vec::new();
        rec.encode(&mut extra);
        let kind = rec.kind();
        let mut fields: Vec<(&str, Json)> = vec![
            ("v", Json::from(self.cfg.schema_version as usize)),
            ("kind", Json::from(kind.as_ref())),
            ("key", Json::from(hex_key(key).as_str())),
            ("used", Json::from(used as usize)),
        ];
        for (k, v) in extra {
            fields.push((k, v));
        }
        Json::obj(fields).to_string()
    }

    fn render_tomb(&self, key: u64, used: u64) -> String {
        Json::obj(vec![
            ("v", Json::from(self.cfg.schema_version as usize)),
            ("kind", Json::from(TOMB_KIND)),
            ("key", Json::from(hex_key(key).as_str())),
            ("used", Json::from(used as usize)),
        ])
        .to_string()
    }

    fn parse_line(&self, line: &str) -> Option<(u64, u64, SlotState<R>)> {
        let rec = Json::parse(line).ok()?;
        if rec.get("v").as_usize().map(|v| v as u64) != Some(self.cfg.schema_version) {
            return None;
        }
        let key = rec.get("key").as_str().and_then(parse_hex_key)?;
        // pre-core records carry no stamp: they read as "oldest"
        let used = rec.get("used").as_usize().map(|v| v as u64).unwrap_or(0);
        let kind = rec.get("kind").as_str()?;
        if kind == TOMB_KIND {
            return Some((key, used, SlotState::Tomb));
        }
        let r = R::decode(kind, &rec)?;
        Some((key, used, SlotState::Live(r)))
    }

    /// Parse a shard file into the slots the first time a key routed
    /// to it is requested.
    fn load_shard(&self, inner: &mut Inner<R>, shard: usize) {
        if inner.shards[shard].loaded {
            return;
        }
        inner.shards[shard].loaded = true;
        self.shard_loads.fetch_add(1, Ordering::Relaxed);
        self.parse_shard_lines(inner, shard);
    }

    /// The raw disk-to-memory merge under `load_shard`, the flush-time
    /// re-read, and the compact-time sweep. Unknown schema versions,
    /// unknown kinds, and corrupt lines are skipped (a half-written or
    /// foreign record must never sink a run). Merge rule: in-memory
    /// entries win unless the disk stamp is strictly newer *and* ours
    /// is clean — a fresher use or eviction by a concurrent process
    /// replaces a clean slot; our own unflushed data is never clobbered.
    /// Also refreshes the shard's dead-line stats (tombstones +
    /// garbage + in-file shadowed duplicates) for auto-compaction.
    fn parse_shard_lines(&self, inner: &mut Inner<R>, shard: usize) {
        let text = match fs::read_to_string(self.shard_path(shard)) {
            Ok(t) => t,
            Err(_) => {
                // never flushed, or unreadable: treat as empty
                inner.shards[shard].disk_lines = 0;
                inner.shards[shard].disk_dead = 0;
                return;
            }
        };
        let mut total = 0usize;
        let mut dead = 0usize;
        let mut seen: HashSet<u64> = HashSet::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            total += 1;
            let Some((key, used, state)) = self.parse_line(line) else {
                dead += 1;
                continue;
            };
            if !seen.insert(key) {
                // in-file duplicate: first record wins, later copies
                // are shadowed (and reclaimable)
                dead += 1;
                continue;
            }
            if matches!(state, SlotState::Tomb) {
                dead += 1; // tombstones are reclaimable at compaction
            }
            let bytes = line.len() + 1;
            match inner.slots.entry(key) {
                Entry::Vacant(v) => {
                    v.insert(Slot { state, used, bytes, dirty: false });
                }
                Entry::Occupied(mut o) => {
                    let cur = o.get();
                    if !cur.dirty && used > cur.used {
                        o.insert(Slot { state, used, bytes, dirty: false });
                    }
                }
            }
        }
        inner.shards[shard].disk_lines = total;
        inner.shards[shard].disk_dead = dead;
    }

    /// Force every shard into memory (CLI stats and union assertions;
    /// normal traffic should rely on lazy loading).
    pub fn load_all(&self) {
        let mut inner = self.inner.lock().unwrap();
        for s in 0..self.n_shards {
            self.load_shard(&mut inner, s);
        }
    }

    /// Merge every shard from disk, one parse per shard: a first touch
    /// goes through the lazy-load path; an already-loaded shard
    /// re-parses to fold in records concurrent processes flushed since
    /// we read it. Call with the `DirLock` held — then the disk state
    /// cannot move underneath, and the merged view stays current for
    /// the rest of the locked section.
    fn merge_all(&self, inner: &mut Inner<R>) {
        for s in 0..self.n_shards {
            if inner.shards[s].loaded {
                self.parse_shard_lines(inner, s);
            } else {
                self.load_shard(inner, s);
            }
        }
    }

    /// Live record of `kind` for `key`, if known. A key held under a
    /// different kind — or a tombstone — reads as a miss. A hit bumps
    /// the LRU stamp to the current epoch (marking the shard for
    /// rewrite only when an eviction budget is active, so unbounded
    /// warm runs stay read-only on disk).
    pub fn get(&self, kind: &str, key: u64) -> Option<R> {
        let mut inner = self.inner.lock().unwrap();
        let shard = self.shard_of(key);
        self.load_shard(&mut inner, shard);
        let epoch = self.epoch;
        let mut bumped = false;
        let hit = match inner.slots.get_mut(&key) {
            Some(slot) => match &slot.state {
                SlotState::Live(r) if r.kind() == kind => {
                    if slot.used < epoch {
                        slot.used = epoch;
                        bumped = true;
                    }
                    Some(r.clone())
                }
                _ => None,
            },
            None => None,
        };
        if bumped && self.cfg.policy.is_bounded() {
            inner.shards[shard].dirty = true;
        }
        match hit {
            Some(r) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(r)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Record a value (write-behind: durable at the next flush). An
    /// identical live value only refreshes the LRU stamp; a changed
    /// value, a resurrection over a tombstone, or a fresh key dirties
    /// the slot — that is how a corrupt artifact gets repaired after
    /// its fallback recompute.
    pub fn put(&self, key: u64, rec: R) {
        let mut inner = self.inner.lock().unwrap();
        let shard = self.shard_of(key);
        let epoch = self.epoch;
        let same = matches!(
            inner.slots.get(&key),
            Some(Slot { state: SlotState::Live(cur), .. }) if *cur == rec
        );
        if same {
            let mut bumped = false;
            if let Some(slot) = inner.slots.get_mut(&key) {
                if slot.used < epoch {
                    slot.used = epoch;
                    bumped = true;
                }
            }
            if bumped && self.cfg.policy.is_bounded() {
                inner.shards[shard].dirty = true;
            }
        } else {
            // measure the serialized size only when a byte budget needs
            // it — rendering on every put would double serialization
            // work for the common unbounded store (flush's render pass
            // refreshes `bytes` to the exact length either way)
            let bytes = if self.cfg.policy.max_bytes.is_some() {
                self.render_live(key, &rec, epoch).len() + 1
            } else {
                0
            };
            inner
                .slots
                .insert(key, Slot { state: SlotState::Live(rec), used: epoch, bytes, dirty: true });
            inner.shards[shard].dirty = true;
        }
    }

    /// Explicitly evict a key: it reads as a miss from now on, and a
    /// tombstone persists the eviction so a concurrent writer's merge
    /// cannot resurrect a *staler* copy of the record. Advisory, not
    /// absolute: a concurrent process that used the key at a strictly
    /// newer epoch keeps it live through its own merge (and compaction
    /// reclaims tombstones — see the module docs); for a deterministic
    /// cache that only ever costs bytes, and budgets re-evict. Returns
    /// whether a live record was evicted.
    pub fn evict(&self, key: u64) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let shard = self.shard_of(key);
        self.load_shard(&mut inner, shard);
        let live = matches!(
            inner.slots.get(&key),
            Some(Slot { state: SlotState::Live(_), .. })
        );
        if live {
            self.tombstone(&mut inner, key);
        }
        live
    }

    fn tombstone(&self, inner: &mut Inner<R>, key: u64) {
        let epoch = self.epoch;
        let bytes = self.render_tomb(key, epoch).len() + 1;
        inner
            .slots
            .insert(key, Slot { state: SlotState::Tomb, used: epoch, bytes, dirty: true });
        let shard = self.shard_of(key);
        inner.shards[shard].dirty = true;
        self.evictions.fetch_add(1, Ordering::Relaxed);
    }

    /// Enforce the eviction policy over the (fully loaded) slot map:
    /// age bound first, then LRU down to the byte / record budgets.
    /// Deterministic: candidates order by (stamp, key).
    fn apply_policy(&self, inner: &mut Inner<R>) {
        let pol = self.cfg.policy.clone();
        let epoch = self.epoch;
        if let Some(max_age) = pol.max_age_epochs {
            let mut expired: Vec<u64> = inner
                .slots
                .iter()
                .filter_map(|(&k, s)| {
                    let live = matches!(s.state, SlotState::Live(_));
                    (live && epoch.saturating_sub(s.used) > max_age).then_some(k)
                })
                .collect();
            expired.sort_unstable();
            for key in expired {
                self.tombstone(inner, key);
            }
        }
        let mut live: Vec<(u64, u64, usize)> = inner
            .slots
            .iter()
            .filter_map(|(&k, s)| match s.state {
                SlotState::Live(_) => Some((s.used, k, s.bytes)),
                SlotState::Tomb => None,
            })
            .collect();
        let mut bytes: u64 = live.iter().map(|&(_, _, b)| b as u64).sum();
        let mut count = live.len();
        let over = |bytes: u64, count: usize| {
            pol.max_bytes.is_some_and(|m| bytes > m)
                || pol.max_records.is_some_and(|m| count > m)
        };
        if !over(bytes, count) {
            return;
        }
        live.sort_unstable(); // (used, key, bytes): oldest stamp first
        let mut i = 0;
        while i < live.len() && over(bytes, count) {
            let (_, key, b) = live[i];
            self.tombstone(inner, key);
            bytes -= b as u64;
            count -= 1;
            i += 1;
        }
    }

    /// Serialize one shard's slots in sorted (kind, key) order.
    /// Returns (body, line count, tombstone count) and refreshes each
    /// written slot's byte size to the exact rendered length.
    fn render_shard(&self, inner: &mut Inner<R>, shard: usize) -> (String, usize, usize) {
        let mut lines: Vec<(String, u64, String)> = Vec::new();
        let mut tombs = 0usize;
        for (&key, slot) in &inner.slots {
            if self.shard_of(key) != shard {
                continue;
            }
            let (kind, line) = match &slot.state {
                SlotState::Live(r) => {
                    (r.kind().into_owned(), self.render_live(key, r, slot.used))
                }
                SlotState::Tomb => {
                    tombs += 1;
                    (TOMB_KIND.to_string(), self.render_tomb(key, slot.used))
                }
            };
            lines.push((kind, key, line));
        }
        for (_, key, line) in &lines {
            if let Some(slot) = inner.slots.get_mut(key) {
                slot.bytes = line.len() + 1;
            }
        }
        // sorted (kind, key) order: shard bytes are deterministic
        lines.sort_by(|a, b| (a.0.as_str(), a.1).cmp(&(b.0.as_str(), b.1)));
        let mut body = String::new();
        for (_, _, line) in &lines {
            body.push_str(line);
            body.push('\n');
        }
        (body, lines.len(), tombs)
    }

    fn clear_slot_dirty(&self, inner: &mut Inner<R>, shard: usize) {
        for (&key, slot) in inner.slots.iter_mut() {
            if self.shard_of(key) == shard {
                slot.dirty = false;
            }
        }
    }

    fn auto_compact_due(&self, inner: &Inner<R>) -> bool {
        let Some(ratio) = self.cfg.policy.auto_compact_ratio else {
            return false;
        };
        let (lines, dead) = inner
            .shards
            .iter()
            .fold((0usize, 0usize), |a, s| (a.0 + s.disk_lines, a.1 + s.disk_dead));
        lines > 0 && (dead as f64) / (lines as f64) > ratio
    }

    /// Write every dirty shard atomically (temp + rename), serialized
    /// across processes by the directory lock and merged with the disk
    /// state first — a flush never drops entries: neither on-disk
    /// records this run did not happen to read, nor records a
    /// concurrent process flushed since. When an eviction budget is
    /// active the policy is enforced first (which loads every shard).
    /// Returns the number of shard files written; may trigger an
    /// auto-compaction afterwards (see `StorePolicy`).
    pub fn flush(&self) -> Result<usize> {
        // cheap dirtiness pre-check, then take the cross-process lock
        // *without* holding the in-process Mutex: a contended DirLock
        // wait (up to the staleness window) must not stall every
        // worker thread doing get/put on the shared store
        {
            let inner = self.inner.lock().unwrap();
            if !inner.shards.iter().any(|s| s.dirty) {
                return Ok(0);
            }
        }
        let lock = DirLock::acquire(&self.dir)?;
        let mut inner = self.inner.lock().unwrap();
        let premerged = self.cfg.policy.is_bounded();
        if premerged {
            // merge every shard from disk *before* deciding evictions:
            // shards loaded long ago may hold stale LRU stamps, and
            // evicting on a stale view could tombstone a key a
            // concurrent process used (and stamped fresher) since —
            // its dirty tombstone would then survive the merge and
            // clobber the most-recently-used record instead of the
            // least.
            self.merge_all(&mut inner);
            self.apply_policy(&mut inner);
        }
        // recompute under the lock: another thread may have flushed
        let dirty: Vec<usize> =
            (0..self.n_shards).filter(|&s| inner.shards[s].dirty).collect();
        if dirty.is_empty() {
            return Ok(0);
        }
        for &shard in &dirty {
            lock.refresh();
            if !premerged {
                // merge-on-flush; redundant when merge_all already ran
                // under this same lock (the disk cannot have moved)
                self.parse_shard_lines(&mut inner, shard);
                inner.shards[shard].loaded = true;
            }
            let (body, lines, tombs) = self.render_shard(&mut inner, shard);
            let path = self.shard_path(shard);
            if fault::trip(FlushFault::BeforeRename) {
                // emulate a kill after the temp write, before the
                // rename: the temp file exists, the shard file is
                // untouched, and the directory lock stays behind (the
                // "process" died holding it)
                let _ = fs::write(tmp_path(&path), body.as_bytes());
                std::mem::forget(lock);
                anyhow::bail!("injected crash before rename (store::fault)");
            }
            write_atomic(&path, body.as_bytes())?;
            inner.shards[shard].dirty = false;
            inner.shards[shard].disk_lines = lines;
            inner.shards[shard].disk_dead = tombs;
            self.clear_slot_dirty(&mut inner, shard);
        }
        self.flushes.fetch_add(1, Ordering::Relaxed);
        if fault::trip(FlushFault::BeforeLockRelease) {
            // data is durable; the lock is abandoned as a crash would
            std::mem::forget(lock);
            anyhow::bail!("injected crash before lock release (store::fault)");
        }
        let auto = self.auto_compact_due(&inner);
        drop(inner);
        drop(lock);
        if auto {
            self.compact()?;
        }
        Ok(dirty.len())
    }

    /// Compaction pass: load + merge every shard, enforce the eviction
    /// policy, drop tombstones and dead lines, and rewrite only the
    /// shards whose bytes change (so a second compact is a no-op and a
    /// warm start straddling a compact replays identical reads). Also
    /// sweeps orphaned temp files left by killed writers. Serialized
    /// by the directory lock; also persists any pending writes.
    pub fn compact(&self) -> Result<CompactReport> {
        let lock = DirLock::acquire(&self.dir)?;
        let mut inner = self.inner.lock().unwrap();
        // merge-on-compact: fold in records concurrent processes
        // flushed since our lazy loads (one parse per shard)
        self.merge_all(&mut inner);
        let ev0 = self.evictions.load(Ordering::Relaxed);
        if self.cfg.policy.is_bounded() {
            self.apply_policy(&mut inner);
        }
        let mut rep = CompactReport {
            evicted: self.evictions.load(Ordering::Relaxed) - ev0,
            dead_lines_dropped: inner.shards.iter().map(|s| s.disk_dead).sum(),
            ..CompactReport::default()
        };
        let tomb_keys: Vec<u64> = inner
            .slots
            .iter()
            .filter_map(|(&k, s)| matches!(s.state, SlotState::Tomb).then_some(k))
            .collect();
        rep.tombstones_dropped = tomb_keys.len();
        for k in &tomb_keys {
            inner.slots.remove(k);
        }
        for shard in 0..self.n_shards {
            lock.refresh();
            let path = self.shard_path(shard);
            let before = fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
            rep.bytes_before += before;
            let (body, lines, _) = self.render_shard(&mut inner, shard);
            if body.is_empty() {
                if before > 0 {
                    let _ = fs::remove_file(&path);
                    rep.shards_rewritten += 1;
                }
            } else {
                let unchanged = before == body.len() as u64
                    && fs::read(&path).map(|b| b == body.as_bytes()).unwrap_or(false);
                if !unchanged {
                    write_atomic(&path, body.as_bytes())?;
                    rep.shards_rewritten += 1;
                }
                rep.bytes_after += body.len() as u64;
            }
            inner.shards[shard].dirty = false;
            inner.shards[shard].disk_lines = lines;
            inner.shards[shard].disk_dead = 0;
            self.clear_slot_dirty(&mut inner, shard);
            rep.live_records += lines;
        }
        // sweep crash leftovers: orphaned *shard* temp files from
        // killed writers. Meta temps are deliberately spared — another
        // process may be mid-open (the meta epoch bump takes no
        // DirLock), and deleting its staged temp would fail that open.
        let tmp_prefix = format!(".{}-", self.cfg.file_prefix);
        if let Ok(rd) = fs::read_dir(&self.dir) {
            for e in rd.flatten() {
                let name = e.file_name();
                let name = name.to_string_lossy();
                if name.starts_with(tmp_prefix.as_str()) && name.contains(".tmp-") {
                    let _ = fs::remove_file(e.path());
                }
            }
        }
        self.compactions.fetch_add(1, Ordering::Relaxed);
        Ok(rep)
    }

    /// Snapshot the store counters. `pending` counts exactly the
    /// not-yet-durable slots (the ISSUE 4 drift fix).
    pub fn stats(&self) -> StoreStats {
        let inner = self.inner.lock().unwrap();
        let mut entries = 0usize;
        let mut tombstones = 0usize;
        let mut pending = 0usize;
        let mut live_bytes = 0u64;
        for slot in inner.slots.values() {
            match slot.state {
                SlotState::Live(_) => {
                    entries += 1;
                    live_bytes += slot.bytes as u64;
                }
                SlotState::Tomb => tombstones += 1,
            }
            if slot.dirty {
                pending += 1;
            }
        }
        StoreStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            shard_loads: self.shard_loads.load(Ordering::Relaxed),
            flushes: self.flushes.load(Ordering::Relaxed),
            entries,
            pending,
            tombstones,
            live_bytes,
            evictions: self.evictions.load(Ordering::Relaxed),
            compactions: self.compactions.load(Ordering::Relaxed),
            epoch: self.epoch,
        }
    }

    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn shard_loads(&self) -> usize {
        self.shard_loads.load(Ordering::Relaxed)
    }

    pub fn flush_count(&self) -> usize {
        self.flushes.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> usize {
        self.evictions.load(Ordering::Relaxed)
    }

    pub fn compactions(&self) -> usize {
        self.compactions.load(Ordering::Relaxed)
    }
}

impl<R: Record> Drop for ShardedStore<R> {
    /// Best-effort durability for callers that forget an explicit
    /// flush; errors are swallowed (Drop cannot fail).
    fn drop(&mut self) {
        let _ = self.flush();
    }
}

pub fn parse_hex_key(s: &str) -> Option<u64> {
    u64::from_str_radix(s, 16).ok()
}

pub fn hex_key(key: u64) -> String {
    format!("{key:016x}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, Clone, Copy, PartialEq)]
    struct TestRec {
        tag: &'static str,
        val: f64,
    }

    impl Record for TestRec {
        fn kind(&self) -> Cow<'_, str> {
            Cow::Borrowed(self.tag)
        }
        fn encode(&self, out: &mut Vec<(&'static str, Json)>) {
            out.push(("val", Json::from(self.val)));
        }
        fn decode(kind: &str, rec: &Json) -> Option<TestRec> {
            let tag = match kind {
                "a" => "a",
                "b" => "b",
                _ => return None,
            };
            Some(TestRec { tag, val: rec.get("val").as_f64()? })
        }
    }

    fn cfg() -> StoreConfig {
        StoreConfig {
            schema_version: 7,
            default_shards: 4,
            file_prefix: "t",
            label: "test store",
            policy: StorePolicy::default_auto(),
        }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("fso-sharded-core-{}-{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn open(dir: &Path) -> ShardedStore<TestRec> {
        ShardedStore::open(dir, cfg()).unwrap()
    }

    /// Keys with a chosen top byte (shard) and low tag.
    fn key(top: u8, low: u64) -> u64 {
        ((top as u64) << 56) | low
    }

    fn rec(val: f64) -> TestRec {
        TestRec { tag: "a", val }
    }

    #[test]
    fn roundtrip_kind_mismatch_and_tombstone_semantics() {
        let dir = tmp_dir("roundtrip");
        {
            let s = open(&dir);
            s.put(key(1, 10), rec(0.5));
            s.put(key(1, 11), TestRec { tag: "b", val: 1.5 });
            assert_eq!(s.stats().pending, 2);
            s.flush().unwrap();
            assert_eq!(s.stats().pending, 0);
        }
        let s = open(&dir);
        assert_eq!(s.get("a", key(1, 10)), Some(rec(0.5)));
        assert_eq!(s.get("b", key(1, 10)), None, "kind mismatch is a miss");
        assert_eq!(s.get("b", key(1, 11)), Some(TestRec { tag: "b", val: 1.5 }));
        assert!(s.evict(key(1, 10)));
        assert!(!s.evict(key(1, 10)), "second evict finds nothing live");
        assert_eq!(s.get("a", key(1, 10)), None, "evicted key is a miss");
        s.flush().unwrap();
        drop(s);
        let s = open(&dir);
        assert_eq!(s.get("a", key(1, 10)), None, "tombstone survives reopen");
        assert_eq!(s.get("b", key(1, 11)), Some(TestRec { tag: "b", val: 1.5 }));
        // resurrection: a fresh put over the tombstone is live again
        s.put(key(1, 10), rec(2.5));
        s.flush().unwrap();
        drop(s);
        let s = open(&dir);
        assert_eq!(s.get("a", key(1, 10)), Some(rec(2.5)));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn pending_counts_only_undurable_slots_after_merge_on_flush() {
        // the ISSUE 4 stats-drift fix, at the core level: disk records
        // folded in by merge-on-flush must not count as pending when a
        // new record later dirties their shard
        let dir = tmp_dir("pending");
        {
            let other = open(&dir);
            other.put(key(2, 1), rec(1.0));
            other.put(key(2, 2), rec(2.0));
            other.flush().unwrap();
        }
        let s = open(&dir);
        s.put(key(2, 3), rec(3.0));
        assert_eq!(s.stats().pending, 1);
        s.flush().unwrap(); // merges keys 1 and 2 from disk
        assert_eq!(s.stats().entries, 3);
        assert_eq!(s.stats().pending, 0);
        s.put(key(2, 4), rec(4.0));
        let st = s.stats();
        assert_eq!(st.entries, 4);
        assert_eq!(
            st.pending, 1,
            "pending must count the one new record, not the whole dirty shard"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn byte_budget_evicts_lru_then_compact_fits_files_in_budget() {
        let dir = tmp_dir("budget");
        let n = 10u64;
        let probe_dir = tmp_dir("budget-probe");
        let line_len = {
            // probe one record's serialized size (all identical shape);
            // a byte budget must be set for puts to measure themselves
            let probe = ShardedStore::<TestRec>::open(
                &probe_dir,
                StoreConfig {
                    policy: StorePolicy {
                        max_bytes: Some(u64::MAX),
                        ..StorePolicy::default()
                    },
                    ..cfg()
                },
            )
            .unwrap();
            probe.put(key(3, 100), rec(0.25));
            probe.stats().live_bytes as usize
        };
        let _ = fs::remove_dir_all(&probe_dir);
        let budget = (line_len * 6) as u64; // room for ~6 of 10
        let s = ShardedStore::<TestRec>::open(
            &dir,
            StoreConfig {
                policy: StorePolicy { max_bytes: Some(budget), ..StorePolicy::default() },
                ..cfg()
            },
        )
        .unwrap();
        for i in 0..n {
            s.put(key(3, 100 + i), rec(0.25));
        }
        s.flush().unwrap();
        let st = s.stats();
        assert!(st.evictions > 0, "over-budget store must evict: {st:?}");
        assert!(
            st.live_bytes <= budget,
            "live bytes {} must fit the budget {budget}",
            st.live_bytes
        );
        // same stamp everywhere -> ties break by key: smallest evicted
        assert_eq!(s.get("a", key(3, 100)), None, "oldest (smallest key) evicted");
        assert_eq!(s.get("a", key(3, 100 + n - 1)), Some(rec(0.25)), "newest kept");
        s.compact().unwrap();
        let on_disk: u64 = fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().path())
            .filter(|p| {
                p.file_name().unwrap().to_string_lossy().starts_with("t-")
            })
            .map(|p| fs::metadata(&p).unwrap().len())
            .sum();
        assert!(
            on_disk <= budget,
            "compacted shard files ({on_disk} B) must fit the byte budget ({budget} B)"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn lru_prefers_recently_used_across_epochs() {
        let dir = tmp_dir("lru");
        {
            let s = open(&dir); // epoch 1
            for i in 0..4u64 {
                s.put(key(4, i), rec(i as f64));
            }
            s.flush().unwrap();
        }
        // epoch 2: touch key 2, add key 9, then shrink to 2 records
        let s = ShardedStore::<TestRec>::open(
            &dir,
            StoreConfig {
                policy: StorePolicy { max_records: Some(2), ..StorePolicy::default() },
                ..cfg()
            },
        )
        .unwrap();
        assert_eq!(s.epoch(), 2);
        assert!(s.get("a", key(4, 2)).is_some()); // bump to epoch 2
        s.put(key(4, 9), rec(9.0)); // stamped epoch 2
        s.flush().unwrap();
        assert_eq!(s.stats().entries, 2);
        assert!(s.get("a", key(4, 2)).is_some(), "recently-used key survives");
        assert!(s.get("a", key(4, 9)).is_some(), "fresh key survives");
        assert!(s.get("a", key(4, 0)).is_none(), "stale keys evicted");
        assert!(s.get("a", key(4, 1)).is_none());
        assert!(s.get("a", key(4, 3)).is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn age_bound_evicts_unused_epochs() {
        let dir = tmp_dir("age");
        {
            let s = open(&dir); // epoch 1
            s.put(key(5, 1), rec(1.0));
            s.put(key(5, 2), rec(2.0));
            s.flush().unwrap();
        }
        // epoch 2, max_age 0: anything not used *this* epoch goes
        let s = ShardedStore::<TestRec>::open(
            &dir,
            StoreConfig {
                policy: StorePolicy { max_age_epochs: Some(0), ..StorePolicy::default() },
                ..cfg()
            },
        )
        .unwrap();
        assert!(s.get("a", key(5, 1)).is_some()); // bump to epoch 2
        s.put(key(5, 3), rec(3.0));
        s.flush().unwrap();
        assert!(s.get("a", key(5, 1)).is_some(), "used-this-epoch survives");
        assert!(s.get("a", key(5, 3)).is_some());
        assert!(s.get("a", key(5, 2)).is_none(), "unused-for-an-epoch evicted");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn auto_compaction_reclaims_tombstones_past_ratio() {
        let dir = tmp_dir("autocompact");
        let s = open(&dir); // default_auto: compacts past 50% dead
        for i in 0..4u64 {
            s.put(key(6, i), rec(i as f64));
        }
        s.flush().unwrap();
        for i in 0..3u64 {
            assert!(s.evict(key(6, i)));
        }
        // the flush writes 3 tombstones + 1 live record (75% dead) and
        // must then auto-compact them away
        s.flush().unwrap();
        assert!(s.compactions() >= 1, "auto-compaction must have fired");
        assert_eq!(s.stats().tombstones, 0, "compaction drops tombstones");
        // keys carry top byte 6 -> shard 6 % 4 = 2
        let text = fs::read_to_string(dir.join("t-002.jsonl")).unwrap_or_default();
        assert!(
            !text.contains("\"tomb\""),
            "no tombstone lines may remain on disk: {text}"
        );
        assert!(s.get("a", key(6, 3)).is_some());
        for i in 0..3u64 {
            assert!(s.get("a", key(6, i)).is_none(), "evicted key resurfaced");
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn compact_is_idempotent_and_preserves_reads() {
        let dir = tmp_dir("idempotent");
        let s = open(&dir);
        for i in 0..6u64 {
            s.put(key(7, i), TestRec { tag: if i % 2 == 0 { "a" } else { "b" }, val: i as f64 });
        }
        s.flush().unwrap();
        s.evict(key(7, 0));
        let r1 = s.compact().unwrap();
        assert_eq!(r1.live_records, 5);
        assert_eq!(r1.tombstones_dropped, 1);
        let snapshot: Vec<Option<TestRec>> = (0..6)
            .map(|i| s.get(if i % 2 == 0 { "a" } else { "b" }, key(7, i)))
            .collect();
        let r2 = s.compact().unwrap();
        assert_eq!(r2.shards_rewritten, 0, "second compact must be a no-op");
        assert_eq!(r2.bytes_before, r2.bytes_after);
        let after: Vec<Option<TestRec>> = (0..6)
            .map(|i| s.get(if i % 2 == 0 { "a" } else { "b" }, key(7, i)))
            .collect();
        assert_eq!(snapshot, after, "compaction must not change any read result");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn epoch_bumps_per_open_and_meta_pins_shards() {
        let dir = tmp_dir("epoch");
        {
            let s = ShardedStore::<TestRec>::open_sharded(&dir, cfg(), 2).unwrap();
            assert_eq!(s.epoch(), 1);
            assert_eq!(s.shard_count(), 2);
        }
        let s = ShardedStore::<TestRec>::open_sharded(&dir, cfg(), 64).unwrap();
        assert_eq!(s.epoch(), 2, "every open bumps the logical epoch");
        assert_eq!(s.shard_count(), 2, "meta.json pins the shard count");
        let _ = fs::remove_dir_all(&dir);
    }
}
